"""Process-parallel backend tests: exact ordered output, zero tuple loss,
markers intact, crash/restart recovery, spill path, and shared-memory hygiene.

The watchdog rides at 60 s for these (process spawn/join failures must
surface fast, not after the 120 s default).
"""
import os
import signal
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import OpSpec, ProcessRuntime, run_graph, run_pipeline
from repro.core.shm import ShmReorderRing, ShmSpscRing


# ---------------------------------------------------------------- helpers
def _mk_specs(drop_mod=3):
    return [
        OpSpec("double", "stateless", lambda v: [v * 2]),
        OpSpec(
            "filt", "stateless",
            lambda v, m=drop_mod: [v] if (m == 0 or v % m) else [],
        ),
        OpSpec(
            "count", "stateful",
            lambda s, v: (s + 1, [(v, s + 1)]), init_state=lambda: 0,
        ),
    ]


def _oracle(vals, drop_mod=3):
    out, c = [], 0
    for v in vals:
        d = v * 2
        if drop_mod == 0 or d % drop_mod:
            c += 1
            out.append((d, c))
    return out


def _shm_segments():
    try:
        return {f for f in os.listdir("/dev/shm") if f.startswith("repro_")}
    except FileNotFoundError:  # non-Linux: nothing to check
        return set()


# ------------------------------------------------------------ ordered output
@pytest.mark.timeout(60)
@settings(max_examples=8, deadline=None)
@given(
    vals=st.lists(st.integers(min_value=0, max_value=1000), min_size=1, max_size=400),
    drop_mod=st.sampled_from([0, 2, 3, 7]),
    workers=st.sampled_from([1, 2, 4]),
    io_batch=st.sampled_from([1, 4, 32]),
)
def test_property_process_exact_order_no_loss(vals, drop_mod, workers, io_batch):
    """Random selectivity / batch sizes / worker counts: the process backend's
    egress equals the sequential reference exactly (order + zero loss)."""
    pipe, report = run_pipeline(
        _mk_specs(drop_mod),
        vals,
        num_workers=workers,
        backend="process",
        collect_outputs=True,
        io_batch=io_batch,
    )
    expected = _oracle(vals, drop_mod)
    assert pipe.outputs == expected
    assert report.tuples_in == len(vals)
    assert report.tuples_out == len(expected)


@pytest.mark.timeout(60)
def test_process_stateless_only_chain():
    src = list(range(1, 800))
    pipe, report = run_pipeline(
        _mk_specs()[:2], src, num_workers=3, backend="process",
        collect_outputs=True,
    )
    assert pipe.outputs == [v * 2 for v in src if (v * 2) % 3]
    assert report.egress_throughput > 0


@pytest.mark.timeout(60)
def test_process_keyed_routing_preserves_per_key_state():
    specs = [
        OpSpec(
            "ksum", "partitioned",
            lambda s, k, v: (s + v, [(k, s + v)]),
            key_fn=lambda v: v % 7, num_partitions=14, init_state=lambda: 0,
        ),
        OpSpec("id", "stateless", lambda v: [v]),
    ]
    src = list(range(1, 600))
    states, expected = {}, []
    for v in src:
        k = v % 7
        states[k] = states.get(k, 0) + v
        expected.append((k, states[k]))
    pipe, _ = run_pipeline(
        specs, src, num_workers=3, backend="process", collect_outputs=True
    )
    assert pipe.outputs == expected


@pytest.mark.timeout(60)
def test_process_markers_and_latency():
    src = list(range(1, 2000))
    pipe, report = run_pipeline(
        _mk_specs(), src, num_workers=2, backend="process", marker_interval=16
    )
    assert report.mean_latency > 0
    assert len(pipe.markers) > 0


@pytest.mark.timeout(60)
def test_process_backend_on_dag_graph():
    """run_graph(backend='process'): stateless prefix parallel, split/merge
    tail executed in the parent — egress equals the linear reference."""
    from repro.core import Merge, Split

    nodes = {
        "pre": OpSpec("pre", "stateless", lambda v: [v + 1]),
        "split": Split("round_robin"),
        "a": OpSpec("a", "stateless", lambda v: [v * 2]),
        "b": OpSpec("b", "stateless", lambda v: [v * 2]),
        "merge": Merge(),
        "tot": OpSpec(
            "tot", "stateful", lambda s, v: (s + v, [s + v]), init_state=lambda: 0
        ),
    }
    edges = [
        ("pre", "split"), ("split", "a"), ("split", "b"),
        ("a", "merge"), ("b", "merge"), ("merge", "tot"),
    ]
    src = list(range(50))
    expected, s = [], 0
    for v in src:
        s += (v + 1) * 2
        expected.append(s)
    pipe, _ = run_graph(
        nodes, edges, src, num_workers=2, backend="process", collect_outputs=True
    )
    assert pipe.outputs == expected


# --------------------------------------------------------------- spill path
@pytest.mark.timeout(60)
def test_process_oversized_payloads_take_spill_path():
    """Bundles larger than a reorder slot travel via the pipe side channel
    with a spill tag in the ring — order must survive."""
    src = [("x" * 3000, i) for i in range(200)]  # ~3 KB payloads
    specs = [
        OpSpec("stamp", "stateless", lambda t: [(t[0], t[1], len(t[0]))]),
        OpSpec("keep", "stateless", lambda t: [t] if t[1] % 2 else []),
    ]
    pipe, _ = run_pipeline(
        specs, src, num_workers=2, backend="process", collect_outputs=True,
        io_batch=8, reorder_payload=1024,
    )
    assert pipe.outputs == [
        ("x" * 3000, i, 3000) for _, i in src if i % 2
    ]


# ---------------------------------------------------------- crash / restart
@pytest.mark.timeout(60)
def test_process_worker_crash_restart_exact_output():
    """SIGKILL one worker mid-run: the runtime re-forks it, replays its
    in-flight serials, and the egress still equals the reference exactly."""
    def slowish(v):
        x = 0
        for _ in range(200):
            x += 1
        return [v * 3] if v % 5 else []

    specs = [OpSpec("slow", "stateless", slowish)]
    src = list(range(1, 12000))
    rt = ProcessRuntime.from_chain(
        specs, num_workers=2, collect_outputs=True, io_batch=4
    )

    orig_setup = rt._setup
    killed = {"done": False}

    def chaos_setup():
        orig_setup()
        pid = rt._procs[0].pid  # capture now; stop() clears the list later

        # kill worker 0 shortly after the pipeline starts moving
        import threading

        def killer():
            time.sleep(0.02)
            try:
                os.kill(pid, signal.SIGKILL)
                killed["done"] = True
            except ProcessLookupError:
                pass

        threading.Thread(target=killer, daemon=True).start()

    rt._setup = chaos_setup
    report = rt.run(src)
    assert killed["done"], "chaos killer never fired"
    assert rt.restarts >= 1, "crash was not detected/recovered"
    assert rt.outputs == [v * 3 for v in src if v % 5]
    assert report.tuples_in == len(src)
    assert report.tuples_out == len(rt.outputs)


@pytest.mark.timeout(60)
def test_process_worker_exception_propagates():
    def boom(v):
        if v == 37:
            raise ValueError("kaboom")
        return [v]

    with pytest.raises(RuntimeError, match="kaboom"):
        run_pipeline(
            [OpSpec("boom", "stateless", boom)],
            list(range(100)),
            num_workers=2,
            backend="process",
            io_batch=1,
        )


# ----------------------------------------------------------- crash soak
def _soak_hot(v):
    x = float(v)
    for _ in range(300):
        x = (x * 1.0000001 + 1.31) % 97.0
    return [int(x * 1000)]


def _soak_mod(v):
    return v % 9


def _soak_ksum(s, k, v):
    s = (s or 0) + v
    return s, [(k, s % 99991)]


def _soak_zero():
    return 0


@pytest.mark.timeout(120)
def test_crash_soak_ten_kills_including_during_elastic_replan():
    """Soak: SIGKILL a random stage-0 (stateless, recoverable) worker 10
    times over one run while elastic replans churn (deliberately wrong
    priors force a resize mid-run, so kills land in every replan phase).
    Egress must equal the sequential reference exactly and no shared-memory
    segment may leak."""
    import random
    import threading

    from repro.core import ProcessRuntime

    specs = [
        OpSpec("hot", "stateless", _soak_hot, cost_us=1),  # lie: ~25 µs
        OpSpec(
            "cold", "partitioned", _soak_ksum, key_fn=_soak_mod,
            num_partitions=18, init_state=_soak_zero, cost_us=60,  # lie: ~2
        ),
    ]
    src = list(range(1, 30001))
    states, expected = {}, []
    for v in src:
        x = float(v)
        for _ in range(300):
            x = (x * 1.0000001 + 1.31) % 97.0
        out = int(x * 1000)
        k = out % 9
        states[k] = states.get(k, 0) + out
        expected.append((k, states[k] % 99991))

    before = _shm_segments()
    rt = ProcessRuntime.from_chain(
        specs, num_workers="auto", worker_budget=3, collect_outputs=True,
        cost_priors={"hot": 1.0, "cold": 60.0},
        replan_interval=0.05, replan_patience=2, batch_size=32,
    )
    kills = {"done": 0}
    stop_killer = threading.Event()

    def killer():
        rng = random.Random(0xC0FFEE)
        while kills["done"] < 10 and not stop_killer.is_set():
            time.sleep(0.05)
            victims = rt.worker_groups()[0] if rt._procs else []
            victims = [p for p in victims if p.is_alive()]
            if not victims:
                continue
            try:
                os.kill(rng.choice(victims).pid, signal.SIGKILL)
                kills["done"] += 1
            except (ProcessLookupError, AttributeError):
                continue

    th = threading.Thread(target=killer, daemon=True)
    orig_setup = rt._setup

    def chaos_setup():
        orig_setup()
        th.start()

    rt._setup = chaos_setup
    try:
        report = rt.run(src)
    finally:
        stop_killer.set()
        th.join(timeout=5)
    assert kills["done"] >= 10, f"soak only landed {kills['done']} kills"
    assert rt.restarts >= 1, "no crash recovery happened"
    assert rt.outputs == expected
    assert report.tuples_in == len(src)
    assert _shm_segments() == before


def _slow_ksum(s, k, v):
    x = 0
    for _ in range(200):
        x += 1
    s = (s or 0) + v
    return s, [(k, s)]


def _slow_count(s, v):
    x = 0
    for _ in range(200):
        x += 1
    return s + 1, [(v, s + 1)]


def _stateful_stage_op(kind):
    if kind == "keyed":
        return OpSpec(
            "ks", "partitioned", _slow_ksum, key_fn=lambda v: v % 7,
            num_partitions=14, init_state=lambda: 0,
        )
    return OpSpec("ct", "stateful", _slow_count, init_state=lambda: 0)


def _chaos_kill_first_worker(rt, stage=1, after=0.05):
    """Wrap ``rt._setup`` so the first worker of ``stage`` is SIGKILLed
    shortly after the pipeline comes up."""
    orig_setup = rt._setup

    def chaos_setup():
        orig_setup()
        victim = rt.worker_groups()[stage][0].pid
        import threading

        def killer():
            time.sleep(after)
            try:
                os.kill(victim, signal.SIGKILL)
            except ProcessLookupError:
                pass

        threading.Thread(target=killer, daemon=True).start()

    rt._setup = chaos_setup


def _stateful_reference(kind, n):
    if kind == "keyed":
        states, out = {}, []
        for v in range(1, n):
            k = v % 7
            states[k] = states.get(k, 0) + v
            out.append((k, states[k]))
        return out
    return [(v, v) for v in range(1, n)]


@pytest.mark.timeout(60)
@pytest.mark.parametrize("kind", ["keyed", "stateful"])
def test_kill_in_stateful_stage_recovers_by_default(kind):
    """A SIGKILL in a keyed/stateful stage is survivable by default now
    that epoch checkpointing is on: the supervisor restores the last
    committed snapshot, replays, and egress equals the reference exactly
    — with every shm segment still unlinked at the end."""
    n = 60000
    specs = [OpSpec("id", "stateless", lambda v: [v]), _stateful_stage_op(kind)]
    before = _shm_segments()
    rt = ProcessRuntime.from_chain(specs, num_workers=2, collect_outputs=True)
    _chaos_kill_first_worker(rt)
    report = rt.run(range(1, n))
    assert rt.outputs == _stateful_reference(kind, n)
    assert report.tuples_out == n - 1
    assert rt.restarts >= 1 and rt.recoveries >= 1
    assert _shm_segments() == before


@pytest.mark.timeout(60)
@pytest.mark.parametrize("kind", ["keyed", "stateful"])
def test_kill_in_stateful_stage_raises_cleanly_when_ckpt_off(kind):
    """With checkpointing explicitly disabled, a SIGKILL in a keyed or
    stateful stage is unrecoverable (worker-local state is gone): the
    runtime must raise a clear error — not hang, not silently drop
    tuples — and still unlink every shm segment."""
    specs = [OpSpec("id", "stateless", lambda v: [v]), _stateful_stage_op(kind)]
    before = _shm_segments()
    rt = ProcessRuntime.from_chain(
        specs, num_workers=2, collect_outputs=True, checkpoint_interval=0,
    )
    _chaos_kill_first_worker(rt)
    with pytest.raises(RuntimeError, match="worker-local state|died"):
        rt.run(range(1, 60000))
    assert _shm_segments() == before


# ------------------------------------------------------------- shm hygiene
@pytest.mark.timeout(60)
def test_no_shared_memory_leaks_across_repeated_runs():
    """20 consecutive runs must not leave a single repro_* segment behind."""
    before = _shm_segments()
    specs = [OpSpec("id", "stateless", lambda v: [v])]
    for i in range(20):
        pipe, _ = run_pipeline(
            specs, list(range(50)), num_workers=2, backend="process",
            collect_outputs=True,
        )
        assert pipe.outputs == list(range(50))
    assert _shm_segments() == before


@pytest.mark.timeout(60)
def test_stop_is_idempotent():
    rt = ProcessRuntime.from_chain(
        [OpSpec("id", "stateless", lambda v: [v])], num_workers=1
    )
    rt.run(range(10))
    rt.stop()  # second stop after run's own stop: no-op, no raise
    rt.stop()


# ------------------------------------------------------------ ring unit tests
def test_spsc_ring_roundtrip_and_spanning_records():
    ring = ShmSpscRing(f"repro_test_{os.getpid()}_a", slots=8, slot_bytes=64)
    try:
        assert ring.get() is None
        assert ring.put(1, 2, b"abc")
        big = bytes(range(256)) * 1  # spans multiple 64-byte slots
        assert ring.put(2, 5, big)
        assert ring.get() == (1, 2, b"abc")
        assert ring.get() == (2, 5, big)
        assert ring.get() is None
        # fill until full -> put returns False, then drain frees space
        n = 0
        while ring.put(10 + n, 0, b"x" * 40):
            n += 1
        assert n > 0 and not ring.put(99, 0, b"x" * 40)
        assert ring.get() is not None
        assert ring.put(99, 0, b"x" * 40)
    finally:
        ring.close()
        ring.unlink()


def test_reorder_ring_orders_and_rejects():
    got = []
    ring = ShmReorderRing(f"repro_test_{os.getpid()}_b", size=4, payload_bytes=32)
    try:
        OK, FULL, STALE = (
            ShmReorderRing.PUBLISHED, ShmReorderRing.FULL, ShmReorderRing.STALE
        )
        assert ring.try_publish(2, 0, b"b") == OK
        assert ring.poll() is None  # serial 1 missing: window blocked
        assert ring.try_publish(5, 0, b"x") == FULL  # beyond next+size
        assert ring.try_publish(1, 0, b"a") == OK
        for expect in (1, 2):
            t, tag, data, span = ring.poll()
            got.append(t)
            assert span == 1
        assert got == [1, 2]
        assert ring.try_publish(1, 0, b"dup") == STALE  # replay of drained
        assert ring.try_publish(5, 0, b"x") == OK  # window advanced
    finally:
        ring.close()
        ring.unlink()


def test_reorder_ring_span_publish_covers_contiguous_run():
    """A span slot carries a whole contiguous micro-batch: the drain jumps
    ``next`` past the covered serials and the next span lines up."""
    ring = ShmReorderRing(f"repro_test_{os.getpid()}_c", size=8, payload_bytes=32)
    try:
        assert ring.try_publish(1, 0, b"abc", span=3) == ShmReorderRing.PUBLISHED
        assert ring.try_publish(4, 0, b"de", span=2) == ShmReorderRing.PUBLISHED
        t, tag, data, span = ring.poll()
        assert (t, data, span) == (1, b"abc", 3)
        t, tag, data, span = ring.poll()
        assert (t, data, span) == (4, b"de", 2)
        assert ring.poll() is None
        assert ring.next_serial == 6
        # serials inside a drained span are stale for any late replay
        assert ring.try_publish(2, 0, b"x") == ShmReorderRing.STALE
    finally:
        ring.close()
        ring.unlink()


def test_spsc_peek_advance_and_consumer_resync():
    """peek leaves the record uncommitted (crash-replay basis); sync_consumer
    realigns a fresh consumer mirror with the shared head cursor."""
    ring = ShmSpscRing(f"repro_test_{os.getpid()}_d", slots=8, slot_bytes=64)
    try:
        assert ring.put(7, 1, b"abc")
        serial, tag, data, nslots = ring.peek()
        assert (serial, tag, data) == (7, 1, b"abc")
        # not committed: a re-peek (crash replacement) sees the same record
        assert ring.peek()[:3] == (7, 1, b"abc")
        ring.advance(nslots)
        assert ring.peek() is None
        # a stale mirror (fresh fork) resyncs to the committed shared head
        ring._head = 0
        ring.sync_consumer()
        assert ring.peek() is None
    finally:
        ring.close()
        ring.unlink()


# ------------------------------------------------------------- staged stages
@pytest.mark.timeout(60)
def test_interior_stateful_op_runs_as_own_process_stage():
    """A chain with an interior stateful operator must cut into >= 2 process
    stages, each with its own live worker group (the tentpole claim: interior
    operators leave the parent)."""
    specs = _mk_specs()  # SL -> SL -> SF
    rt = ProcessRuntime.from_chain(specs, num_workers=2, collect_outputs=True)
    assert rt.num_stages == 2
    assert [p.kind for p in rt.stage_plans] == ["stateless", "stateful"]

    groups = {}
    orig_setup = rt._setup

    def spy_setup():
        orig_setup()
        groups["pids"] = [
            sorted(p.pid for p in g) for g in rt.worker_groups()
        ]

    rt._setup = spy_setup
    src = list(range(1, 500))
    rt.run(src)
    assert len(groups["pids"]) == 2  # two distinct worker groups ran
    assert all(groups["pids"]), "every stage must own live worker processes"
    assert set(groups["pids"][0]).isdisjoint(groups["pids"][1])
    assert rt.outputs == _oracle(src)


@pytest.mark.timeout(60)
def test_interior_keyed_stage_parallel_workers_exact_state():
    """SL -> PS -> SL: the partitioned op runs as its own keyed stage across
    several workers; per-key state and global order must both survive."""
    specs = [
        OpSpec("inc", "stateless", lambda v: [v + 1]),
        OpSpec(
            "ksum", "partitioned",
            lambda s, k, v: (s + v, [(k, s + v)]),
            key_fn=lambda v: v % 5, num_partitions=10, init_state=lambda: 0,
        ),
        OpSpec("fmt", "stateless", lambda t: [t]),
    ]
    src = list(range(1, 700))
    states, expected = {}, []
    for v in src:
        v1 = v + 1
        k = v1 % 5
        states[k] = states.get(k, 0) + v1
        expected.append((k, states[k]))
    rt = ProcessRuntime.from_chain(
        specs, num_workers=3, collect_outputs=True, io_batch=8
    )
    assert rt.num_stages == 2
    assert rt.stage_plans[1].kind == "keyed"
    assert rt.stage_plans[1].workers == 3
    rt.run(src)
    assert rt.outputs == expected


@pytest.mark.timeout(60)
def test_keyed_stage_composes_with_io_batch():
    """The PR-2 gap: keyed routing used to force io_batch=1.  Per-worker
    batches now carry per-tuple serials, so any batch size must reproduce
    the exact cross-worker interleave order."""
    specs = [
        OpSpec(
            "ksum", "partitioned",
            lambda s, k, v: (s + v, [(k, s + v)]),
            key_fn=lambda v: v % 7, num_partitions=14, init_state=lambda: 0,
        ),
    ]
    src = list(range(1, 600))
    states, expected = {}, []
    for v in src:
        k = v % 7
        states[k] = states.get(k, 0) + v
        expected.append((k, states[k]))
    for io_batch in (1, 7, 32):
        pipe, _ = run_pipeline(
            specs, src, num_workers=3, backend="process",
            collect_outputs=True, io_batch=io_batch,
        )
        assert pipe.outputs == expected, f"io_batch={io_batch}"


@pytest.mark.timeout(60)
def test_stages_1_restores_ingress_only_plan():
    """stages=1 is the PR-2 compatibility mode: one parallel ingress segment,
    the rest of the graph executed in the parent tail."""
    specs = _mk_specs()
    rt = ProcessRuntime.from_chain(specs, num_workers=2, stages=1,
                                   collect_outputs=True)
    assert rt.num_stages == 1
    assert rt._tail is not None  # the SF op stays in the parent
    src = list(range(1, 400))
    rt.run(src)
    assert rt.outputs == _oracle(src)
