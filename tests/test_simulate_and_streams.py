"""Simulator sanity (qualitative paper claims) + TPCx-BB query correctness."""
import pytest

from repro.core.simulate import SimConfig, SimOp, simulate
from repro.core import run_pipeline
from repro.streams.tpcxbb import QUERIES, sim_ops


# ------------------------------------------------------------------ simulator
def test_sim_perfect_scaling_stateless():
    ops = [SimOp("op", "stateless", cost_us=100.0)]
    r1 = simulate(ops, 2000, SimConfig(num_workers=1, heuristic="lp"))
    r8 = simulate(
        [SimOp("op", "stateless", cost_us=100.0)], 2000,
        SimConfig(num_workers=8, heuristic="lp"),
    )
    assert r1["makespan_us"] / r8["makespan_us"] > 7.0


def test_sim_stateful_caps_at_one_worker():
    ops = [SimOp("sf", "stateful", cost_us=50.0)]
    r1 = simulate(ops, 1000, SimConfig(num_workers=1))
    r8 = simulate([SimOp("sf", "stateful", cost_us=50.0)], 1000, SimConfig(num_workers=8))
    assert r1["makespan_us"] / r8["makespan_us"] < 1.2  # no speedup possible


def test_sim_nonblocking_beats_lockbased_under_contention():
    def go(scheme):
        return simulate(
            [SimOp("light", "stateless", cost_us=10.0)],
            20_000,
            SimConfig(num_workers=16, reorder_scheme=scheme, heuristic="lp"),
        )

    nb, lb = go("non_blocking"), go("lock_based")
    assert nb["makespan_us"] < lb["makespan_us"]
    assert lb["blocked_us"] > 10 * nb["blocked_us"]


def test_sim_hybrid_beats_partitioned_under_skew():
    import random

    def gaussian_key_sampler(sigma, key_space):
        def sample(rng: random.Random) -> int:
            v = ((rng.gauss(0.0, sigma) + 1.0) % 2.0) - 1.0
            return int((v + 1.0) / 2.0 * (key_space - 1))

        return sample

    def go(scheme, parts):
        return simulate(
            [SimOp("ps", "partitioned", cost_us=100.0, num_partitions=parts)],
            10_000,
            SimConfig(num_workers=8, worklist_scheme=scheme, heuristic="lp"),
            key_sampler=gaussian_key_sampler(0.2, key_space=parts),
        )

    hy = go("hybrid", 100)
    pq = go("partitioned", 8)
    assert hy["makespan_us"] * 1.5 < pq["makespan_us"]


def test_sim_conservation():
    """Tuples in == tuples out x selectivity along the chain."""
    ops = [
        SimOp("a", "stateless", cost_us=5.0, selectivity=2.0),
        SimOp("b", "partitioned", cost_us=5.0, num_partitions=16, selectivity=1.0),
        SimOp("c", "stateless", cost_us=5.0, selectivity=0.5),
    ]
    r = simulate(ops, 1000, SimConfig(num_workers=4))
    assert r["egress"] == 1000 * 2 * 1 * 0.5


# ------------------------------------------------------------------ tpcxbb
@pytest.mark.parametrize("qname", list(QUERIES))
def test_tpcxbb_queries_run_ordered(qname):
    n = 6000
    specs, source = QUERIES[qname](n=n)
    pipe, report = run_pipeline(
        specs, list(source), num_workers=3, heuristic="ct", collect_outputs=True
    )
    # sequential oracle comparison
    from test_core_pipeline import _sequential_reference

    specs2, source2 = QUERIES[qname](n=n)
    expected = _sequential_reference(specs2, list(source2))
    assert pipe.outputs == expected, f"{qname}: concurrent != sequential"
    assert pipe.egress_count > 0, f"{qname}: query produced no output"


@pytest.mark.parametrize("qname", list(QUERIES))
def test_tpcxbb_sim_profiles(qname):
    ops = sim_ops(qname)
    assert len(ops) >= 3
    r = simulate(ops, 2000, SimConfig(num_workers=4, heuristic="ct"),
                 key_sampler=lambda rng: rng.randrange(1 << 30))
    assert r["throughput_per_s"] > 0
    assert r["egress"] >= 0


# ------------------------------------------------------------------ scheduler
def test_ct_beats_qst_on_long_pipeline():
    """The paper's headline scheduling claim, in simulation."""
    def go(h):
        return simulate(
            sim_ops("q2"), 10_000, SimConfig(num_workers=8, heuristic=h),
            key_sampler=lambda rng: rng.randrange(1 << 30),
        )

    ct, qst = go("ct"), go("qst")
    assert ct["throughput_per_s"] >= qst["throughput_per_s"] * 0.95
