"""Unit + property tests for the reordering schemes (paper §3, Theorem 3.1)."""
import random
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.reorder import (
    LockBasedReorderBuffer,
    NonBlockingReorderBuffer,
    make_reorder_buffer,
)


@pytest.mark.parametrize("scheme", ["non_blocking", "lock_based"])
def test_in_order_single_thread(scheme):
    out = []
    buf = make_reorder_buffer(scheme, out.append, size=8)
    for t in range(1, 20):
        assert buf.send(t, t)
    assert out == list(range(1, 20))


def test_out_of_order_single_thread():
    out = []
    buf = NonBlockingReorderBuffer(out.append, size=16)
    order = list(range(1, 17))
    random.Random(0).shuffle(order)
    for t in order:
        buf.send(t, t)
    assert out == list(range(1, 17))


def test_entry_condition_rejects_far_future():
    out = []
    buf = NonBlockingReorderBuffer(out.append, size=4)
    assert not buf.send(5, 5)  # next=1, window [1,5) excludes 5
    assert buf.rejected_adds == 1
    assert buf.send(1, 1)
    assert out == [1]
    assert buf.send(5, 5)  # window now [2,6)
    assert out == [1]  # 5 buffered, waiting on 2..4


def test_ring_wraparound():
    out = []
    buf = NonBlockingReorderBuffer(out.append, size=4)
    for t in range(1, 101):
        assert buf.send(t, t * 10)
    assert out == [t * 10 for t in range(1, 101)]


@pytest.mark.parametrize("scheme", ["non_blocking", "lock_based"])
@pytest.mark.parametrize("n_threads", [2, 4, 8])
def test_concurrent_ordering(scheme, n_threads):
    """Theorem 3.1: outputs sent downstream in serial order under concurrency.

    Workers model the paper's execution: each dequeues the next input from a
    shared FIFO worklist, "processes" it, and retries send until accepted.
    (The smallest in-flight serial is always held by some worker, which is why
    the bounded ring cannot deadlock — the paper's §3 progress argument.)
    """
    import collections

    n = 600
    out = []
    buf = make_reorder_buffer(scheme, out.append, size=16)
    worklist = collections.deque(range(1, n + 1))

    def worker(wid):
        rng = random.Random(wid)
        while True:
            try:
                t = worklist.popleft()
            except IndexError:
                return
            if rng.random() < 0.2:
                threading.Event().wait(rng.random() * 1e-4)  # processing skew
            buf.send_blocking(t, t)

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert out == list(range(1, n + 1))


@settings(max_examples=50, deadline=None)
@given(
    perm=st.permutations(list(range(1, 33))),
    size=st.sampled_from([1, 2, 4, 7, 32, 64]),
)
def test_property_any_permutation_any_ring(perm, size):
    """Property: for any completion permutation and ring size, egress is ordered
    and exactly-once (sequential adversarial schedule)."""
    out = []
    buf = NonBlockingReorderBuffer(out.append, size=size)
    pending = list(perm)
    while pending:
        nxt = []
        for t in pending:
            if not buf.send(t, t):
                nxt.append(t)  # ring full for t; retry in a later round
        assert len(nxt) < len(pending), "no progress — liveness violated"
        pending = nxt
    assert out == sorted(perm)


def test_nonblocking_adders_do_not_wait():
    """The non-blocking property: while one worker drains a long prefix, another
    worker's add must complete without taking the drain path's flag."""
    out = []
    gate = threading.Event()
    slow_sent = []

    def slow_downstream(v):
        slow_sent.append(v)
        gate.wait(0.2)  # drainer is slow

    buf = NonBlockingReorderBuffer(slow_downstream, size=64)
    for t in range(2, 10):
        buf.send(t, t)  # buffered, next=1 missing

    t_done = threading.Event()

    def drainer():
        buf.send(1, 1)  # triggers drain of 1..9, slow
        t_done.set()

    th = threading.Thread(target=drainer)
    th.start()
    while not slow_sent:  # wait until drain started
        threading.Event().wait(1e-4)
    # adder: must return promptly even though drain is in progress
    import time

    t0 = time.perf_counter()
    assert buf.send(10, 10)
    add_latency = time.perf_counter() - t0
    gate.set()
    th.join()
    assert add_latency < 0.1, f"adder blocked for {add_latency}s"
    assert out == []  # all sends went to slow_downstream
    assert slow_sent == list(range(1, 11))
