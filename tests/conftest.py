"""Suite-wide fixtures: per-test watchdog + offline-environment shims.

Threaded runtime tests can hang indefinitely when a drain bug deadlocks the
pipeline; a SIGALRM watchdog turns such hangs into loud TimeoutErrors so CI
surfaces them as failures instead of stalling.  Override the limit per test
with ``@pytest.mark.timeout(seconds)`` or globally via ``REPRO_TEST_TIMEOUT``.

Tier-1 command (see ROADMAP.md):  PYTHONPATH=src python -m pytest -x -q
"""
from __future__ import annotations

import os
import signal
import sys

import pytest

# Make tests/ importable (for _hypothesis_compat) regardless of rootdir.
sys.path.insert(0, os.path.dirname(__file__))

DEFAULT_TIMEOUT = int(os.environ.get("REPRO_TEST_TIMEOUT", "120"))
# The coverage gate (scripts/coverage_gate.py) line-traces the core package,
# slowing its hot paths; it sets this scale so per-test limits stretch
# proportionally instead of turning tracer overhead into fake hangs.
TIMEOUT_SCALE = float(os.environ.get("REPRO_TIMEOUT_SCALE", "1"))


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    limit = int(marker.args[0]) if marker and marker.args else DEFAULT_TIMEOUT
    limit = int(limit * TIMEOUT_SCALE)
    if limit <= 0 or not hasattr(signal, "SIGALRM"):
        yield
        return

    def on_alarm(signum, frame):
        raise TimeoutError(
            f"watchdog: {item.nodeid} exceeded {limit}s (likely drain hang)"
        )

    old = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(limit)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test watchdog limit override"
    )
