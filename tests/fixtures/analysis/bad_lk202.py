"""Fixture: blocking operation while holding a lock -> LK202."""
import threading
import time


class SleepyCritical:
    def __init__(self):
        self._lock = threading.Lock()

    def throttle(self):
        with self._lock:
            time.sleep(0.01)
