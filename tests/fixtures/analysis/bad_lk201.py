"""Fixture: two code paths acquire the same locks in opposite orders -> LK201."""
import threading


class DeadlockProne:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def forward(self):
        with self._a:
            with self._b:
                pass

    def backward(self):
        with self._b:
            with self._a:
                pass
