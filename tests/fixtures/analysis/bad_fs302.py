"""Fixture: shared-memory creation with no unlink discipline -> FS302."""
from repro.core.shm import ShmSpscRing


class RingLeaker:
    def __init__(self, nbytes):
        self.ring = ShmSpscRing(nbytes)

    def close(self):
        self.ring.close()  # closes the mapping but never unlinks the segment
