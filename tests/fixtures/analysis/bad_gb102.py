"""Fixture: read of a guarded-by(rw) attribute outside its lock -> GB102."""
import threading


class TornReader:
    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0  # guarded-by(rw): self._lock

    def add(self, n):
        with self._lock:
            self.total += n

    def peek(self):
        return self.total  # unlocked read of an rw-guarded attribute
