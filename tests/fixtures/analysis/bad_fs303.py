"""Fixture: lock acquisition inside a signal handler -> FS303."""
import signal
from threading import Lock

_state_lock = Lock()
_shutdown = False


def _on_term(signum, frame):
    global _shutdown
    with _state_lock:  # the interrupted thread may already hold this
        _shutdown = True


def _on_int(signum, frame):
    _state_lock.acquire()  # same deadlock, spelled explicitly
    try:
        pass
    finally:
        _state_lock.release()


signal.signal(signal.SIGTERM, _on_term)
signal.signal(signal.SIGINT, _on_int)
