"""Fixture: guarded-by comment not attached to a self.attr line -> GB104."""

THRESHOLD = 16  # guarded-by: self._lock
