"""Fixture: call to a '# holds:' function without holding its lock -> LK203."""
import threading


class ContractBreaker:
    def __init__(self):
        self._lock = threading.Lock()

    def _advance(self):  # holds: self._lock
        pass

    def run(self):
        self._advance()  # caller never took the lock
