"""Fixture: threading primitive created in a forking module -> FS301."""
import multiprocessing as mp
import threading

_state_lock = threading.Lock()


def spawn(fn):
    p = mp.Process(target=fn)
    p.start()
    return p
