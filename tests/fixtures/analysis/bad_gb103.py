"""Fixture: guarded-by names a lock never acquired in the class -> GB103."""
import threading


class TypoGuard:
    def __init__(self):
        self._lock = threading.Lock()
        self.items: list = []  # guarded-by: self._locck

    def noop(self):
        with self._lock:  # the real lock; the annotation's typo never matches
            pass
