"""Fixture: justified suppression that matches no finding -> AN002."""
import threading


class StaleIgnore:
    def __init__(self):
        self._lock = threading.Lock()

    def tidy(self):
        with self._lock:
            # analysis: ignore[LK202]: nothing here blocks any more; stale
            return 1
