"""Fixture: write to a guarded-by attribute outside its lock -> GB101."""
import threading


class LeakyCounter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0  # guarded-by: self._lock

    def safe_bump(self):
        with self._lock:
            self.count += 1

    def racy_bump(self):
        self.count += 1  # outside the lock: the violation
