"""Fixture: a class that follows every checked discipline — zero findings."""
import threading
import time


class WellBehaved:
    """Guarded writes under the lock, an honored holds contract, a justified
    lock-free declaration, and no blocking calls under the lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self.pending: list = []  # guarded-by: self._lock
        self.total = 0  # guarded-by(rw): self._lock
        # lock-free: single-writer instrumentation; torn reads are acceptable
        self.last_seen = 0.0

    def push(self, item):
        with self._lock:
            self.pending.append(item)
            self._bump(1)
        self.last_seen = time.perf_counter()

    def _bump(self, n):  # holds: self._lock
        self.total += n

    def drain(self):
        with self._lock:
            out, self.pending = list(self.pending), []
            return out, self.total
