"""Fixture: suppression without a justification -> AN001 (and only AN001 —
the underlying GB101 is suppressed, but the bare ignore is itself flagged)."""
import threading


class Unjustified:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0  # guarded-by: self._lock

    def bump(self):
        # analysis: ignore[GB101]
        self.n += 1

    def locked_bump(self):
        with self._lock:
            self.n += 1
