"""Per-architecture smoke tests (deliverable f): reduced configs of each
family run one forward/train step + prefill/decode consistency on CPU."""
import dataclasses
import functools

import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="model smoke tests need jax (models are jax-native)"
)
import jax.numpy as jnp

from repro.configs import ARCH_IDS, applicable_shapes, get_config, smoke_config
from repro.models.common import count_params, init_params
from repro.models.transformer import (
    decode_step,
    forward_train,
    generate,
    loss_fn,
    prefill,
)

@functools.lru_cache(maxsize=None)
def KEY():
    # Lazy: creating a PRNGKey initializes the jax CPU client, and doing
    # that at import (= pytest collection) time poisons every forked
    # process-backend jax device worker that runs later in the same
    # session — forked children inherit dead XLA threadpool locks and
    # deadlock (see docs/columnar.md, fork safety).
    return jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=24):
    toks = jax.random.randint(KEY(), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.num_encoder_tokens:
        batch["encoder_states"] = jax.random.normal(
            KEY(), (B, cfg.num_encoder_tokens, cfg.d_model), cfg.dtype
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, KEY())
    batch = _batch(cfg)
    logits, aux = forward_train(cfg, params, batch["tokens"], batch.get("encoder_states"))
    assert logits.shape == (2, 24, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
    loss, metrics = loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g).astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    cfg = dataclasses.replace(smoke_config(arch), capacity_factor=64.0)
    params = init_params(cfg, KEY())
    B, S = 2, 24
    batch = _batch(cfg, B, S)
    toks = batch["tokens"]
    enc = batch.get("encoder_states")
    full_logits, _ = forward_train(cfg, params, toks, enc)
    lg_p, cache = prefill(cfg, params, toks[:, : S - 1], enc, max_len=S + 4)
    lg_d, _ = decode_step(
        cfg, params, toks[:, S - 1], cache, jnp.full((B,), S - 1, jnp.int32)
    )
    scale = float(jnp.abs(full_logits[:, S - 1]).max())
    err_p = float(jnp.abs(lg_p - full_logits[:, S - 2]).max())
    err_d = float(jnp.abs(lg_d - full_logits[:, S - 1]).max())
    # mamba-family decode uses a different (recurrent) numeric path in bf16
    tol = 0.15 * max(scale, 1.0) if cfg.has("mamba") else 3e-2 * max(scale, 1.0)
    assert err_p <= tol, f"prefill mismatch {err_p} (scale {scale})"
    assert err_d <= tol, f"decode mismatch {err_d} (scale {scale})"


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-780m", "qwen2-moe-a2.7b"])
def test_smoke_generate(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, KEY())
    prompt = jax.random.randint(KEY(), (2, 8), 0, cfg.vocab_size)
    out = generate(cfg, params, prompt, num_steps=4)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.padded_vocab)))


def test_full_configs_param_counts():
    """Exact assigned configs must hit their published sizes (sanity that the
    configs are the assignment, not approximations)."""
    expect = {
        "llama-3.2-vision-90b": (80, 95),
        "jamba-1.5-large-398b": (380, 410),
        "phi3.5-moe-42b-a6.6b": (40, 44),
        "qwen2-moe-a2.7b": (13, 16),
        "starcoder2-15b": (14, 17),
        "glm4-9b": (8.5, 10),
        "chatglm3-6b": (5.5, 7),
        "musicgen-large": (2.0, 2.8),
        "olmo-1b": (1.0, 1.5),
        "mamba2-780m": (0.7, 1.0),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_applicable_shapes_rules():
    assert "long_500k" in applicable_shapes(get_config("mamba2-780m"))
    assert "long_500k" in applicable_shapes(get_config("jamba-1.5-large-398b"))
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if cfg.family not in ("ssm", "hybrid"):
            assert "long_500k" not in applicable_shapes(cfg)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(applicable_shapes(cfg))
