"""Minimal stand-in for ``hypothesis`` when it is not installed.

The offline test environment has no ``hypothesis`` wheel, so the property
tests degrade to seeded randomized sampling: ``@given`` draws
``max_examples`` pseudo-random examples from the declared strategies (plus a
deterministic "minimal" first example) and runs the test once per draw.  No
shrinking, no database — just deterministic coverage so the properties still
execute as tests instead of erroring at import.

Usage (mirrors the real API surface the suite needs)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

import random
from types import SimpleNamespace

_DEFAULT_MAX_EXAMPLES = 25
_SEED = 0xA5C3


class _Strategy:
    def __init__(self, draw, minimal):
        self._draw = draw
        self._minimal = minimal

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def minimal(self):
        return self._minimal()


def integers(min_value=None, max_value=None) -> _Strategy:
    lo = -(1 << 16) if min_value is None else min_value
    hi = (1 << 16) if max_value is None else max_value
    return _Strategy(lambda rng: rng.randint(lo, hi), lambda: lo)


def floats(min_value=None, max_value=None, **_ignored) -> _Strategy:
    lo = -1e6 if min_value is None else min_value
    hi = 1e6 if max_value is None else max_value
    return _Strategy(lambda rng: rng.uniform(lo, hi), lambda: float(lo))


def tuples(*strategies_) -> _Strategy:
    def draw(rng):
        return tuple(s.draw(rng) for s in strategies_)

    return _Strategy(draw, lambda: tuple(s.minimal() for s in strategies_))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements), lambda: elements[0])


def lists(elements: _Strategy, min_size=0, max_size=None) -> _Strategy:
    hi = min_size + 20 if max_size is None else max_size

    def draw(rng):
        return [elements.draw(rng) for _ in range(rng.randint(min_size, hi))]

    return _Strategy(draw, lambda: [elements.minimal() for _ in range(min_size)])


def sets(elements: _Strategy, min_size=0, max_size=None) -> _Strategy:
    hi = min_size + 20 if max_size is None else max_size

    def fill(rng, size):
        out = set()
        for _ in range(1000):  # bounded: small element domains may saturate
            if len(out) >= size:
                break
            out.add(elements.draw(rng))
        return out

    def draw(rng):
        return fill(rng, rng.randint(min_size, hi))

    return _Strategy(draw, lambda: fill(random.Random(0), min_size))


def permutations(values) -> _Strategy:
    values = list(values)

    def draw(rng):
        out = list(values)
        rng.shuffle(out)
        return out

    return _Strategy(draw, lambda: list(values))


strategies = SimpleNamespace(
    integers=integers,
    floats=floats,
    tuples=tuples,
    lists=lists,
    sampled_from=sampled_from,
    sets=sets,
    permutations=permutations,
)


def given(**strategy_kw):
    def decorate(fn):
        def runner(*args, **kw):
            cfg = getattr(runner, "_hc_settings", {})
            n = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            # example 0: the minimal draw (catches empty/degenerate cases)
            fn(*args, **{k: s.minimal() for k, s in strategy_kw.items()}, **kw)
            for _ in range(max(n - 1, 0)):
                drawn = {k: s.draw(rng) for k, s in strategy_kw.items()}
                try:
                    fn(*args, **drawn, **kw)
                except Exception:
                    print(f"falsifying example: {drawn!r}")
                    raise

        # Copy identity but NOT __wrapped__: pytest must not see the strategy
        # parameters in the signature (they are not fixtures).
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner._hc_given = True
        return runner

    return decorate


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._hc_settings = {"max_examples": max_examples}
        return fn

    return decorate
