"""Staged-process stage-cut coverage: for random operator graphs with
interior partitioned/stateful operators, the staged process backend's egress
(content AND order) must equal the thread backend's, across micro-batch sizes
and worker counts — the tentpole's correctness contract.  Plus the
RunReport.egress_throughput degenerate-window regression tests.

Watchdog rides at 60 s like the other process-backend tests.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import OpSpec, run_pipeline
from repro.core.procrun import ProcessRuntime, _chain_nodes, _plan_stages


# ------------------------------------------------------------ random chains
def _op_from_code(code: int, i: int) -> OpSpec:
    """Deterministic operator palette (everything picklable / fork-safe)."""
    code = code % 5
    if code == 0:
        return OpSpec(f"sl_double{i}", "stateless", _double)
    if code == 1:
        return OpSpec(f"sl_filter{i}", "stateless", _drop_mod3)
    if code == 2:
        return OpSpec(f"sl_fan{i}", "stateless", _fan2)
    if code == 3:
        return OpSpec(
            f"ps_sum{i}", "partitioned", _keyed_sum,
            key_fn=_mod7, num_partitions=14, init_state=_zero,
        )
    return OpSpec(f"sf_count{i}", "stateful", _counting, init_state=_zero)


def _double(v):
    return [v * 2 + 1]


def _drop_mod3(v):
    return [v] if v % 3 else []


def _fan2(v):
    return [v, v + 1]


def _mod7(v):
    return v % 7


def _zero():
    return 0


def _keyed_sum(s, k, v):
    s += v
    return s, [s % 100003]


def _counting(s, v):
    return s + 1, [(v + s) % 100003]


def _build_chain(codes):
    """Chain from drawn codes with a partitioned op forced into the interior
    (the configuration PR 2 could not parallelize)."""
    specs = [_op_from_code(c, i) for i, c in enumerate(codes)]
    specs.insert(1 + len(specs) // 2, _op_from_code(3, 99))
    return specs


@pytest.mark.timeout(60)
@settings(max_examples=6, deadline=None)
@given(
    codes=st.lists(st.integers(min_value=0, max_value=4), min_size=1, max_size=4),
    n=st.integers(min_value=1, max_value=250),
    workers=st.sampled_from([1, 2, 3]),
    batch_size=st.sampled_from([1, 7, 32]),
)
def test_property_staged_equals_thread_backend(codes, n, workers, batch_size):
    """Random chains with an interior partitioned op: staged process egress
    == thread egress, for batch_size in {1, 7, 32} and several worker
    counts."""
    specs = _build_chain(codes)
    src = list(range(1, n + 1))
    thread_pipe, _ = run_pipeline(
        specs, src, num_workers=2, collect_outputs=True, backend="thread"
    )
    proc_pipe, report = run_pipeline(
        specs, src, num_workers=workers, collect_outputs=True,
        backend="process", batch_size=batch_size,
    )
    assert proc_pipe.num_stages >= 2  # the interior op got its own stage
    assert proc_pipe.outputs == thread_pipe.outputs
    assert report.tuples_in == n
    assert report.tuples_out == len(thread_pipe.outputs)


@pytest.mark.timeout(60)
@settings(max_examples=4, deadline=None)
@given(
    codes=st.lists(st.integers(min_value=0, max_value=4), min_size=2, max_size=5),
    stages=st.sampled_from([1, 2, 3]),
)
def test_property_stage_cap_preserves_semantics(codes, stages):
    """Any stage cap (deep cut, shallow cut, ingress-only) yields identical
    egress — the planner only moves work between parent and stages."""
    specs = _build_chain(codes)
    src = list(range(1, 180))
    ref_pipe, _ = run_pipeline(
        specs, src, num_workers=1, collect_outputs=True, backend="thread"
    )
    pipe, _ = run_pipeline(
        specs, src, num_workers=2, collect_outputs=True,
        backend="process", stages=stages,
    )
    assert pipe.num_stages <= stages
    assert pipe.outputs == ref_pipe.outputs


def test_stage_planner_cuts_at_state_boundaries():
    """Unit check on the planner: SL,SL | PS,SL | SF | PS -> 4 stages, each
    headed by the state boundary, stateful stage single-worker."""
    specs = [
        _op_from_code(0, 0), _op_from_code(1, 1),  # stateless run
        _op_from_code(3, 2), _op_from_code(0, 3),  # partitioned + trailing SL
        _op_from_code(4, 4),                       # stateful
        _op_from_code(3, 5),                       # partitioned again
    ]
    nodes, edges = _chain_nodes(specs)
    plans, tail_nodes, tail_edges = _plan_stages(nodes, edges, 4, None)
    assert [p.kind for p in plans] == ["stateless", "keyed", "stateful", "keyed"]
    assert [len(p.ops) for p in plans] == [2, 2, 1, 1]
    assert [p.workers for p in plans] == [4, 4, 1, 4]
    assert not tail_nodes and not tail_edges
    # cap at 2: the rest must fall back into the parent tail
    plans2, tail_nodes2, _ = _plan_stages(nodes, edges, 4, 2)
    assert [p.kind for p in plans2] == ["stateless", "keyed"]
    assert len(tail_nodes2) == 2


# ------------------------------------------------- unstaged routing warning
def test_unstaged_routing_nodes_emit_structured_warning():
    """backend='process' used to run Split/Merge graphs' routing region in
    the parent tail silently; it must now emit a structured warning naming
    the unstaged nodes."""
    import warnings

    from repro.core import Merge, ProcessRuntime, Split, UnstagedGraphWarning

    nodes = {
        "pre": _op_from_code(0, 0),
        "split": Split("round_robin"),
        "a": _op_from_code(0, 1),
        "b": _op_from_code(0, 2),
        "merge": Merge(),
    }
    edges = [
        ("pre", "split"), ("split", "a"), ("split", "b"),
        ("a", "merge"), ("b", "merge"),
    ]
    with pytest.warns(UnstagedGraphWarning) as rec:
        ProcessRuntime(nodes, edges, num_workers=1)
    w = rec[0].message
    assert set(w.unstaged) == {"split", "a", "b", "merge"}
    assert "split" in str(w) and "parent tail" in str(w)

    # plain chains — even under an explicit stage cap — must stay silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", UnstagedGraphWarning)
        ProcessRuntime.from_chain(
            [_op_from_code(0, 0), _op_from_code(4, 1)], num_workers=1, stages=1
        )


# --------------------------------------------- egress_throughput regression
def _nullify(v):
    return []


def _ident(v):
    return [v]


@pytest.mark.parametrize("backend", ["thread", "process"])
def test_egress_throughput_zero_output_run_reports_zero(backend):
    """Regression: a run egressing 0 tuples used to risk dividing by a ~0
    first-push==last-egress window; it must report 0.0, not raise."""
    _, report = run_pipeline(
        [OpSpec("null", "stateless", _nullify)], [1, 2, 3],
        num_workers=1, backend=backend,
    )
    assert report.tuples_out == 0
    assert report.egress_throughput == 0.0


@pytest.mark.parametrize("backend", ["thread", "process"])
def test_egress_throughput_single_output_run_reports_zero(backend):
    """A single egressed tuple's window is degenerate (first push == last
    egress): the rate is meaningless and must be reported as 0.0."""
    pipe, report = run_pipeline(
        [OpSpec("id", "stateless", _ident)], [42],
        num_workers=1, backend=backend, collect_outputs=True,
    )
    assert pipe.outputs == [42]
    assert report.tuples_out == 1
    assert report.egress_throughput == 0.0


def test_egress_throughput_normal_run_still_positive():
    _, report = run_pipeline(
        [OpSpec("id", "stateless", _ident)], list(range(500)),
        num_workers=2, backend="thread",
    )
    assert report.egress_throughput > 0.0
