"""Micro-batched threaded path: ordered semantics must be batch-size
invariant — for any batch size, the egress equals the sequential reference
exactly, no tuples are lost, and every latency marker is accounted for."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import OpSpec, run_pipeline
from repro.core.pipeline import CompiledPipeline, GraphPipeline, Merge, Split
from repro.core.runtime import StreamRuntime


def _specs_mixed():
    return [
        OpSpec("double", "stateless", lambda v: [v * 2]),
        OpSpec(
            "ksum", "partitioned",
            lambda s, k, v: (s + v, [(k, s + v)]),
            key_fn=lambda v: v % 5, num_partitions=8, init_state=lambda: 0,
        ),
        OpSpec("filt", "stateless", lambda kv: [kv] if kv[1] % 2 == 0 else []),
        OpSpec(
            "count", "stateful",
            lambda s, kv: (s + 1, [(kv[0], kv[1], s + 1)]), init_state=lambda: 0,
        ),
    ]


def _oracle(vals):
    states, out, c = {}, [], 0
    for v in vals:
        d = v * 2
        k = d % 5
        states[k] = states.get(k, 0) + d
        if states[k] % 2 == 0:
            c += 1
            out.append((k, states[k], c))
    return out


@settings(max_examples=12, deadline=None)
@given(
    vals=st.lists(st.integers(min_value=0, max_value=200), min_size=1, max_size=300),
    batch=st.sampled_from([1, 2, 7, 32, 64]),
    workers=st.sampled_from([1, 2, 5]),
)
def test_property_batched_matches_sequential_oracle(vals, batch, workers):
    pipe, report = run_pipeline(
        _specs_mixed(),
        vals,
        num_workers=workers,
        batch_size=batch,
        collect_outputs=True,
    )
    expected = _oracle(vals)
    assert pipe.outputs == expected
    assert report.tuples_in == len(vals)
    assert report.tuples_out == len(expected)


@settings(max_examples=6, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=500),
    batch=st.sampled_from([2, 16, 32]),
)
def test_property_batched_markers_all_accounted(n, batch):
    """Every injected marker must be recorded (egress or drop), regardless of
    where batch boundaries land."""
    interval = 8
    pipe, _ = run_pipeline(
        [
            OpSpec("keep_some", "stateless", lambda v: [v] if v % 3 else []),
            OpSpec("id", "stateless", lambda v: [v]),
        ],
        list(range(1, n + 1)),
        num_workers=2,
        batch_size=batch,
        marker_interval=interval,
    )
    assert len(pipe.markers) == n // interval
    assert all(m.exit > 0 for m in pipe.markers)


def test_partial_batch_flush_and_drained():
    """A partial ingress batch holds drained() False until flush()."""
    pipe = CompiledPipeline(
        [OpSpec("id", "stateless", lambda v: [v])],
        batch_size=32,
        collect_outputs=True,
    )
    rt = StreamRuntime(pipe, num_workers=2)
    rt.start()
    try:
        for v in range(5):  # 5 < 32: accumulates, nothing enqueued
            pipe.push(v)
        assert not pipe.drained()
        pipe.flush()
        deadline = 100
        while not pipe.drained() and deadline:
            deadline -= 1
            import time

            time.sleep(0.01)
        assert pipe.drained()
    finally:
        rt.stop()
    assert pipe.outputs == list(range(5))


def test_graph_with_routing_clamps_batch_size():
    g = GraphPipeline(
        nodes={
            "split": Split("round_robin"),
            "a": OpSpec("a", "stateless", lambda v: [v]),
            "b": OpSpec("b", "stateless", lambda v: [v]),
            "merge": Merge(),
        },
        edges=[("split", "a"), ("split", "b"), ("a", "merge"), ("b", "merge")],
        batch_size=32,
    )
    assert g.batch_size == 1  # routing nodes keep per-tuple granularity


def test_egress_throughput_reported():
    _, report = run_pipeline(
        [OpSpec("id", "stateless", lambda v: [v])],
        list(range(2000)),
        num_workers=2,
        batch_size=32,
    )
    assert report.egress_throughput > 0
    assert "egress" in str(report)
