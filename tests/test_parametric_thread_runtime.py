"""Thread-runtime run with real-compute parametric operators (paper §7's
micro-benchmark substrate) — ordering holds with real work in the loop."""
from repro.core import run_pipeline
from repro.streams.parametric import partitioned_parametric, stateless_parametric


def test_parametric_pipeline_ordered_under_threads():
    specs = [
        stateless_parametric(matrix_n=8, selectivity=1.0),
        partitioned_parametric(matrix_n=8, num_partitions=32),
    ]
    source = [i % 64 for i in range(2000)]  # 64 recurring keys
    pipe, report = run_pipeline(
        specs, source, num_workers=4, heuristic="ct", collect_outputs=True
    )
    assert report.tuples_out == 2000
    # per-KEY state: each key's counter must be the arrival-ordered 1,2,3,...
    seen = {}
    for key, count in pipe.outputs:
        assert count == seen.get(key, 0.0) + 1.0, "per-key order violated"
        seen[key] = count
