"""Static-analysis subsystem tests: one fixture per rule (each bad fixture
trips exactly its rule), a regression gate that the live core tree stays
clean against the committed baseline, the plan-time ordering-safety
verifier (``PhysicalPlan.verify`` / rules PV4xx), and the CLI surface
(``python -m repro.analysis``) including the baseline check workflow."""
import json
import os

import pytest

from repro.analysis import (
    RULES,
    analyze_paths,
    diff_baseline,
    load_baseline,
    verify_plan,
    write_baseline,
)
from repro.analysis.__main__ import main as analysis_main
from repro.core import (
    Engine,
    EngineConfig,
    OpSpec,
    PhysicalPlan,
    PlanVerificationError,
    ProcessOptions,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures", "analysis")

_FIXTURE_RULES = [
    "GB101", "GB102", "GB103", "GB104",
    "LK201", "LK202", "LK203",
    "FS301", "FS302", "FS303",
    "AN001", "AN002",
]


def _analyze_fixture(name):
    return analyze_paths([os.path.join(FIXTURES, name)], root=REPO_ROOT)


# ------------------------------------------------------------- rule fixtures
@pytest.mark.parametrize("rule", _FIXTURE_RULES)
def test_bad_fixture_triggers_exactly_its_rule(rule):
    findings = _analyze_fixture(f"bad_{rule.lower()}.py")
    assert findings, f"fixture for {rule} produced no findings"
    assert {f.rule for f in findings} == {rule}


def test_good_fixture_is_clean():
    assert _analyze_fixture("good.py") == []


def test_every_finding_rule_is_cataloged():
    for rule in _FIXTURE_RULES:
        assert rule in RULES
    for f in _analyze_fixture("bad_gb101.py"):
        assert f.rule in RULES
        assert str(f.line) not in f.key()  # baseline keys survive line churn
        assert f.path in f.render()


# ------------------------------------------------- live-tree regression gate
def test_live_core_tree_is_clean_against_baseline():
    """The committed core tree must produce no findings beyond the committed
    baseline — the same gate ``python -m repro.analysis --check`` enforces."""
    findings = analyze_paths(None, root=REPO_ROOT)
    baseline = load_baseline(os.path.join(REPO_ROOT, "ANALYSIS_BASELINE.json"))
    new, _stale = diff_baseline(findings, baseline)
    assert new == [], "new findings outside baseline:\n" + "\n".join(
        f.render() for f in new
    )


def test_baseline_round_trip(tmp_path):
    findings = _analyze_fixture("bad_gb101.py")
    path = str(tmp_path / "base.json")
    write_baseline(path, findings)
    keys = load_baseline(path)
    assert keys == {f.key() for f in findings}
    new, stale = diff_baseline(findings, keys)
    assert new == [] and stale == set()
    new, stale = diff_baseline([], keys)
    assert new == [] and stale == keys


def test_baseline_rejects_unknown_version(tmp_path):
    path = tmp_path / "base.json"
    path.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError, match="version"):
        load_baseline(str(path))


# -------------------------------------------------- plan-time verify (PV4xx)
def _ident(v):
    return [v]


def _zero():
    return 0


def _sf_sum(s, v):
    s += v
    return s, [s]


def _kcount(s, k, v):
    return (s or 0) + 1, [v]


def _mod8(v):
    return v % 8


def _stateful_plan_dict():
    eng = Engine(EngineConfig(
        backend="process", num_workers=2,
        process=ProcessOptions(worker_budget=2),
    ))
    plan = eng.plan([
        OpSpec("pre", "stateless", _ident, cost_us=2),
        OpSpec("sf", "stateful", _sf_sum, init_state=_zero, cost_us=4),
    ])
    return plan.to_dict()


def test_verify_rejects_hand_built_width2_stateful_stage():
    d = _stateful_plan_dict()
    idx = next(i for i, s in enumerate(d["stages"]) if s["kind"] == "stateful")
    d["stages"][idx]["workers"] = 2
    d["stages"][idx]["max_workers"] = 2  # keep PV404 out of the way
    bad = PhysicalPlan.from_dict(d)
    with pytest.raises(PlanVerificationError) as ei:
        bad.verify()
    err = ei.value
    assert [v.rule for v in err.violations] == ["PV401"]
    assert err.violations[0].stage == d["stages"][idx]["index"]
    assert "PV401" in str(err)
    # non-raising mode returns the same structured rows
    assert bad.verify(raise_on_violation=False) == err.violations


def test_verify_flags_ring_and_op_cap_violations():
    d = _stateful_plan_dict()
    d["ring"]["reorder_size"] = d["ring"]["io_batch"] - 1
    for op in d["ops"]:
        if op["kind"] == "stateful":
            op["max_dop"] = 4
    rules = {v.rule for v in PhysicalPlan.from_dict(d).verify(
        raise_on_violation=False
    )}
    assert "PV403" in rules
    assert "PV406" in rules


def test_verify_plan_duck_typed_entry_point():
    d = _stateful_plan_dict()
    assert verify_plan(PhysicalPlan.from_dict(d)) == []


def test_engine_plan_verifies_by_default(monkeypatch):
    calls = []
    orig = PhysicalPlan.verify

    def spy(self, **kw):
        calls.append(self)
        return orig(self, **kw)

    monkeypatch.setattr(PhysicalPlan, "verify", spy)
    eng = Engine(EngineConfig(backend="thread", num_workers=2))
    plan = eng.plan([OpSpec("pre", "stateless", _ident, cost_us=2)])
    assert calls == [plan]


def test_explain_reports_ordering_safety():
    eng = Engine(EngineConfig(backend="thread", num_workers=2))
    plan = eng.plan([OpSpec("pre", "stateless", _ident, cost_us=2)])
    assert "ordering-safety: verified OK" in plan.explain()
    d = plan.to_dict()
    d["ops"][0]["kind"] = "stateful"
    d["ops"][0]["max_dop"] = 8
    bad = PhysicalPlan.from_dict(d)
    assert "PV406" in bad.explain()


def test_keyed_width_above_partitions_is_rejected():
    eng = Engine(EngineConfig(
        backend="process", num_workers=2,
        process=ProcessOptions(worker_budget=2),
    ))
    plan = eng.plan([
        OpSpec("hot", "partitioned", _kcount, key_fn=_mod8, num_partitions=4,
               init_state=_zero, cost_us=8),
    ])
    d = plan.to_dict()
    idx = next(i for i, s in enumerate(d["stages"]) if s["kind"] == "keyed")
    d["stages"][idx]["workers"] = 8
    d["stages"][idx]["max_workers"] = 8
    rules = {v.rule for v in PhysicalPlan.from_dict(d).verify(
        raise_on_violation=False
    )}
    assert "PV402" in rules


def test_checkpoint_geometry_is_verified():
    d = _stateful_plan_dict()
    # engine-built: the stateful stage checkpoints, the interval covers a
    # full dispatch unit, and the plan verifies clean
    assert any(s["checkpointed"] for s in d["stages"])
    assert d["ring"]["checkpoint_interval"] >= d["ring"]["io_batch"]
    assert PhysicalPlan.from_dict(d).verify(raise_on_violation=False) == []
    # a stateless stage cannot checkpoint (no state to snapshot)
    bad = _stateful_plan_dict()
    idx = next(
        i for i, s in enumerate(bad["stages"]) if s["kind"] == "stateless"
    )
    bad["stages"][idx]["checkpointed"] = True
    rules = {v.rule for v in PhysicalPlan.from_dict(bad).verify(
        raise_on_violation=False
    )}
    assert rules == {"PV407"}
    # an epoch shorter than a dispatch unit cannot be honored
    bad = _stateful_plan_dict()
    bad["ring"]["checkpoint_interval"] = bad["ring"]["io_batch"] - 1
    rules = {v.rule for v in PhysicalPlan.from_dict(bad).verify(
        raise_on_violation=False
    )}
    assert rules == {"PV407"}


def test_traffic_policy_geometry_is_verified():
    """PV408: hysteresis band, p99-guard sign, and a resizable stage for an
    explicitly armed policy.  ProcessOptions.validate blocks these at
    construction, so the violations are injected into built plans — the
    deserialized-and-edited surface the catalog exists for."""
    def _plan(specs, **popts):
        eng = Engine(EngineConfig(
            backend="process", num_workers=2,
            process=ProcessOptions(worker_budget=2, **popts),
        ))
        return eng.plan(specs)

    keyed = [
        OpSpec("hot", "partitioned", _kcount, key_fn=_mod8, num_partitions=4,
               init_state=_zero, cost_us=8),
    ]
    # engine-built with the policy armed: clean
    plan = _plan(keyed, traffic_elastic=True)
    assert plan.verify(raise_on_violation=False) == []
    # empty hysteresis band: shrink threshold at/above grow
    plan = _plan(keyed)
    plan.config.process.traffic_shrink_util = plan.config.process.traffic_grow_util
    rules = {v.rule for v in plan.verify(raise_on_violation=False)}
    assert rules == {"PV408"}
    # non-positive p99-guard budget
    plan = _plan(keyed)
    plan.config.process.resize_latency_budget = -0.5
    rules = {v.rule for v in plan.verify(raise_on_violation=False)}
    assert rules == {"PV408"}
    # armed policy with nothing it can ever act on: a stateful-only plan
    # (width pinned at 1) leaves no non-stateful stage with headroom
    plan = _plan([
        OpSpec("acc", "stateful", _sf_sum, init_state=_zero, cost_us=2),
    ])
    assert plan.verify(raise_on_violation=False) == []  # unarmed: fine
    plan.config.process.traffic_elastic = True
    rules = {v.rule for v in plan.verify(raise_on_violation=False)}
    assert rules == {"PV408"}


# ---------------------------------------------------------------------- CLI
def test_cli_rules_lists_catalog(capsys):
    assert analysis_main(["--rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out


def test_cli_check_fails_on_new_finding(tmp_path, capsys):
    bad = os.path.join(FIXTURES, "bad_gb101.py")
    rc = analysis_main([bad, "--check", "--baseline",
                        str(tmp_path / "missing.json")])
    assert rc == 2
    assert "GB101" in capsys.readouterr().out


def test_cli_write_baseline_then_check_passes(tmp_path, capsys):
    bad = os.path.join(FIXTURES, "bad_gb101.py")
    base = str(tmp_path / "base.json")
    assert analysis_main([bad, "--write-baseline", "--baseline", base]) == 0
    assert analysis_main([bad, "--check", "--baseline", base]) == 0
    # fixed finding -> stale baseline entry is warned about, not fatal
    good = os.path.join(FIXTURES, "good.py")
    assert analysis_main([good, "--check", "--baseline", base]) == 0
    assert "stale" in capsys.readouterr().out


def test_cli_json_report(capsys):
    bad = os.path.join(FIXTURES, "bad_lk202.py")
    assert analysis_main([bad, "--json"]) == 0
    data = json.loads(capsys.readouterr().out)
    assert data["summary"]["total"] == 1
    assert data["findings"][0]["rule"] == "LK202"
