"""Distribution tests on a small forced-host-device mesh (subprocess so the
main test process keeps its single CPU device)."""
import json
import os
import subprocess
import sys

import pytest

pytest.importorskip(
    "jax", reason="distribution tests fork a jax host-device mesh subprocess"
)

_SMALL_MESH_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import dataclasses
from repro.configs import smoke_config, SHAPES
from repro.launch.mesh import make_test_mesh
from repro.models.common import abstract_params, init_params, param_pspecs
from repro.sharding.context import use_mesh
from repro.sharding.partitioning import named_sanitized, batch_spec
from repro.train.optimizer import OptConfig, abstract_opt_state
from repro.train import train_step as ts

results = {}

# --- lower+compile a reduced train step on the (2,4) test mesh
cfg = smoke_config("olmo-1b")
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
mesh = make_test_mesh()
ocfg = OptConfig()
with mesh, use_mesh(mesh):
    step = ts.make_train_step(cfg, ocfg)
    ins, outs = ts.train_step_shardings(cfg, ocfg, mesh, shape)
    ap = abstract_params(cfg)
    args = (ap, abstract_opt_state(ocfg, ap), ts.abstract_train_batch(cfg, shape))
    compiled = jax.jit(step, in_shardings=ins, out_shardings=outs,
                       donate_argnums=(0, 1)).lower(*args).compile()
results["train_compiles"] = True
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):  # older jax: one entry per device program
    ca = ca[0] if ca else {}
results["train_flops"] = ca.get("flops", 0)

# --- multi-pod test mesh (2,2,2): pod axis must shard
cfg2 = smoke_config("qwen2-moe-a2.7b")
mesh2 = make_test_mesh(multi_pod=True)
with mesh2, use_mesh(mesh2):
    step = ts.make_train_step(cfg2, ocfg)
    shape2 = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=8)
    ins, outs = ts.train_step_shardings(cfg2, ocfg, mesh2, shape2)
    ap = abstract_params(cfg2)
    args = (ap, abstract_opt_state(ocfg, ap), ts.abstract_train_batch(cfg2, shape2))
    compiled2 = jax.jit(step, in_shardings=ins, out_shardings=outs,
                        donate_argnums=(0, 1)).lower(*args).compile()
results["multipod_compiles"] = True

# --- REAL execution of a sharded train step on 8 devices (numerics parity)
cfg3 = smoke_config("olmo-1b")
params = init_params(cfg3, jax.random.PRNGKey(0))
import numpy as np
toks = jnp.asarray(np.random.RandomState(0).randint(0, cfg3.vocab_size, (8, 32)))
batch = {"tokens": toks, "labels": toks}
from repro.models.transformer import loss_fn
with mesh, use_mesh(mesh):
    pp = named_sanitized(mesh, param_pspecs(cfg3), abstract_params(cfg3))
    sparams = jax.device_put(params, pp)
    sbatch = jax.device_put(batch, NamedSharding(mesh, batch_spec(mesh, 8, 1)))
    loss_sharded, _ = jax.jit(lambda p, b: loss_fn(cfg3, p, b))(sparams, sbatch)
loss_single, _ = loss_fn(cfg3, params, batch)
results["loss_sharded"] = float(loss_sharded)
results["loss_single"] = float(loss_single)

# --- int8 error-feedback gradient psum over the pod axis (shard_map)
from repro.train.grad_compression import compress_allreduce_leaf
try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map
g = jnp.arange(16.0).reshape(2, 8) * 0.01  # (pod-sharded dim, payload)
err = jnp.zeros((2, 8))
def fn(gl, el):
    s, e = compress_allreduce_leaf(gl[0], el[0], "pod")
    return s[None], e[None]
import inspect
_sm_kw = (
    {"check_vma": False}
    if "check_vma" in inspect.signature(shard_map).parameters
    else {"check_rep": False}  # pre-0.5 jax spelling
)
with mesh2:
    summed, new_err = shard_map(
        fn, mesh=mesh2, in_specs=(P("pod", None), P("pod", None)),
        out_specs=(P("pod", None), P("pod", None)), **_sm_kw,
    )(g, err)
true_sum = g.sum(axis=0)
rel = float(jnp.linalg.norm(summed[0] - true_sum) / (jnp.linalg.norm(true_sum)))
results["compressed_psum_rel_err"] = rel
print("RESULTS:" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def small_mesh_results():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", _SMALL_MESH_PROG],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=560,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:"):])


def test_train_step_compiles_on_mesh(small_mesh_results):
    assert small_mesh_results["train_compiles"]
    assert small_mesh_results["train_flops"] > 0


def test_multipod_mesh_compiles(small_mesh_results):
    assert small_mesh_results["multipod_compiles"]


def test_sharded_loss_matches_single_device(small_mesh_results):
    a = small_mesh_results["loss_sharded"]
    b = small_mesh_results["loss_single"]
    assert abs(a - b) / max(abs(b), 1e-6) < 5e-2, (a, b)


def test_compressed_psum_close(small_mesh_results):
    assert small_mesh_results["compressed_psum_rel_err"] < 0.02
