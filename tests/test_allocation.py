"""Skew/allocation test battery (ISSUE 4 tentpole contract).

Zipf-skewed keyed chains driven through flat vs. cost-model ("auto") worker
allocation across micro-batch sizes and stage shapes must produce output
exactly equal to the thread backend — and the allocator must give the hot
stage at least as many workers as any cold data-parallel stage.  Plus unit
coverage of the proportional allocator, calibration, the occupancy monitor's
drift detection, and an end-to-end elastic-replan run (quiesce at a serial
boundary, keyed state migration, re-fork at a new width).

Process tests ride the 60 s watchdog like the rest of the process-backend
suite.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    CostModel,
    OpSpec,
    OccupancyMonitor,
    ProcessRuntime,
    TrafficMonitor,
    proportional_allocation,
    resolve_workers,
    run_pipeline,
)
from repro.core.procrun import _chain_nodes, _plan_stages


# ------------------------------------------------- fork/pickle-safe operators
def _double(v):
    return [v * 2 + 1]


def _fan2(v):
    return [v, v + 3]


def _drop5(v):
    return [v] if v % 5 else []


def _mod11(v):
    return v % 11


def _fst(t):
    return t[0]


def _zero():
    return 0


def _tup_inc(t):  # stateless over keyed output tuples
    return [(t[0], t[1] + 3)]


def _tup_drop5(t):
    return [t] if t[1] % 5 else []


def _ksum(s, k, v):
    s = (s or 0) + (v if isinstance(v, int) else v[1])
    return s, [(k, s % 99991)]


def _kcount(s, k, t):
    s = (s or 0) + 1
    return s, [(k, s, t[1] % 997)]


def _count(s, t):
    return s + 1, [(s, t[1])]


def _spin_hot(v):
    x = float(v)
    for _ in range(400):
        x = (x * 1.0000001 + 1.31) % 97.0
    return [int(x * 1000)]


# Stage shapes: (specs builder, {op name: cost_us} priors, hot stage index).
# Remember the planner's stage grammar: a leading stateless run is stage 0,
# every partitioned/stateful op heads a new stage and absorbs its trailing
# stateless run.
def _shape_interior_hot():
    specs = [
        OpSpec("pre", "stateless", _double, cost_us=2),
        OpSpec("hot", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=120),
        OpSpec("post", "stateless", _tup_inc, cost_us=2),
    ]
    return specs, {"pre": 2, "hot": 120, "post": 2}, 1


def _shape_leading_keyed_hot():
    specs = [
        OpSpec("hot", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=90),
        OpSpec("mid", "stateless", _tup_drop5, cost_us=2),
        OpSpec("cold", "partitioned", _kcount, key_fn=_fst,
               num_partitions=22, init_state=_zero, cost_us=3),
    ]
    return specs, {"hot": 90, "mid": 2, "cold": 3}, 0


def _shape_hot_prefix():
    specs = [
        OpSpec("hot", "stateless", _double, cost_us=150),
        OpSpec("cold", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=4),
        OpSpec("tail", "stateful", _count, init_state=_zero, cost_us=1),
    ]
    return specs, {"hot": 150, "cold": 4, "tail": 1}, 0


SHAPES = {
    "interior_hot": _shape_interior_hot,
    "leading_keyed_hot": _shape_leading_keyed_hot,
    "hot_prefix": _shape_hot_prefix,
}


def _zipf_values(n: int, seed: int, skew: float = 2.0, universe: int = 400):
    """Deterministic zipf-skewed int stream (hot keys dominate — the keyed
    load imbalance the battery drives through both allocations)."""
    rng = random.Random(seed)
    return [
        1 + min(int(universe * (rng.random() ** skew)), universe - 1)
        for _ in range(n)
    ]


# -------------------------------------------------- allocator unit/properties
@settings(max_examples=20, deadline=None)
@given(
    loads=st.lists(st.integers(min_value=0, max_value=1000), min_size=1,
                   max_size=6),
    budget=st.integers(min_value=0, max_value=12),
    cap=st.integers(min_value=1, max_value=4),
)
def test_property_proportional_allocation_invariants(loads, budget, cap):
    n = len(loads)
    mins = [1] * n
    caps = [cap] * n
    widths = proportional_allocation([float(l) for l in loads], budget,
                                     mins, caps)
    assert len(widths) == n
    assert all(mins[i] <= widths[i] <= caps[i] for i in range(n))
    assert sum(widths) <= max(budget, sum(mins))
    # monotone in load: an uncapped hotter stage never gets fewer workers
    for i in range(n):
        for j in range(n):
            if loads[i] > loads[j] and widths[i] < caps[i]:
                assert widths[i] >= widths[j], (loads, widths)


def test_allocation_pins_stateful_and_caps_keyed():
    specs, priors, _hot = _shape_hot_prefix()
    nodes, edges = _chain_nodes(specs)
    plans, _, _ = _plan_stages(nodes, edges, 1, None)
    model = CostModel(plans, priors)
    widths = model.allocate(budget=8)
    # stateful stage pinned at 1 regardless of leftover budget
    assert widths[[p.kind for p in plans].index("stateful")] == 1
    # the hot stage soaked up the budget
    assert widths[0] == max(widths)
    assert sum(widths) <= 8
    # keyed cap: partition count bounds the keyed stage
    assert widths[1] <= 22


def test_resolve_workers_auto_and_validation():
    assert resolve_workers(3) == 3
    assert resolve_workers("auto") >= 2
    assert resolve_workers("auto", budget=7) == 7
    with pytest.raises(ValueError):
        resolve_workers("many")


# --------------------------------------------- the zipf flat-vs-auto battery
@pytest.mark.timeout(60)
@settings(max_examples=4, deadline=None)
@given(
    shape=st.sampled_from(sorted(SHAPES)),
    batch_size=st.sampled_from([1, 7, 32]),
    n=st.integers(min_value=40, max_value=350),
    skew=st.sampled_from([15, 25]),  # zipf exponent x10
    seed=st.integers(min_value=0, max_value=5),
)
def test_property_zipf_flat_vs_auto_exact_equality(shape, batch_size, n,
                                                   skew, seed):
    """Flat AND auto allocation must both reproduce the thread backend's
    egress exactly on zipf-skewed keyed chains, for batch_size {1, 7, 32}
    across stage shapes; the allocator must give the hot stage >= as many
    workers as any cold data-parallel stage."""
    specs, priors, hot = SHAPES[shape]()
    src = _zipf_values(n, seed=seed, skew=skew / 10.0)
    ref, _ = run_pipeline(
        specs, src, num_workers=2, collect_outputs=True, backend="thread"
    )
    flat, _ = run_pipeline(
        specs, src, num_workers=2, collect_outputs=True,
        backend="process", batch_size=batch_size,
    )
    assert flat.outputs == ref.outputs
    auto, _ = run_pipeline(
        specs, src, num_workers="auto", worker_budget=4, cost_priors=priors,
        collect_outputs=True, backend="process", batch_size=batch_size,
    )
    assert auto.outputs == ref.outputs
    widths = auto.stage_widths()
    dp = [i for i, p in enumerate(auto.stage_plans) if p.kind != "stateful"]
    assert all(widths[hot] >= widths[i] for i in dp), (widths, hot)
    assert widths[hot] >= 2  # budget 4 over <=2 dp stages: hot gets spare


@pytest.mark.timeout(60)
def test_calibration_profiles_real_costs_without_priors():
    """workers='auto' with no priors: the calibration dry run must measure
    the hot stateless prefix and hand it the spare budget — and the profiled
    warm-up must not disturb the stream (exact output equality)."""
    specs = [
        OpSpec("hot", "stateless", _spin_hot),  # declared cost_us defaults!
        OpSpec("cold", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero),
    ]
    src = _zipf_values(2500, seed=3)
    ref, _ = run_pipeline(specs, src, num_workers=1, collect_outputs=True)
    rt, report = run_pipeline(
        specs, src, num_workers="auto", worker_budget=3,
        backend="process", collect_outputs=True, batch_size=16,
        # pin the calibrated widths: this test asserts what the dry run
        # measured, and a live replan (e.g. from coverage-tracer-distorted
        # occupancy) would overwrite them — the monitor has its own tests
        replan_interval=300.0,
    )
    assert rt.outputs == ref.outputs
    assert report.tuples_in == len(src)
    widths = rt.stage_widths()
    assert widths[0] > widths[1], widths  # measured, not declared, costs won
    assert rt.cost_model.profiles[0].measured


# ------------------------------------------------------- occupancy monitoring
def test_occupancy_monitor_proposes_growing_the_hot_stage():
    specs, priors, _hot = _shape_interior_hot()
    nodes, edges = _chain_nodes(specs)
    plans, _, _ = _plan_stages(nodes, edges, 1, None)
    model = CostModel(plans, {"pre": 2, "hot": 2, "post": 2})  # wrong priors
    mon = OccupancyMonitor(model, budget=3, interval=0.0, patience=2)
    widths, resizable = [1, 1], [True, True]
    # stage 1 drains slowly with a dominant backlog; stage 0 keeps pace
    proposal = None
    for tick in range(1, 6):
        proposal = mon.sample(
            now=float(tick),
            drained=[tick * 1000, tick * 50],
            backlog=[0, 64],
            widths=widths,
            resizable=resizable,
        )
        if proposal:
            break
    assert proposal, "monitor never reacted to sustained occupancy drift"
    assert dict(proposal).get(1) == 2, proposal  # grow the hot keyed stage
    assert model.profiles[1].measured  # live rates replaced the bad prior


def test_occupancy_monitor_ignores_unaddressable_drift():
    specs, priors, _hot = _shape_hot_prefix()
    nodes, edges = _chain_nodes(specs)
    plans, _, _ = _plan_stages(nodes, edges, 1, None)
    model = CostModel(plans, priors)
    mon = OccupancyMonitor(model, budget=3, interval=0.0, patience=1)
    for tick in range(1, 5):
        proposal = mon.sample(
            now=float(tick),
            drained=[tick * 100, tick * 100, tick * 90],
            backlog=[0, 0, 64],  # the STATEFUL stage is hot: nothing to do
            widths=[1, 1, 1],
            resizable=[True, True, False],
        )
        assert not proposal


def test_occupancy_monitor_survives_alternating_hot_stage():
    """Regression (per-stage patience streaks): two stages alternating as
    the backlog leader must each accumulate their own qualifying samples.
    The pre-fix monitor kept one shared streak keyed to 'the' hot stage and
    reset it on every leader change, so an oscillating hot spot never
    reached ``patience`` and the pipeline never replanned."""
    specs, priors, _hot = _shape_interior_hot()
    nodes, edges = _chain_nodes(specs)
    plans, _, _ = _plan_stages(nodes, edges, 1, None)
    model = CostModel(plans, priors)
    mon = OccupancyMonitor(model, budget=4, interval=0.0, patience=2)
    widths, resizable = [1, 1], [True, True]
    proposal = None
    fired_at = None
    for tick in range(1, 7):
        # leader flips every sample: 0, 1, 0, 1, ...
        backlog = [70, 30] if tick % 2 else [30, 70]
        proposal = mon.sample(
            now=float(tick),
            drained=[tick * 100, tick * 80],
            backlog=backlog,
            widths=widths,
            resizable=resizable,
        )
        if proposal:
            fired_at = tick
            break
    assert proposal, (
        "alternating hot stages starved the shared patience streak: "
        "the monitor never proposed a replan"
    )
    # the leader at the firing tick reached its own 2-sample streak
    hot = 0 if fired_at % 2 else 1
    assert dict(proposal).get(hot) == 2, (proposal, fired_at)


# ----------------------------------------------------------- traffic monitor
def _traffic_fixture(cost_us=1000.0, **kw):
    """A pre(stateless)+hot(keyed) two-stage model with a known per-tuple
    cost, so ``util = rate * cost / (width * 1e6)`` is easy to dial."""
    specs = [
        OpSpec("pre", "stateless", _double, cost_us=2),
        OpSpec("hot", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=cost_us),
    ]
    nodes, edges = _chain_nodes(specs)
    plans, _, _ = _plan_stages(nodes, edges, 1, None)
    model = CostModel(plans, {"pre": 2, "hot": cost_us})
    kw.setdefault("interval", 0.0)
    return TrafficMonitor(model, budget=4, **kw)


def _feed_rate(mon, rate, sessions=6, queued=0, t0=0.0):
    """Two load snapshots that establish an offered-rate EWMA of ``rate``."""
    mon.ingest({"ts": t0, "sessions": sessions, "admitted_total": 0,
                "ingress_queued": queued, "backpressured": 0})
    mon.ingest({"ts": t0 + 1.0, "sessions": sessions,
                "admitted_total": int(rate), "ingress_queued": queued,
                "backpressured": 0})


def test_traffic_monitor_inert_until_rate_established():
    mon = _traffic_fixture(patience=1)
    # no ingest at all: the policy must not act on a zero-information rate
    assert mon.sample(1.0, [10, 10], [0, 0], [1, 1], [True, True]) is None
    mon.ingest({"ts": 0.0, "sessions": 6, "admitted_total": 0,
                "ingress_queued": 0, "backpressured": 0})
    # one snapshot: still no delta to derive a rate from
    assert mon.sample(2.0, [20, 20], [0, 0], [1, 1], [True, True]) is None


def test_traffic_monitor_grows_keyed_stage_after_patience():
    mon = _traffic_fixture(patience=2)
    _feed_rate(mon, 900)  # util = 900 * 1000us / 1e6 = 0.9 > grow 0.85
    assert mon.sample(2.0, [50, 50], [0, 0], [1, 1], [True, True]) is None
    prop = mon.sample(3.0, [50, 50], [0, 0], [1, 1], [True, True])
    assert prop == [(1, 2)], prop  # the keyed stage, one step wider
    assert mon.proposals == 1


def test_traffic_monitor_saturation_overrides_cost_model():
    """Admission pressure (deep mux ingress queues) must force a grow even
    when the cost model says the stages are idle — the measured-cost surface
    can be stale or wrong, the queue is ground truth."""
    mon = _traffic_fixture(patience=1)
    # rate ~20/s: util 0.02, nowhere near grow_util...
    _feed_rate(mon, 20, sessions=6, queued=40)  # ...but 40 >= max(16, 12)
    assert mon.saturated()
    prop = mon.sample(2.0, [10, 10], [0, 8], [1, 1], [True, True])
    assert prop == [(1, 2)], prop


def test_traffic_monitor_hysteresis_blocks_marginal_shrink():
    """A shrink must also clear the *grow* threshold at the narrower width
    (util * w / (w-1) < grow_util) — otherwise the very next sample would
    qualify the stage for re-growth and widths oscillate."""
    mon = _traffic_fixture(patience=1, grow_util=0.85, shrink_util=0.5)
    _feed_rate(mon, 900)  # width 2: util 0.45 < shrink 0.5 ...
    # ... but at width 1 it would be 0.9 > grow 0.85: blocked
    assert mon.sample(2.0, [50, 50], [0, 0], [1, 2], [True, True]) is None
    assert mon.sample(3.0, [50, 50], [0, 0], [1, 2], [True, True]) is None
    # deepen the trough: width 2 util 0.35, width 1 would be 0.7 < 0.85
    _feed_rate(mon, 700, t0=10.0)
    mon._rate = 700.0  # EWMA converges slowly; pin for determinism
    prop = mon.sample(4.0, [50, 50], [0, 0], [1, 2], [True, True])
    assert prop == [(1, 1)], prop


def test_traffic_monitor_shrink_needs_drained_backlog():
    mon = _traffic_fixture(patience=1)
    _feed_rate(mon, 50)  # deep trough by rate...
    # ...but the stage still holds queued work: no shrink while draining
    assert mon.sample(
        2.0, [50, 50], [0, 32], [1, 2], [True, True]
    ) is None
    prop = mon.sample(3.0, [60, 60], [0, 0], [1, 2], [True, True])
    assert prop == [(1, 1)], prop


def test_traffic_monitor_cooldown_and_abort_backoff():
    mon = _traffic_fixture(patience=1, cooldown=2.0)
    _feed_rate(mon, 900)
    assert mon.sample(2.0, [50, 50], [0, 0], [1, 1], [True, True]) == [(1, 2)]
    # inside the cooldown window: the same pressure must not re-fire
    assert mon.sample(3.0, [60, 60], [0, 0], [1, 1], [True, True]) is None
    assert mon.sample(4.5, [70, 70], [0, 0], [1, 1], [True, True]) == [(1, 2)]
    # an aborted resize backs off 4x the cooldown from its report time
    mon.resize_result(4.5, aborted=True)
    assert mon.backoffs == 1
    assert mon.sample(11.0, [80, 80], [0, 0], [1, 1], [True, True]) is None
    assert mon.sample(13.0, [90, 90], [0, 0], [1, 1], [True, True]) == [(1, 2)]


def test_traffic_monitor_funds_grow_by_shrinking_idle_stage():
    """With no spare budget the grow proposal must lead with a donor shrink
    (shrink listed first so the supervisor frees budget before spending)."""
    mon = _traffic_fixture(patience=1)
    mon.budget = 3
    _feed_rate(mon, 1700)
    # widths [2, 1] exhaust the budget; keyed stage 1 is drowning (util
    # 1.7), stateless stage 0 is near-idle -> donate one of its workers
    prop = mon.sample(2.0, [50, 50], [0, 24], [2, 1], [True, True])
    assert prop == [(0, 1), (1, 2)], prop


def test_traffic_monitor_rejects_empty_hysteresis_band():
    with pytest.raises(ValueError):
        _traffic_fixture(grow_util=0.5, shrink_util=0.5)
    with pytest.raises(ValueError):
        _traffic_fixture(grow_util=0.4, shrink_util=0.6)


def test_traffic_monitor_rate_counts_unabsorbed_ingress():
    """Offered load the runtime failed to admit (tuples parked in the mux's
    DRR queues) must still count toward the rate EWMA — measuring only the
    admitted delta would read *harder* saturation as *lower* load."""
    mon = _traffic_fixture(patience=1)
    mon.ingest({"ts": 0.0, "sessions": 2, "admitted_total": 0,
                "ingress_queued": 0, "backpressured": 0})
    # 100 admitted + queue grew by 400: offered was 500/s, not 100/s
    mon.ingest({"ts": 1.0, "sessions": 2, "admitted_total": 100,
                "ingress_queued": 400, "backpressured": 0})
    assert mon.rate == pytest.approx(500.0)


# ---------------------------------------------------------- elastic replanning
@pytest.mark.timeout(60)
def test_elastic_replan_reforks_at_new_width_exact_output():
    """Deliberately wrong priors under-provision the hot stage; the
    supervisor must detect the drift, quiesce at a serial boundary, migrate
    keyed state through the handoff, re-fork at the corrected widths — and
    the egress must still equal the sequential reference exactly."""
    specs = [
        OpSpec("hot", "stateless", _spin_hot, cost_us=1),  # actually ~30 µs
        OpSpec("cold", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=80),  # actually ~2
    ]
    src = _zipf_values(25000, seed=7)
    ref, _ = run_pipeline(specs, src, num_workers=1, collect_outputs=True)
    rt = ProcessRuntime.from_chain(
        specs, num_workers="auto", worker_budget=3, collect_outputs=True,
        cost_priors={"hot": 1.0, "cold": 80.0},
        replan_interval=0.05, replan_patience=2, batch_size=32,
    )
    assert rt.stage_widths() == [1, 2]  # the lie: cold got the spare worker
    report = rt.run(src)
    assert rt.replans >= 1, "no elastic replan event fired"
    assert rt.stage_widths()[0] >= 2, rt.stage_widths()  # hot stage re-forked wider
    assert rt.outputs == ref.outputs
    assert report.tuples_in == len(src)


@pytest.mark.timeout(60)
def test_elastic_disabled_keeps_widths_fixed():
    specs = [
        OpSpec("hot", "stateless", _spin_hot, cost_us=1),
        OpSpec("cold", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=80),
    ]
    src = list(range(1, 4000))
    ref, _ = run_pipeline(specs, src, num_workers=1, collect_outputs=True)
    rt = ProcessRuntime.from_chain(
        specs, num_workers="auto", worker_budget=3, collect_outputs=True,
        cost_priors={"hot": 1.0, "cold": 80.0}, elastic=False,
    )
    widths0 = rt.stage_widths()
    rt.run(src)
    assert rt.replans == 0
    assert rt.stage_widths() == widths0
    assert rt.outputs == ref.outputs
