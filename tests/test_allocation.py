"""Skew/allocation test battery (ISSUE 4 tentpole contract).

Zipf-skewed keyed chains driven through flat vs. cost-model ("auto") worker
allocation across micro-batch sizes and stage shapes must produce output
exactly equal to the thread backend — and the allocator must give the hot
stage at least as many workers as any cold data-parallel stage.  Plus unit
coverage of the proportional allocator, calibration, the occupancy monitor's
drift detection, and an end-to-end elastic-replan run (quiesce at a serial
boundary, keyed state migration, re-fork at a new width).

Process tests ride the 60 s watchdog like the rest of the process-backend
suite.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    CostModel,
    OpSpec,
    OccupancyMonitor,
    ProcessRuntime,
    proportional_allocation,
    resolve_workers,
    run_pipeline,
)
from repro.core.procrun import _chain_nodes, _plan_stages


# ------------------------------------------------- fork/pickle-safe operators
def _double(v):
    return [v * 2 + 1]


def _fan2(v):
    return [v, v + 3]


def _drop5(v):
    return [v] if v % 5 else []


def _mod11(v):
    return v % 11


def _fst(t):
    return t[0]


def _zero():
    return 0


def _tup_inc(t):  # stateless over keyed output tuples
    return [(t[0], t[1] + 3)]


def _tup_drop5(t):
    return [t] if t[1] % 5 else []


def _ksum(s, k, v):
    s = (s or 0) + (v if isinstance(v, int) else v[1])
    return s, [(k, s % 99991)]


def _kcount(s, k, t):
    s = (s or 0) + 1
    return s, [(k, s, t[1] % 997)]


def _count(s, t):
    return s + 1, [(s, t[1])]


def _spin_hot(v):
    x = float(v)
    for _ in range(400):
        x = (x * 1.0000001 + 1.31) % 97.0
    return [int(x * 1000)]


# Stage shapes: (specs builder, {op name: cost_us} priors, hot stage index).
# Remember the planner's stage grammar: a leading stateless run is stage 0,
# every partitioned/stateful op heads a new stage and absorbs its trailing
# stateless run.
def _shape_interior_hot():
    specs = [
        OpSpec("pre", "stateless", _double, cost_us=2),
        OpSpec("hot", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=120),
        OpSpec("post", "stateless", _tup_inc, cost_us=2),
    ]
    return specs, {"pre": 2, "hot": 120, "post": 2}, 1


def _shape_leading_keyed_hot():
    specs = [
        OpSpec("hot", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=90),
        OpSpec("mid", "stateless", _tup_drop5, cost_us=2),
        OpSpec("cold", "partitioned", _kcount, key_fn=_fst,
               num_partitions=22, init_state=_zero, cost_us=3),
    ]
    return specs, {"hot": 90, "mid": 2, "cold": 3}, 0


def _shape_hot_prefix():
    specs = [
        OpSpec("hot", "stateless", _double, cost_us=150),
        OpSpec("cold", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=4),
        OpSpec("tail", "stateful", _count, init_state=_zero, cost_us=1),
    ]
    return specs, {"hot": 150, "cold": 4, "tail": 1}, 0


SHAPES = {
    "interior_hot": _shape_interior_hot,
    "leading_keyed_hot": _shape_leading_keyed_hot,
    "hot_prefix": _shape_hot_prefix,
}


def _zipf_values(n: int, seed: int, skew: float = 2.0, universe: int = 400):
    """Deterministic zipf-skewed int stream (hot keys dominate — the keyed
    load imbalance the battery drives through both allocations)."""
    rng = random.Random(seed)
    return [
        1 + min(int(universe * (rng.random() ** skew)), universe - 1)
        for _ in range(n)
    ]


# -------------------------------------------------- allocator unit/properties
@settings(max_examples=20, deadline=None)
@given(
    loads=st.lists(st.integers(min_value=0, max_value=1000), min_size=1,
                   max_size=6),
    budget=st.integers(min_value=0, max_value=12),
    cap=st.integers(min_value=1, max_value=4),
)
def test_property_proportional_allocation_invariants(loads, budget, cap):
    n = len(loads)
    mins = [1] * n
    caps = [cap] * n
    widths = proportional_allocation([float(l) for l in loads], budget,
                                     mins, caps)
    assert len(widths) == n
    assert all(mins[i] <= widths[i] <= caps[i] for i in range(n))
    assert sum(widths) <= max(budget, sum(mins))
    # monotone in load: an uncapped hotter stage never gets fewer workers
    for i in range(n):
        for j in range(n):
            if loads[i] > loads[j] and widths[i] < caps[i]:
                assert widths[i] >= widths[j], (loads, widths)


def test_allocation_pins_stateful_and_caps_keyed():
    specs, priors, _hot = _shape_hot_prefix()
    nodes, edges = _chain_nodes(specs)
    plans, _, _ = _plan_stages(nodes, edges, 1, None)
    model = CostModel(plans, priors)
    widths = model.allocate(budget=8)
    # stateful stage pinned at 1 regardless of leftover budget
    assert widths[[p.kind for p in plans].index("stateful")] == 1
    # the hot stage soaked up the budget
    assert widths[0] == max(widths)
    assert sum(widths) <= 8
    # keyed cap: partition count bounds the keyed stage
    assert widths[1] <= 22


def test_resolve_workers_auto_and_validation():
    assert resolve_workers(3) == 3
    assert resolve_workers("auto") >= 2
    assert resolve_workers("auto", budget=7) == 7
    with pytest.raises(ValueError):
        resolve_workers("many")


# --------------------------------------------- the zipf flat-vs-auto battery
@pytest.mark.timeout(60)
@settings(max_examples=4, deadline=None)
@given(
    shape=st.sampled_from(sorted(SHAPES)),
    batch_size=st.sampled_from([1, 7, 32]),
    n=st.integers(min_value=40, max_value=350),
    skew=st.sampled_from([15, 25]),  # zipf exponent x10
    seed=st.integers(min_value=0, max_value=5),
)
def test_property_zipf_flat_vs_auto_exact_equality(shape, batch_size, n,
                                                   skew, seed):
    """Flat AND auto allocation must both reproduce the thread backend's
    egress exactly on zipf-skewed keyed chains, for batch_size {1, 7, 32}
    across stage shapes; the allocator must give the hot stage >= as many
    workers as any cold data-parallel stage."""
    specs, priors, hot = SHAPES[shape]()
    src = _zipf_values(n, seed=seed, skew=skew / 10.0)
    ref, _ = run_pipeline(
        specs, src, num_workers=2, collect_outputs=True, backend="thread"
    )
    flat, _ = run_pipeline(
        specs, src, num_workers=2, collect_outputs=True,
        backend="process", batch_size=batch_size,
    )
    assert flat.outputs == ref.outputs
    auto, _ = run_pipeline(
        specs, src, num_workers="auto", worker_budget=4, cost_priors=priors,
        collect_outputs=True, backend="process", batch_size=batch_size,
    )
    assert auto.outputs == ref.outputs
    widths = auto.stage_widths()
    dp = [i for i, p in enumerate(auto.stage_plans) if p.kind != "stateful"]
    assert all(widths[hot] >= widths[i] for i in dp), (widths, hot)
    assert widths[hot] >= 2  # budget 4 over <=2 dp stages: hot gets spare


@pytest.mark.timeout(60)
def test_calibration_profiles_real_costs_without_priors():
    """workers='auto' with no priors: the calibration dry run must measure
    the hot stateless prefix and hand it the spare budget — and the profiled
    warm-up must not disturb the stream (exact output equality)."""
    specs = [
        OpSpec("hot", "stateless", _spin_hot),  # declared cost_us defaults!
        OpSpec("cold", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero),
    ]
    src = _zipf_values(2500, seed=3)
    ref, _ = run_pipeline(specs, src, num_workers=1, collect_outputs=True)
    rt, report = run_pipeline(
        specs, src, num_workers="auto", worker_budget=3,
        backend="process", collect_outputs=True, batch_size=16,
        # pin the calibrated widths: this test asserts what the dry run
        # measured, and a live replan (e.g. from coverage-tracer-distorted
        # occupancy) would overwrite them — the monitor has its own tests
        replan_interval=300.0,
    )
    assert rt.outputs == ref.outputs
    assert report.tuples_in == len(src)
    widths = rt.stage_widths()
    assert widths[0] > widths[1], widths  # measured, not declared, costs won
    assert rt.cost_model.profiles[0].measured


# ------------------------------------------------------- occupancy monitoring
def test_occupancy_monitor_proposes_growing_the_hot_stage():
    specs, priors, _hot = _shape_interior_hot()
    nodes, edges = _chain_nodes(specs)
    plans, _, _ = _plan_stages(nodes, edges, 1, None)
    model = CostModel(plans, {"pre": 2, "hot": 2, "post": 2})  # wrong priors
    mon = OccupancyMonitor(model, budget=3, interval=0.0, patience=2)
    widths, resizable = [1, 1], [True, True]
    # stage 1 drains slowly with a dominant backlog; stage 0 keeps pace
    proposal = None
    for tick in range(1, 6):
        proposal = mon.sample(
            now=float(tick),
            drained=[tick * 1000, tick * 50],
            backlog=[0, 64],
            widths=widths,
            resizable=resizable,
        )
        if proposal:
            break
    assert proposal, "monitor never reacted to sustained occupancy drift"
    assert dict(proposal).get(1) == 2, proposal  # grow the hot keyed stage
    assert model.profiles[1].measured  # live rates replaced the bad prior


def test_occupancy_monitor_ignores_unaddressable_drift():
    specs, priors, _hot = _shape_hot_prefix()
    nodes, edges = _chain_nodes(specs)
    plans, _, _ = _plan_stages(nodes, edges, 1, None)
    model = CostModel(plans, priors)
    mon = OccupancyMonitor(model, budget=3, interval=0.0, patience=1)
    for tick in range(1, 5):
        proposal = mon.sample(
            now=float(tick),
            drained=[tick * 100, tick * 100, tick * 90],
            backlog=[0, 0, 64],  # the STATEFUL stage is hot: nothing to do
            widths=[1, 1, 1],
            resizable=[True, True, False],
        )
        assert not proposal


# ---------------------------------------------------------- elastic replanning
@pytest.mark.timeout(60)
def test_elastic_replan_reforks_at_new_width_exact_output():
    """Deliberately wrong priors under-provision the hot stage; the
    supervisor must detect the drift, quiesce at a serial boundary, migrate
    keyed state through the handoff, re-fork at the corrected widths — and
    the egress must still equal the sequential reference exactly."""
    specs = [
        OpSpec("hot", "stateless", _spin_hot, cost_us=1),  # actually ~30 µs
        OpSpec("cold", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=80),  # actually ~2
    ]
    src = _zipf_values(25000, seed=7)
    ref, _ = run_pipeline(specs, src, num_workers=1, collect_outputs=True)
    rt = ProcessRuntime.from_chain(
        specs, num_workers="auto", worker_budget=3, collect_outputs=True,
        cost_priors={"hot": 1.0, "cold": 80.0},
        replan_interval=0.05, replan_patience=2, batch_size=32,
    )
    assert rt.stage_widths() == [1, 2]  # the lie: cold got the spare worker
    report = rt.run(src)
    assert rt.replans >= 1, "no elastic replan event fired"
    assert rt.stage_widths()[0] >= 2, rt.stage_widths()  # hot stage re-forked wider
    assert rt.outputs == ref.outputs
    assert report.tuples_in == len(src)


@pytest.mark.timeout(60)
def test_elastic_disabled_keeps_widths_fixed():
    specs = [
        OpSpec("hot", "stateless", _spin_hot, cost_us=1),
        OpSpec("cold", "partitioned", _ksum, key_fn=_mod11,
               num_partitions=22, init_state=_zero, cost_us=80),
    ]
    src = list(range(1, 4000))
    ref, _ = run_pipeline(specs, src, num_workers=1, collect_outputs=True)
    rt = ProcessRuntime.from_chain(
        specs, num_workers="auto", worker_budget=3, collect_outputs=True,
        cost_priors={"hot": 1.0, "cold": 80.0}, elastic=False,
    )
    widths0 = rt.stage_widths()
    rt.run(src)
    assert rt.replans == 0
    assert rt.stage_widths() == widths0
    assert rt.outputs == ref.outputs
