"""DAG dataflow tests: split/merge ordered egress (Def. 5.1 generalized to
graphs) + scheduler-budget contract regressions for the hybrid worklist."""
import collections
import threading
import time

import pytest

from repro.core import (
    GraphPipeline,
    HybridQueueWorklist,
    Merge,
    OpSpec,
    Split,
    StreamRuntime,
    run_graph,
    run_pipeline,
)


# ------------------------------------------------------- sequential DAG oracle
def _graph_sequential_reference(nodes, edges, source):
    """Single-threaded oracle for a GraphPipeline: route each tuple through
    the graph depth-first, one at a time, with keyed/round-robin splits."""
    succ = collections.defaultdict(list)
    pred = collections.defaultdict(list)
    for u, v in edges:
        succ[u].append(v)
        pred[v].append(u)
    (src_name,) = [n for n in nodes if not pred[n]]
    states = {n: {} for n in nodes}
    rr = {n: 0 for n, s in nodes.items() if isinstance(s, Split)}
    out = []

    def run_spec(name, value):
        s = nodes[name]
        if s.kind == "stateless":
            return s.fn(value)
        if s.kind == "stateful":
            st = states[name].get("_", s.init_state())
            st, outs = s.fn(st, value)
            states[name]["_"] = st
            return outs
        key = s.key_fn(value)
        st = states[name].get(key)
        if st is None:
            st = s.init_state()
        st, outs = s.fn(st, key, value)
        states[name][key] = st
        return outs

    def visit(name, value):
        spec = nodes[name]
        if isinstance(spec, Split):
            if spec.policy == "round_robin":
                b = rr[name] % len(succ[name])
                rr[name] += 1
            else:
                b = hash(spec.key_fn(value)) % len(succ[name])
            visit(succ[name][b], value)
            return
        if isinstance(spec, Merge):
            nxt = succ[name]
            if nxt:
                visit(nxt[0], value)
            else:
                out.append(value)
            return
        for o in run_spec(name, value):
            if succ[name]:
                visit(succ[name][0], o)
            else:
                out.append(o)

    for v in source:
        visit(src_name, v)
    return out


def _diamond(policy="round_robin", reorder_size=16):
    """split -> (flat-map branch || filter branch) -> merge -> count."""
    key_fn = (lambda v: v % 2) if policy == "keyed" else None
    return {
        "ingest": OpSpec("ingest", "stateless", lambda v: [v]),
        "split": Split(policy, key_fn=key_fn),
        "fan": OpSpec(
            "fan", "stateless", lambda v: [(v, j) for j in range(3)], selectivity=3.0
        ),
        "filt": OpSpec(
            "filt", "stateless", lambda v: [(v, -1)] if v % 3 else [], selectivity=0.6
        ),
        "merge": Merge(reorder_size=reorder_size),
        "count": OpSpec(
            "count",
            "stateful",
            lambda s, t: (s + 1, [(t, s + 1)]),
            init_state=lambda: 0,
        ),
    }, [
        ("ingest", "split"),
        ("split", "fan"),
        ("split", "filt"),
        ("fan", "merge"),
        ("filt", "merge"),
        ("merge", "count"),
    ]


@pytest.mark.parametrize("policy", ["round_robin", "keyed"])
@pytest.mark.parametrize("workers", [1, 4, 6])
def test_dag_split_merge_matches_sequential_oracle(policy, workers):
    nodes, edges = _diamond(policy)
    source = list(range(1, 500))
    expected = _graph_sequential_reference(*_diamond(policy), source)
    pipe, report = run_graph(
        nodes, edges, source, num_workers=workers, collect_outputs=True
    )
    assert pipe.outputs == expected
    assert report.tuples_in == len(source)


@pytest.mark.parametrize("heuristic", ["ct", "lp", "et", "qst", "adaptive"])
def test_dag_all_heuristics_ordered(heuristic):
    nodes, edges = _diamond("round_robin")
    source = list(range(1, 300))
    expected = _graph_sequential_reference(*_diamond("round_robin"), source)
    pipe, _ = run_graph(
        nodes, edges, source, num_workers=4, heuristic=heuristic, collect_outputs=True
    )
    assert pipe.outputs == expected


def test_dag_tiny_merge_ring_no_livelock_single_worker():
    """A merge ring much smaller than the in-flight ticket count must not
    livelock a lone worker (overflow completions park, never spin)."""
    nodes, edges = _diamond("round_robin", reorder_size=2)
    source = list(range(1, 400))
    expected = _graph_sequential_reference(
        *_diamond("round_robin", reorder_size=2), source
    )
    pipe, _ = run_graph(nodes, edges, source, num_workers=1, collect_outputs=True)
    assert pipe.outputs == expected


def test_dag_keyed_split_partitioned_branches_per_key_state():
    """Partitioned-stateful ops inside keyed branches keep per-key state and
    arrival order; merge restores the global serial order."""

    def running_sum(state, key, v):
        s = (state or 0) + v
        return s, [(key, s)]

    def mk():
        return {
            "split": Split("keyed", key_fn=lambda v: v % 5),
            "a": OpSpec(
                "sum_a", "partitioned", running_sum,
                key_fn=lambda v: v % 5, num_partitions=8, init_state=lambda: 0,
            ),
            "b": OpSpec(
                "sum_b", "partitioned", running_sum,
                key_fn=lambda v: v % 5, num_partitions=8, init_state=lambda: 0,
            ),
            "merge": Merge(),
        }, [("split", "a"), ("split", "b"), ("a", "merge"), ("b", "merge")]

    source = list(range(1, 1000))
    expected = _graph_sequential_reference(*mk(), source)
    pipe, _ = run_graph(*mk(), source, num_workers=4, collect_outputs=True)
    assert pipe.outputs == expected


def test_dag_equals_linear_tpcxbb():
    """DAG forms of the TPCx-BB queries produce byte-identical egress to the
    linear single-threaded reference (acceptance criterion)."""
    from repro.streams.tpcxbb import DAG_QUERIES, QUERIES

    for qname, builder in DAG_QUERIES.items():
        n = 2500
        specs, src = QUERIES[qname](n=n)
        lin, _ = run_pipeline(specs, list(src), num_workers=1, collect_outputs=True)
        nodes, edges, src2 = builder(n=n)
        dag, _ = run_graph(nodes, edges, list(src2), num_workers=4, collect_outputs=True)
        assert dag.outputs == lin.outputs, qname


def test_compiled_pipeline_is_graph_wrapper():
    from repro.core import CompiledPipeline

    pipe = CompiledPipeline(
        [OpSpec("double", "stateless", lambda v: [v * 2])], collect_outputs=True
    )
    assert isinstance(pipe, GraphPipeline)
    rt = StreamRuntime(pipe, num_workers=2)
    rt.run(range(50))
    assert pipe.outputs == [v * 2 for v in range(50)]


def test_adaptive_controller_resizes_caps():
    nodes, edges = _diamond("round_robin")
    g = GraphPipeline(nodes, edges, num_workers=4, collect_outputs=True)
    rt = StreamRuntime(g, num_workers=4, heuristic="adaptive", adapt_interval=0.001)
    rt.run(list(range(1, 2000)))
    assert rt.scheduler.adaptations > 0
    # caps were resized to finite values and never below 1
    assert all(1 <= n.dop_cap for n in g.nodes)
    assert any(n.dop_cap <= 4 for n in g.nodes)


# ----------------------------------------------------- hybrid budget contract
def test_hybrid_consume_respects_budget_under_delegation():
    """Regression: the active worker's drain loop must stop at ``budget`` even
    under sustained delegation (scheduler time-slice contract)."""
    wl = HybridQueueWorklist(1, lambda k: 0)
    n = 3000
    for s in range(1, n + 1):
        wl.add(s, 0, s)

    budget = 16
    overruns = []
    processed = collections.defaultdict(list)

    def worker(wid):
        while True:
            got = wl.consume(
                wid, lambda s, k, v: processed[wid].append(s), budget
            )
            if got > budget:
                overruns.append((wid, got))
            if got == 0 and len(wl) == 0:
                return

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not overruns, f"consume exceeded budget: {overruns[:5]}"
    everything = sorted(s for lst in processed.values() for s in lst)
    assert everything == list(range(1, n + 1)), "lost/duplicated tuples on handoff"


def test_hybrid_budget_handoff_preserves_order():
    """Deterministic handoff: a worker whose slice expires mid-drain (with
    delegations pending) returns exactly ``budget``; the abandoned tuples are
    re-tokenized and processed exactly once, in arrival order, by later
    consumers."""
    wl = HybridQueueWorklist(1, lambda k: 0)
    n = 20
    for s in range(1, n + 1):
        wl.add(s, 0, s)

    order = []
    started = threading.Event()
    go = threading.Event()

    def slow_op(serial, key, v):
        order.append(serial)
        if len(order) == 1:
            started.set()
            go.wait(10)  # hold the partition while the main thread delegates

    result = {}

    def t1():
        result["got"] = wl.consume(0, slow_op, 2)

    th = threading.Thread(target=t1)
    th.start()
    assert started.wait(10)
    # main thread: every pop now delegates to the (stalled) active worker
    assert wl.consume(1, slow_op, 10**9) == 0
    assert wl.delegated > 0
    go.set()
    th.join(timeout=10)
    # slice contract: exactly budget tuples processed, then handoff
    assert result["got"] == 2
    # the re-appended tokens let later consumers finish the partition
    while len(wl):
        wl.consume(1, lambda s, k, v: order.append(s), 7)
    assert order == list(range(1, n + 1))


def test_concurrent_producers_inject_all_markers():
    """Regression: ingress counting is atomic — concurrent producers must not
    lose marker injections."""
    pipe = GraphPipeline(
        {"id": OpSpec("id", "stateless", lambda v: [v])},
        [],
        marker_interval=10,
        collect_outputs=True,
    )
    rt = StreamRuntime(pipe, num_workers=2)
    rt.start()
    n_per, threads = 500, 4

    def producer():
        for i in range(n_per):
            pipe.push(i)

    ps = [threading.Thread(target=producer) for _ in range(threads)]
    for p in ps:
        p.start()
    for p in ps:
        p.join()
    deadline = time.time() + 30
    while not pipe.drained() and time.time() < deadline:
        time.sleep(1e-3)
    rt.stop()
    assert pipe.egress_count == n_per * threads
    assert len(pipe.markers) == (n_per * threads) // 10
