"""Columnar subsystem battery: Schema/ColumnBlock units, wire-codec
round-trips (TAG_COLBLOCK and the widened TAG_TUPS raw path), the
columnar-vs-pickle exact-equality matrix through real process pipelines,
and the DeviceOp ordered-egress bit-identity contract against the
pure-NumPy reference (integer schemas, so jax and NumPy agree bitwise —
see docs/columnar.md for why float columns only agree to the last ulp).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import Engine, EngineConfig, OpSpec, ProcessOptions
from repro.core import shm
from repro.columnar import (
    ColumnBlock,
    ColumnarCodec,
    DeviceExecutor,
    Schema,
    decode_block,
    device_op,
    encode_block,
    have_jax,
    ref_apply,
)


# ---------------------------------------------------------------- operators
def _ident(v):
    return [v]


def _widen(v):
    return [(v, v * 3, float(v) * 0.5)]


def _tup_map(t):
    return [(t[0] * 2 + 1, t[1] - 7, t[2] + 0.25)]


def _narrow(t):
    return [t[0] + t[1]]


def _mod5(t):
    return t[0] % 5


def _zero():
    return 0


def _ksum(s, k, t):
    s += t[0]
    return s, [(s, t[1], t[2])]


# ------------------------------------------------------------- schema units
def test_schema_infer_and_width_rules():
    assert Schema.infer(3) == Schema((("c0", "i8"),), scalar=True)
    assert Schema.infer(0.5) == Schema((("c0", "f8"),), scalar=True)
    assert Schema.infer((1, 2.0)) == Schema.of("i8", "f8")
    # bools, ragged, and object cells are non-columnar by design
    assert Schema.infer(True) is None
    assert Schema.infer((1, True)) is None
    assert Schema.infer("x") is None
    assert Schema.infer(()) is None
    assert Schema.of("i8", "f8").row_bytes == 16
    assert Schema.of("i4", "f4").row_bytes == 8
    with pytest.raises(ValueError):
        Schema.of("i8", "i8", scalar=True)  # scalar schemas are width 1
    with pytest.raises(ValueError):
        Schema.of("u2")  # unknown code


def test_block_round_trip_and_slicing():
    vals = [(i, i * 3, i + 0.5) for i in range(10)]
    marks = [(0, "m0"), (7, "m7")]
    blk = ColumnBlock.from_values(vals, head_serial=100, marks=marks)
    assert blk is not None and len(blk) == 10
    assert blk.head_serial == 100 and blk.contiguous_serials()
    assert blk.to_values() == vals

    # wire round-trip preserves rows, serials, marks
    rt = decode_block(encode_block(blk))
    assert rt.to_values() == vals
    assert rt.head_serial == 100 and rt.contiguous_serials()
    assert rt.marks == marks

    # slicing is zero-copy and re-offsets marks
    sl = blk.slice(5, 9)
    assert sl.to_values() == vals[5:9]
    assert sl.head_serial == 105
    assert sl.marks == [(2, "m7")]
    assert sl.columns[0].base is not None  # a view, not a copy

    # non-contiguous serials survive the wire (explicit-serials flag)
    gap = ColumnBlock.concat([blk.slice(0, 2), blk.slice(6, 8)])
    assert not gap.contiguous_serials()
    rt2 = decode_block(encode_block(gap))
    assert rt2.to_values() == vals[0:2] + vals[6:8]
    assert list(rt2.serials) == [100, 101, 106, 107]


def test_block_builder_rejects_nonconforming_rows():
    assert ColumnBlock.from_values([]) is None
    assert ColumnBlock.from_values([(1, 2), (1, 2, 3)]) is None  # ragged
    assert ColumnBlock.from_values([(1, 2), (1, "x")]) is None  # object cell
    assert ColumnBlock.from_values([1, 2.0]) is None  # mixed scalar types
    assert ColumnBlock.from_values([(1, True)]) is None  # bool is not int
    # i8 overflow falls back rather than wrapping silently
    assert ColumnBlock.from_values([(1 << 70,)]) is None


def test_codec_locks_schema_and_counts_fallbacks():
    codec = ColumnarCodec()
    enc = codec.try_encode_unit([(1, 2.0), (3, 4.0)], [], 1)
    assert enc is not None and codec.schema == Schema.of("i8", "f8")
    # later units must conform to the locked schema
    assert codec.try_encode_unit([(1, 2)], [], 3) is None
    assert codec.fallbacks == 1
    payload, span = enc
    assert span == 2
    assert decode_block(payload).to_values() == [(1, 2.0), (3, 4.0)]


# ----------------------------------------------------- TAG_TUPS raw fast path
@settings(max_examples=40, deadline=None)
@given(
    rows=st.lists(
        st.tuples(
            st.integers(min_value=-(2 ** 62), max_value=2 ** 62),
            st.floats(min_value=-1e9, max_value=1e9),
            st.integers(min_value=-5, max_value=5),
        ),
        min_size=1,
        max_size=40,
    )
)
def test_tups_raw_path_round_trips_exactly(rows):
    """Homogeneous small int/float tuples take the raw struct path and
    round-trip bit-exactly (the widened shm fast-path satellite)."""
    tag, data = shm.encode_bundle(rows)
    assert tag == shm.TAG_TUPS
    assert shm.decode_bundle(tag, data) == rows


def test_tups_fallback_cases_stay_pickle():
    # bool column, ragged rows, oversize ints, wide tuples -> pickle
    for outs in (
        [(1, True)],
        [(1, 2), (3,)],
        [(1 << 70, 2)],
        [tuple(range(17))],
    ):
        tag, _ = shm.encode_bundle(outs)
        assert tag == shm.TAG_PICKLE
    # and decode still inverts whatever encode chose
    for outs in ([(1, 2.5)], [(7,), (8,)], [("a", 1)]):
        tag, data = shm.encode_bundle(outs)
        assert shm.decode_bundle(tag, data) == outs


# ----------------------------------------- columnar-vs-pickle equality matrix
def _chain():
    """Numeric chain with a keyed interior stage: scalar -> wide tuple ->
    tuple map -> keyed running sum -> narrow."""
    return [
        OpSpec("widen", "stateless", _widen, cost_us=2.0),
        OpSpec("tmap", "stateless", _tup_map, cost_us=2.0),
        OpSpec("ksum", "partitioned", _ksum, key_fn=_mod5,
               num_partitions=10, init_state=_zero, cost_us=2.0),
        OpSpec("narrow", "stateless", _narrow, cost_us=2.0),
    ]


def _run_process(columnar: bool, batch_size: int, source):
    eng = Engine(EngineConfig(
        backend="process", num_workers=2, batch_size=batch_size,
        collect_outputs=True,
        process=ProcessOptions(columnar=columnar),
    ))
    return eng.run(list(_chain()), source).handle().outputs


@pytest.mark.timeout(90)
@pytest.mark.parametrize("batch_size", [1, 7, 32])
def test_columnar_egress_equals_pickle_egress(batch_size):
    """The columnar wire path is invisible: exact equality (content AND
    order) with the pickle path across micro-batch sizes, through a chain
    with a keyed stage (keyed dispatch always falls back to pickle — the
    block path must compose with it, not replace it)."""
    source = list(range(201))
    base = _run_process(False, batch_size, source)
    col = _run_process(True, batch_size, source)
    assert col == base
    # and both equal the thread backend's reference egress
    eng = Engine(EngineConfig(backend="thread", num_workers=2,
                              batch_size=batch_size, collect_outputs=True))
    ref = eng.run(list(_chain()), source).handle().outputs
    assert col == ref


# ------------------------------------------- device ordered-egress property
def _device_chain(backend: str, kernel: str = "affine"):
    return [
        OpSpec("widen2", "stateless", _pair, cost_us=1.0),
        device_op("dev", kernel, Schema.of("i8", "i8"),
                  params={"a": 3, "b": -1}, backend=backend, cost_us=4.0),
        OpSpec("fold", "stateless", _fold, cost_us=1.0),
    ]


def _pair(v):
    return [(v, v * 2)]


def _fold(t):
    return [t[0] - t[1]]


def _device_reference(source):
    out = []
    for v in source:
        (t,) = _pair(v)
        (r,) = ref_apply(t, "affine", (("a", 3), ("b", -1)),
                         Schema.of("i8", "i8"))
        out.extend(_fold(r))
    return out


@pytest.mark.timeout(120)
@pytest.mark.parametrize("batch_size", [1, 7, 32])
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_device_egress_bit_identical_to_reference(backend, batch_size):
    """Device-stage egress is exactly ordered and bit-identical to the
    per-value NumPy reference, for both kernel backends, regardless of how
    device batches regroup dispatch units (integer schema: jax int math is
    exact, so cross-backend equality is bitwise)."""
    if backend == "jax" and not have_jax():
        pytest.skip("jax not installed; numpy reference backend still covers "
                    "the device path")
    source = list(range(157))
    eng = Engine(EngineConfig(
        backend="process", num_workers=2, batch_size=batch_size,
        collect_outputs=True,
        process=ProcessOptions(columnar=True, device_batch=64,
                               device_backend=backend),
    ))
    out = eng.run(list(_device_chain(backend)), source).handle().outputs
    assert out == _device_reference(source)


@pytest.mark.timeout(120)
def test_device_pallas_kernel_matches_reference_end_to_end():
    """The pallas-lowered kernel (interpret mode) is egress-identical to
    the NumPy reference through a real process pipeline."""
    if not have_jax():
        pytest.skip("jax not installed; pallas kernels need jax")
    source = list(range(100))
    eng = Engine(EngineConfig(
        backend="process", num_workers=2, batch_size=16,
        collect_outputs=True,
        process=ProcessOptions(columnar=True, device_batch=32,
                               device_backend="jax"),
    ))
    out = eng.run(
        list(_device_chain("jax", kernel="affine_pallas")), source
    ).handle().outputs
    assert out == _device_reference(source)


@settings(max_examples=15, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=9), min_size=1,
                   max_size=20),
    batch=st.integers(min_value=1, max_value=16),
)
def test_device_executor_preserves_unit_boundaries(sizes, batch):
    """DeviceExecutor splits completed batches back into the exact submitted
    units — serials and marks untouched — however units regroup into
    device batches."""
    spec = device_op("dev", "affine", Schema.of("i8", scalar=True),
                     params={"a": 2, "b": 1}, backend="numpy")
    ex = DeviceExecutor(spec, batch=batch, inflight=2)
    serial = 1
    submitted = []
    outs = []
    for n in sizes:
        vals = list(range(serial, serial + n))
        marks = [(0, f"mark{serial}")]
        blk = ColumnBlock.from_values(vals, head_serial=serial, marks=marks,
                                      schema=spec.schema)
        submitted.append((serial, vals, marks))
        outs.extend(ex.submit(blk))
        serial += n
    outs.extend(ex.flush())
    assert ex.pending_rows == 0 and ex.inflight == 0
    assert len(outs) == len(submitted)
    for blk, (head, vals, marks) in zip(outs, submitted):
        assert blk.head_serial == head and blk.contiguous_serials()
        assert blk.to_values() == [v * 2 + 1 for v in vals]
        assert blk.marks == marks


def test_device_op_rejects_bad_construction():
    with pytest.raises(ValueError):
        device_op("d", "no_such_kernel", Schema.of("i8"))
    with pytest.raises(ValueError):
        # device ops are 1:1 — a filtering device op would make partial-batch
        # flushes observable
        OpSpec("d", "device", _ident, selectivity=0.5,
               schema=Schema.of("i8"), device_kernel=("affine", ()))
    with pytest.raises(ValueError):
        OpSpec("d", "device", _ident)  # no kernel/schema
    with pytest.raises(TypeError):
        ref_apply("not numeric", "affine", (), Schema.of("i8", scalar=True))


@pytest.mark.timeout(120)
def test_jax_device_fork_hazard_fails_fast_not_deadlock():
    """A parent process that already initialized a jax backend cannot fork
    jax device workers — the child would deadlock on inherited XLA
    threadpool locks.  The runtime must detect this and raise immediately
    (instead of the opaque 60s drain timeout), and a jax-free parent must
    report no hazard.  Runs in a subprocess so the pytest process itself
    never initializes jax (which would poison every later test the same
    way — the original trigger was a module-level PRNGKey created at
    collection time)."""
    if not have_jax():
        pytest.skip("jax not installed; the hazard needs a jax parent")
    import os
    import subprocess
    import sys

    script = """
import time
from repro.columnar import jax_fork_hazard
assert not jax_fork_hazard(), "import-only parent must be hazard-free"
import jax
jax.random.PRNGKey(0)  # initializes the CPU client: the hazard
assert jax_fork_hazard()
from repro.core import Engine, EngineConfig, ProcessOptions
from repro.columnar import Schema, device_op
ops = [device_op("dev", "affine", Schema.of("i8", scalar=True),
                 params={"a": 2, "b": 1}, backend="jax")]
eng = Engine(EngineConfig(
    backend="process", num_workers=1, batch_size=4, collect_outputs=True,
    process=ProcessOptions(columnar=True, device_batch=8,
                           device_backend="jax"),
))
t0 = time.monotonic()
try:
    eng.run(ops, list(range(32)))
except RuntimeError as exc:
    assert "fork" in str(exc) and "numpy" in str(exc), exc
    assert time.monotonic() - t0 < 30, "guard must fire fast, not drain out"
    print("GUARDED")
else:
    raise SystemExit("expected the fork-hazard guard to raise")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=110, cwd=repo,
        env={**os.environ, "PYTHONPATH": os.path.join(repo, "src")},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "GUARDED" in proc.stdout
