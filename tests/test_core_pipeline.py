"""End-to-end ordered-execution tests (paper §5, Definition 5.1 / Theorem 5.2).

The gold standard: a concurrent execution's egress sequence must equal the
sequential execution's egress sequence, for any pipeline composition and any
scheduler heuristic.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import OpSpec, run_pipeline
from repro.core.pipeline import CompiledPipeline


def _sequential_reference(specs, source):
    """Single-threaded oracle: process tuples one at a time, to completion."""
    states = [
        {} if s.kind == "partitioned" else (s.init_state() if s.kind == "stateful" else None)
        for s in specs
    ]

    def run_op(i, value):
        s = specs[i]
        if s.kind == "stateless":
            return s.fn(value)
        if s.kind == "stateful":
            states[i], outs = s.fn(states[i], value)
            return outs
        key = s.key_fn(value)
        st_ = states[i].get(key)  # per-KEY state (paper semantics)
        if st_ is None:
            st_ = s.init_state()
        st_, outs = s.fn(st_, key, value)
        states[i][key] = st_
        return outs

    def recurse(i, value):
        if i == len(specs):
            out.append(value)
            return
        for o in run_op(i, value):
            recurse(i + 1, o)

    out = []
    for v in source:
        recurse(0, v)
    return out


def _specs_basic():
    return [
        OpSpec("double", "stateless", lambda v: [v * 2], selectivity=1.0),
        OpSpec(
            "running_key_sum",
            "partitioned",
            lambda s, k, v: (s + v, [(k, s + v)]),
            key_fn=lambda v: v % 5,
            num_partitions=8,
            init_state=lambda: 0,
        ),
        OpSpec("odd_filter", "stateless", lambda kv: [kv] if kv[1] % 2 == 0 else [], selectivity=0.5),
        OpSpec(
            "count",
            "stateful",
            lambda s, kv: (s + 1, [(kv[0], kv[1], s + 1)]),
            init_state=lambda: 0,
        ),
    ]


@pytest.mark.parametrize("heuristic", ["ct", "lp", "et", "qst"])
@pytest.mark.parametrize("workers", [1, 4])
def test_pipeline_matches_sequential_oracle(heuristic, workers):
    source = list(range(1, 400))
    specs = _specs_basic()
    expected = _sequential_reference(_specs_basic(), source)
    pipe, report = run_pipeline(
        specs,
        source,
        num_workers=workers,
        heuristic=heuristic,
        collect_outputs=True,
    )
    assert pipe.outputs == expected
    assert report.tuples_in == len(source)


@pytest.mark.parametrize("worklist_scheme", ["hybrid", "partitioned", "shared"])
@pytest.mark.parametrize("reorder_scheme", ["non_blocking", "lock_based"])
def test_pipeline_all_scheme_combinations(worklist_scheme, reorder_scheme):
    source = list(range(1, 250))
    expected = _sequential_reference(_specs_basic(), source)
    pipe, _ = run_pipeline(
        _specs_basic(),
        source,
        num_workers=3,
        worklist_scheme=worklist_scheme,
        reorder_scheme=reorder_scheme,
        collect_outputs=True,
    )
    assert pipe.outputs == expected


def test_high_selectivity_flatmap_order():
    """flat-map (selectivity 5) outputs must stay grouped and ordered."""
    specs = [
        OpSpec("fan", "stateless", lambda v: [(v, j) for j in range(5)], selectivity=5.0),
        OpSpec("id", "stateless", lambda v: [v]),
    ]
    source = list(range(30))
    pipe, _ = run_pipeline(specs, source, num_workers=4, collect_outputs=True)
    assert pipe.outputs == [(v, j) for v in source for j in range(5)]


@settings(max_examples=15, deadline=None)
@given(
    vals=st.lists(st.integers(min_value=0, max_value=100), min_size=1, max_size=120),
    workers=st.sampled_from([1, 2, 5]),
    reorder_size=st.sampled_from([2, 16, 1024]),
)
def test_property_ordered_execution(vals, workers, reorder_size):
    """Def. 5.1 as a hypothesis property over random inputs/workers/ring sizes."""
    expected = _sequential_reference(_specs_basic(), vals)
    pipe = CompiledPipeline(
        _specs_basic(),
        num_workers=workers,
        reorder_size=reorder_size,
        collect_outputs=True,
    )
    from repro.core.runtime import StreamRuntime

    rt = StreamRuntime(pipe, num_workers=workers, heuristic="ct")
    rt.run(vals)
    assert pipe.outputs == expected


def test_latency_markers_recorded():
    source = list(range(1, 1000))
    pipe, report = run_pipeline(
        _specs_basic(), source, num_workers=2, marker_interval=16
    )
    assert report.mean_latency > 0
    assert report.tuples_in == 999
