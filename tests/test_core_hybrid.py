"""Tests for partitioned-parallelism worklists (paper §4, Theorem 4.1)."""
import collections
import random
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.hybrid import (
    HybridQueueWorklist,
    PartitionedQueueWorklist,
    SharedQueueWorklist,
    make_worklist,
)


def _mod_partitioner(num_partitions):
    return lambda k: k % num_partitions


@pytest.mark.parametrize("scheme", ["hybrid", "partitioned", "shared"])
def test_single_worker_processes_all_in_key_order(scheme):
    wl = make_worklist(scheme, 4, _mod_partitioner(4), num_workers=1)
    n = 200
    for s in range(1, n + 1):
        wl.add(s, s % 7, s)
    seen = collections.defaultdict(list)
    total = wl.consume(0, lambda serial, key, v: seen[key].append(serial), 10**9)
    assert total == n
    for key, serials in seen.items():
        assert serials == sorted(serials), f"key {key} out of order"
    assert len(wl) == 0


@pytest.mark.parametrize("n_workers", [2, 4, 8])
def test_hybrid_concurrent_key_order_and_exactly_once(n_workers):
    """Theorem 4.1: same-key tuples processed exactly once, in order, never
    concurrently."""
    p = 16
    wl = HybridQueueWorklist(p, _mod_partitioner(p))
    n = 2000
    rng = random.Random(7)
    keys = [rng.randrange(40) for _ in range(n)]

    lock = threading.Lock()
    seen: dict[int, list[int]] = collections.defaultdict(list)
    active = [0] * p  # concurrency detector per partition
    violations = []

    def operate(serial, key, value):
        part = key % p
        with lock:
            active[part] += 1
            if active[part] > 1:
                violations.append(part)
        seen[key].append(serial)
        with lock:
            active[part] -= 1

    for s, k in enumerate(keys, start=1):
        wl.add(s, k, (s, k))

    def worker(wid):
        while wl.consume(wid, operate, 64):
            pass

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert not violations, f"concurrent same-partition processing: {violations}"
    got = sorted(s for lst in seen.values() for s in lst)
    assert got == list(range(1, n + 1)), "lost or duplicated tuples"
    # per-key arrival order (serials ascending per key)
    expect = collections.defaultdict(list)
    for s, k in enumerate(keys, start=1):
        expect[k].append(s)
    for k, serials in seen.items():
        assert serials == expect[k], f"key {k} processed out of order"


def test_hybrid_delegation_happens_under_contention():
    p = 1  # force every tuple into one partition -> heavy delegation
    wl = HybridQueueWorklist(p, _mod_partitioner(p))
    n = 500
    for s in range(1, n + 1):
        wl.add(s, 0, s)
    order = []

    def worker(wid):
        while wl.consume(wid, lambda s, k, v: order.append(s), 32):
            pass

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert order == list(range(1, n + 1))


@settings(max_examples=30, deadline=None)
@given(
    keys=st.lists(st.integers(min_value=0, max_value=9), min_size=1, max_size=300),
    parts=st.sampled_from([1, 2, 3, 8]),
    budget=st.sampled_from([1, 3, 1000]),
)
def test_property_hybrid_sequential_interleavings(keys, parts, budget):
    """Round-robin workers with tiny budgets — per-key order + exactly-once hold
    for any interleaving the budgeted consume loop can produce."""
    wl = HybridQueueWorklist(parts, _mod_partitioner(parts))
    for s, k in enumerate(keys, start=1):
        wl.add(s, k, None)
    seen = collections.defaultdict(list)
    progressed = True
    while len(wl) and progressed:
        progressed = False
        for wid in range(3):
            if wl.consume(wid, lambda s, k, v: seen[k].append(s), budget):
                progressed = True
    assert progressed or not len(wl), "no progress"
    expect = collections.defaultdict(list)
    for s, k in enumerate(keys, start=1):
        expect[k].append(s)
    assert seen == expect


def test_partitioned_queue_static_ownership():
    """Volcano-style: a worker only drains its own buckets."""
    p, w = 8, 4
    wl = PartitionedQueueWorklist(p, _mod_partitioner(p), num_workers=w)
    for s in range(1, 81):
        wl.add(s, s % p, s)
    got = []
    wl.consume(0, lambda s, k, v: got.append(k % p), 10**9)
    assert set(got) <= {0, 4}  # worker 0 owns buckets {0, 4}
    assert len(wl) == 80 - len(got)
