"""Serving-tier battery: multiplexed sessions on one planned runtime.

Covers the SessionMux contract (docs/serving.md): per-session ordering
under interleaving (property, both backends x batch sizes x session
counts), deterministic deficit-round-robin fairness, slow-consumer
isolation, admission control and shedding, graceful churn, and the
starvation snapshot's per-session backlog stats.
"""
import collections
import random
import time

import pytest

from repro.core.api import Engine, EngineConfig, SessionStarvation
from repro.core.operators import OpSpec
from repro.serve import (
    AdmissionError,
    ArrivalConfig,
    MuxConfig,
    SessionMux,
    arrival_times,
    percentile,
    run_open_loop,
)


# --------------------------------------------------------- op zoo (picklable)
# Module-level functions (process-backend dispatch units pickle them), all
# int -> list[int] so every random chain composition stays well-typed.
def _double(v):
    return [v * 2]


def _drop_mod3(v):
    return [] if v % 3 == 0 else [v]


def _fan2(v):
    return [v, v + 1]


def _spin_double(v):
    end = time.perf_counter() + 1e-3
    while time.perf_counter() < end:
        pass
    return [v * 2]


def _runsum(state, v):
    s = (state or 0) + v
    return s, [s]


def _keyed_sum(state, key, v):
    s = (state or 0) + v
    return s, [s]


def _mod4(v):
    return v % 4


_ZOO = {
    "double": lambda: OpSpec("double", "stateless", _double),
    "drop": lambda: OpSpec("drop", "stateless", _drop_mod3, selectivity=0.67),
    "fan": lambda: OpSpec("fan", "stateless", _fan2, selectivity=2.0),
    "runsum": lambda: OpSpec("runsum", "stateful", _runsum),
    "ksum": lambda: OpSpec("ksum", "partitioned", _keyed_sum,
                           key_fn=_mod4, num_partitions=4),
}


def _oracle(chain, values):
    """Reference single-threaded evaluation of an OpSpec chain."""
    stream = list(values)
    for spec in chain:
        out = []
        if spec.kind == "stateless":
            for v in stream:
                out.extend(spec.fn(v))
        elif spec.kind == "stateful":
            state = spec.init_state()
            for v in stream:
                state, o = spec.fn(state, v)
                out.extend(o)
        else:
            states = {}
            for v in stream:
                k = spec.key_fn(v)
                state, o = spec.fn(states.get(k), k, v)
                states[k] = state
                out.extend(o)
        stream = out
    return stream


def _mux(backend, batch, chain, *, workers=2, **mux_kw):
    eng = Engine(EngineConfig(
        backend=backend, num_workers=workers, batch_size=batch,
    ))
    return SessionMux(
        eng, [_ZOO[name]() for name in chain], config=MuxConfig(**mux_kw)
    )


# ----------------------------------------------------------------- property
# ISSUE 8 acceptance: N interleaved sessions through one Engine yield
# exactly their own outputs in their own order, on both backends across
# batch sizes and session counts.  Chains and inputs are seeded-random
# (string seeds are deterministic across interpreter runs, unlike hash()).
_MATRIX = [
    (backend, batch, n)
    for backend in ("thread", "process")
    for batch in (1, 7, 32)
    for n in (2, 8, 32)
]


@pytest.mark.timeout(300)
@pytest.mark.parametrize("backend,batch,n_sessions", _MATRIX)
def test_interleaved_sessions_exact_per_session_ordering(
    backend, batch, n_sessions
):
    rng = random.Random(f"{backend}-{batch}-{n_sessions}")
    chain_names = [rng.choice(list(_ZOO)) for _ in range(rng.randint(1, 3))]
    per_n = 6 if backend == "process" else 20
    inputs = {
        i: [rng.randrange(1000) for _ in range(rng.randint(2, per_n))]
        for i in range(n_sessions)
    }
    with _mux(backend, batch, chain_names, max_sessions=n_sessions) as mux:
        handles = {i: mux.open() for i in range(n_sessions)}
        # interleave: push a small random chunk per session, round-robin,
        # until every session has fed its full input
        cursors = {i: 0 for i in range(n_sessions)}
        while any(cursors[i] < len(inputs[i]) for i in inputs):
            for i in inputs:
                lo = cursors[i]
                if lo >= len(inputs[i]):
                    continue
                hi = min(lo + rng.randint(1, 4), len(inputs[i]))
                handles[i].push(inputs[i][lo:hi])
                cursors[i] = hi
        chain = [_ZOO[nm]() for nm in chain_names]
        for i, h in handles.items():
            want = _oracle(chain, inputs[i])
            got = list(h.results(max_items=len(want), timeout=60))
            assert got == want, (
                f"session {i} (chain={chain_names}): {got[:8]} != {want[:8]}"
            )
            h.close()
            # drain token egressed behind everything: no stray extras
            assert h.poll() == [], f"session {i} produced extra outputs"


# ----------------------------------------------------------------- fairness
class _FakeInner:
    """Stand-in runtime for deterministic scheduler tests: rejects every
    push until released, then accepts unboundedly, recording sids."""

    def __init__(self):
        self.accepted = []
        self.released = False

    def try_push(self, tagged):
        if not self.released:
            return False
        self.accepted.append(tagged)
        return True

    def poll(self, max_items=None):
        return []

    def service(self):
        time.sleep(1e-4)

    def close(self, drain_timeout=60.0):
        return None

    def _abort(self):
        pass


class _FakeEngine:
    def __init__(self, inner):
        self._inner = inner

    def plan(self, graph, edges=None):
        return None

    def open(self, plan, edges=None):
        return self._inner


@pytest.mark.timeout(60)
def test_deficit_round_robin_respects_weights():
    """Fill two sessions' ingress queues while the runtime is gated shut,
    then release the gate: admissions must follow deficit round-robin —
    a weight-3 session gets ~3x the tuples of a weight-1 session in any
    steady window of the admission trace."""
    inner = _FakeInner()
    mux = SessionMux(
        _FakeEngine(inner), [_ZOO["double"]()],
        config=MuxConfig(max_sessions=2, quantum=4, ingress_depth=512),
    )
    try:
        a = mux.open(weight=1.0)
        b = mux.open(weight=3.0)
        a.push(range(300))
        b.push(range(1000, 1300))
        inner.released = True
        deadline = time.perf_counter() + 30
        while len(inner.accepted) < 400:
            assert time.perf_counter() < deadline, len(inner.accepted)
            time.sleep(1e-3)
        # skip the release transient, stop before either queue runs dry
        # (b exhausts its 300 tuples around entry ~400 of the merged trace)
        window = [sid for sid, _v in inner.accepted[20:320]]
        counts = collections.Counter(window)
        assert counts[a.sid] + counts[b.sid] == 300
        ratio = counts[b.sid] / counts[a.sid]
        assert 2.4 <= ratio <= 3.6, (ratio, counts)
        # no starvation stretch longer than one heavy-session DRR round
        a_at = [j for j, sid in enumerate(window) if sid == a.sid]
        assert max(q - p for p, q in zip(a_at, a_at[1:])) <= 13
    finally:
        mux._closed = True  # fake runtime: skip the drain protocol
        mux._pump.join(timeout=5)


@pytest.mark.timeout(120)
def test_slow_consumer_does_not_stall_other_sessions():
    """A consumer that never reads must not delay another session's
    results: its backlog hits result_budget, its ingress stops being
    admitted, and the shared egress keeps flowing."""
    with _mux(
        "thread", 4, ["double"], max_sessions=2,
        result_budget=32, ingress_depth=64, quantum=4, push_timeout=0.2,
    ) as mux:
        slow = mux.open()
        fast = mux.open()
        # feed the slow consumer until shedding proves its lane is full
        with pytest.raises(AdmissionError) as exc_info:
            slow.push(range(10_000), timeout=0.2)
        assert exc_info.value.reason == "ingress_full"
        assert exc_info.value.sid == slow.sid
        assert slow.pushed < 10_000
        # the fast session (which *does* consume) must still stream
        # promptly end to end, staying under its own result budget
        t0 = time.perf_counter()
        got = []
        for lo in range(0, 200, 16):
            n = fast.push(range(lo, min(lo + 16, 200)))
            got.extend(fast.results(max_items=n, timeout=20))
        elapsed = time.perf_counter() - t0
        assert got == [2 * v for v in range(200)]
        assert elapsed < 10, f"fast session stalled {elapsed:.1f}s"
        # slow lane: undelivered backlog bounded near result_budget (plus
        # tuples already in flight when admission stopped), never the flood
        snap = mux.stats()["sessions"][slow.sid]
        assert snap["undelivered"] <= 32 + 512
        drained = list(slow.results(max_items=slow.pushed, timeout=30))
        assert drained == [2 * v for v in range(slow.pushed)]
        slow.close()
        fast.close()


@pytest.mark.timeout(60)
def test_starvation_snapshot_carries_per_session_backlog():
    with _mux("thread", 1, ["double"], max_sessions=2) as mux:
        quiet = mux.open()
        busy = mux.open()
        busy.push([1, 2, 3])
        with pytest.raises(SessionStarvation) as exc_info:
            list(quiet.results(timeout=0.3))
        snap = exc_info.value.snapshot
        assert set(snap["sessions"]) == {quiet.sid, busy.sid}
        for stats in snap["sessions"].values():
            for key in ("pushed", "admitted", "egressed", "undelivered",
                        "ingress_queued", "weight"):
                assert key in stats
        assert snap["open_sessions"] == 2
        assert list(busy.results(max_items=3, timeout=20)) == [2, 4, 6]
        quiet.close()
        busy.close()


# ---------------------------------------------------------------- admission
@pytest.mark.timeout(60)
def test_admission_control_max_sessions_and_churn_frees_slots():
    with _mux("thread", 1, ["double"], max_sessions=2) as mux:
        a = mux.open()
        b = mux.open()
        with pytest.raises(AdmissionError) as exc_info:
            mux.open()
        assert exc_info.value.reason == "max_sessions"
        assert exc_info.value.limit == 2
        assert "sessions" in exc_info.value.snapshot
        a.push([1, 2])
        assert list(a.results(max_items=2, timeout=20)) == [2, 4]
        a.close()  # graceful churn: retiring a session frees its slot
        c = mux.open()
        c.push([5])
        assert list(c.results(max_items=1, timeout=20)) == [10]
        b.close()
        c.close()
    stats = mux.stats()
    assert stats["retired"][a.sid] == {"pushed": 2, "egressed": 2}
    assert stats["undeliverable"] == 0


@pytest.mark.timeout(60)
def test_mux_closed_rejects_new_sessions_and_pushes():
    mux = _mux("thread", 1, ["double"], max_sessions=4)
    s = mux.open()
    s.push([1])
    assert list(s.results(max_items=1, timeout=20)) == [2]
    mux.close()
    with pytest.raises(AdmissionError) as exc_info:
        mux.open()
    assert exc_info.value.reason == "mux_closed"
    with pytest.raises(RuntimeError):
        s.try_push(9)
    assert mux.close() is mux.report  # idempotent


def test_mux_config_validation():
    for bad in (
        {"max_sessions": 0},
        {"ingress_depth": 0},
        {"result_budget": 0},
        {"quantum": 0},
        {"state_partitions": 0},
    ):
        with pytest.raises(ValueError):
            MuxConfig(**bad).validate()
    with _mux("thread", 1, ["double"]) as mux:
        with pytest.raises(ValueError):
            mux.open(weight=0.0)


# ------------------------------------------------------------ load generator
def test_arrival_shapes_hit_requested_mean_rate():
    n = 4000
    for shape in ("poisson", "lognormal", "pareto", "bursty", "diurnal"):
        cfg = ArrivalConfig(shape=shape, rate=500.0, seed=13, period_s=0.5)
        times = arrival_times(cfg, n)
        assert len(times) == n
        assert all(b >= a for a, b in zip(times, times[1:]))
        achieved = n / times[-1]
        assert 0.6 * cfg.rate < achieved < 1.7 * cfg.rate, (shape, achieved)
    with pytest.raises(ValueError):
        arrival_times(ArrivalConfig(shape="nope"), 1)
    with pytest.raises(ValueError):
        arrival_times(ArrivalConfig(shape="pareto", alpha=0.9), 1)
    with pytest.raises(ValueError):
        arrival_times(ArrivalConfig(shape="bursty", burst_duty=1.5), 1)


def test_percentile_nearest_rank():
    vals = [float(i) for i in range(1, 101)]
    assert percentile(vals, 50) == 50.0
    assert percentile(vals, 99) == 99.0
    assert percentile(vals, 99.9) == 100.0
    assert percentile([], 50) != percentile([], 50)  # NaN


@pytest.mark.timeout(120)
def test_open_loop_latency_charges_queueing_to_the_request():
    """Coordinated-omission check with a ~1ms/tuple operator: a burst
    offered far beyond capacity must report latencies dominated by queueing
    (charged from the *scheduled* arrival), far above the lightly-loaded
    run's service-time latencies."""
    def build():
        eng = Engine(EngineConfig(backend="thread", num_workers=2,
                                  batch_size=1))
        return SessionMux(eng, [OpSpec("spin", "stateless", _spin_double)],
                          config=MuxConfig(max_sessions=4))

    with build() as mux:
        light = run_open_loop(
            mux, sessions=4, requests=20,
            arrivals=ArrivalConfig(rate=25.0, seed=5),
        )
    with build() as mux:
        slam = run_open_loop(
            mux, sessions=4, requests=20,
            arrivals=ArrivalConfig(rate=1e6, seed=5),
        )
    assert light.completed == slam.completed == 80
    # 80 requests x ~1ms arrive "instantly": the tail must carry the queue
    assert slam.p99 > 0.02, slam.p99
    assert slam.p99 > 2 * light.p50, (slam.p99, light.p50)
    assert len(light.per_session) == 4
    for summary in light.per_session.values():
        assert summary["n"] == 20


@pytest.mark.timeout(120)
def test_open_loop_slow_consumer_injection_confined():
    """Slow-consumer injection via the load generator: the victim's own
    completions slow down, everyone else's p99 stays sane."""
    with _mux(
        "thread", 4, ["double"], max_sessions=4, result_budget=8
    ) as mux:
        rep = run_open_loop(
            mux, sessions=4, requests=30,
            arrivals=ArrivalConfig(rate=400.0, seed=9),
            slow_consumers={0: 0.02},
        )
    assert rep.completed == 120
    victim = rep.per_session[0]["p99"]
    others = max(rep.per_session[i]["p99"] for i in (1, 2, 3))
    assert victim > others, (victim, others)
