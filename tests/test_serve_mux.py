"""Serving-tier battery: multiplexed sessions on one planned runtime.

Covers the SessionMux contract (docs/serving.md): per-session ordering
under interleaving (property, both backends x batch sizes x session
counts), deterministic deficit-round-robin fairness, slow-consumer
isolation, admission control and shedding, graceful churn, and the
starvation snapshot's per-session backlog stats.
"""
import collections
import random
import threading
import time

import pytest

from repro.core.api import Engine, EngineConfig, SessionStarvation
from repro.core.operators import OpSpec
from repro.serve import (
    AdmissionError,
    ArrivalConfig,
    MuxConfig,
    SessionMux,
    arrival_times,
    percentile,
    run_open_loop,
)


# --------------------------------------------------------- op zoo (picklable)
# Module-level functions (process-backend dispatch units pickle them), all
# int -> list[int] so every random chain composition stays well-typed.
def _double(v):
    return [v * 2]


def _drop_mod3(v):
    return [] if v % 3 == 0 else [v]


def _fan2(v):
    return [v, v + 1]


def _spin_double(v):
    end = time.perf_counter() + 1e-3
    while time.perf_counter() < end:
        pass
    return [v * 2]


def _runsum(state, v):
    s = (state or 0) + v
    return s, [s]


def _keyed_sum(state, key, v):
    s = (state or 0) + v
    return s, [s]


def _mod4(v):
    return v % 4


_ZOO = {
    "double": lambda: OpSpec("double", "stateless", _double),
    "drop": lambda: OpSpec("drop", "stateless", _drop_mod3, selectivity=0.67),
    "fan": lambda: OpSpec("fan", "stateless", _fan2, selectivity=2.0),
    "runsum": lambda: OpSpec("runsum", "stateful", _runsum),
    "ksum": lambda: OpSpec("ksum", "partitioned", _keyed_sum,
                           key_fn=_mod4, num_partitions=4),
}


def _oracle(chain, values):
    """Reference single-threaded evaluation of an OpSpec chain."""
    stream = list(values)
    for spec in chain:
        out = []
        if spec.kind == "stateless":
            for v in stream:
                out.extend(spec.fn(v))
        elif spec.kind == "stateful":
            state = spec.init_state()
            for v in stream:
                state, o = spec.fn(state, v)
                out.extend(o)
        else:
            states = {}
            for v in stream:
                k = spec.key_fn(v)
                state, o = spec.fn(states.get(k), k, v)
                states[k] = state
                out.extend(o)
        stream = out
    return stream


def _mux(backend, batch, chain, *, workers=2, **mux_kw):
    eng = Engine(EngineConfig(
        backend=backend, num_workers=workers, batch_size=batch,
    ))
    return SessionMux(
        eng, [_ZOO[name]() for name in chain], config=MuxConfig(**mux_kw)
    )


# ----------------------------------------------------------------- property
# ISSUE 8 acceptance: N interleaved sessions through one Engine yield
# exactly their own outputs in their own order, on both backends across
# batch sizes and session counts.  Chains and inputs are seeded-random
# (string seeds are deterministic across interpreter runs, unlike hash()).
_MATRIX = [
    (backend, batch, n)
    for backend in ("thread", "process")
    for batch in (1, 7, 32)
    for n in (2, 8, 32)
]


@pytest.mark.timeout(300)
@pytest.mark.parametrize("backend,batch,n_sessions", _MATRIX)
def test_interleaved_sessions_exact_per_session_ordering(
    backend, batch, n_sessions
):
    rng = random.Random(f"{backend}-{batch}-{n_sessions}")
    chain_names = [rng.choice(list(_ZOO)) for _ in range(rng.randint(1, 3))]
    per_n = 6 if backend == "process" else 20
    inputs = {
        i: [rng.randrange(1000) for _ in range(rng.randint(2, per_n))]
        for i in range(n_sessions)
    }
    with _mux(backend, batch, chain_names, max_sessions=n_sessions) as mux:
        handles = {i: mux.open() for i in range(n_sessions)}
        # interleave: push a small random chunk per session, round-robin,
        # until every session has fed its full input
        cursors = {i: 0 for i in range(n_sessions)}
        while any(cursors[i] < len(inputs[i]) for i in inputs):
            for i in inputs:
                lo = cursors[i]
                if lo >= len(inputs[i]):
                    continue
                hi = min(lo + rng.randint(1, 4), len(inputs[i]))
                handles[i].push(inputs[i][lo:hi])
                cursors[i] = hi
        chain = [_ZOO[nm]() for nm in chain_names]
        for i, h in handles.items():
            want = _oracle(chain, inputs[i])
            got = list(h.results(max_items=len(want), timeout=60))
            assert got == want, (
                f"session {i} (chain={chain_names}): {got[:8]} != {want[:8]}"
            )
            h.close()
            # drain token egressed behind everything: no stray extras
            assert h.poll() == [], f"session {i} produced extra outputs"


# ----------------------------------------------------------------- fairness
class _FakeInner:
    """Stand-in runtime for deterministic scheduler tests: rejects every
    push until released, then accepts unboundedly, recording sids."""

    def __init__(self):
        self.accepted = []
        self.released = False

    def try_push(self, tagged):
        if not self.released:
            return False
        self.accepted.append(tagged)
        return True

    def poll(self, max_items=None):
        return []

    def service(self):
        time.sleep(1e-4)

    def close(self, drain_timeout=60.0):
        return None

    def _abort(self):
        pass


class _FakeEngine:
    def __init__(self, inner):
        self._inner = inner

    def plan(self, graph, edges=None):
        return None

    def open(self, plan, edges=None):
        return self._inner


@pytest.mark.timeout(60)
def test_deficit_round_robin_respects_weights():
    """Fill two sessions' ingress queues while the runtime is gated shut,
    then release the gate: admissions must follow deficit round-robin —
    a weight-3 session gets ~3x the tuples of a weight-1 session in any
    steady window of the admission trace."""
    inner = _FakeInner()
    mux = SessionMux(
        _FakeEngine(inner), [_ZOO["double"]()],
        config=MuxConfig(max_sessions=2, quantum=4, ingress_depth=512),
    )
    try:
        a = mux.open(weight=1.0)
        b = mux.open(weight=3.0)
        a.push(range(300))
        b.push(range(1000, 1300))
        inner.released = True
        deadline = time.perf_counter() + 30
        while len(inner.accepted) < 400:
            assert time.perf_counter() < deadline, len(inner.accepted)
            time.sleep(1e-3)
        # skip the release transient, stop before either queue runs dry
        # (b exhausts its 300 tuples around entry ~400 of the merged trace)
        window = [sid for sid, _v in inner.accepted[20:320]]
        counts = collections.Counter(window)
        assert counts[a.sid] + counts[b.sid] == 300
        ratio = counts[b.sid] / counts[a.sid]
        assert 2.4 <= ratio <= 3.6, (ratio, counts)
        # no starvation stretch longer than one heavy-session DRR round
        a_at = [j for j, sid in enumerate(window) if sid == a.sid]
        assert max(q - p for p, q in zip(a_at, a_at[1:])) <= 13
    finally:
        mux._closed = True  # fake runtime: skip the drain protocol
        mux._pump.join(timeout=5)


@pytest.mark.timeout(60)
def test_deficit_round_robin_banks_credit_across_pause_resume():
    """Regression (empty-ingress DRR turn): a briefly idle session must
    keep its banked deficit — capped at two rounds' worth — so a paused
    high-weight session resumes at its earned share.  The pre-fix pump
    zeroed ``_deficit`` whenever a session's ingress came up empty, so a
    weight-3 session that paused for even one scheduling round restarted
    from zero credit and was admitted at the same trickle as a fresh
    session (12 tuples in the resume round instead of the banked 24)."""
    inner = _FakeInner()
    inner.released = True  # runtime accepts from the start
    mux = SessionMux(
        _FakeEngine(inner), [_ZOO["double"]()],
        config=MuxConfig(max_sessions=2, quantum=4, ingress_depth=512),
    )
    try:
        a = mux.open(weight=1.0)
        b = mux.open(weight=3.0)
        # stop the pump thread: the test drives DRR rounds by hand so the
        # round structure (accrual -> admit -> idle) is deterministic.
        # (_closed also gates the client push surface, so ingress is fed
        # through the queues directly below.)
        mux._closed = True
        mux._pump.join(timeout=5)
        a._deficit = b._deficit = 0.0  # clear accrual from pump idle turns
        a._ingress.extend(range(100))
        a.pushed += 100
        # rounds 1-3: b idle (paused client), a streaming.  b accrues
        # quantum*weight = 12 credit per round, capped at two rounds (24);
        # the cap must hold — an idle session can't bank unboundedly.
        for _ in range(3):
            mux._pump_ingress()
        assert b._deficit == 24.0, b._deficit  # banked, capped (pre-fix: 0.0)
        assert a.admitted == 12  # 3 rounds x quantum 4, unaffected
        # resume: b pushes a burst; the next single round must spend the
        # banked credit plus this round's accrual, already capped at 24
        b._ingress.extend(range(1000, 1060))
        b.pushed += 60
        before = len(inner.accepted)
        mux._pump_ingress()
        admitted = collections.Counter(
            sid for sid, _v in inner.accepted[before:]
        )
        assert admitted[b.sid] == 24, admitted  # pre-fix: 12
        assert admitted[a.sid] == 4  # a's steady share keeps flowing
    finally:
        mux._closed = True
        if mux._pump.is_alive():
            mux._pump.join(timeout=5)


@pytest.mark.timeout(60)
def test_late_output_of_retired_session_counted_undeliverable():
    """A retired session's late outputs (crash-replay overlap, or tuples
    surfacing while an elastic resize drains the sid-partitioned stage)
    must be counted ``undeliverable`` — never delivered to another
    session, never a KeyError in the pump."""
    from repro.serve.mux import _FlushToken

    class _EchoInner(_FakeInner):
        """Accepts pushes and lets the test script the egress stream."""

        def __init__(self):
            super().__init__()
            self.released = True
            self.out = []

        def poll(self, max_items=None):
            out, self.out = self.out, []
            return out

    inner = _EchoInner()
    mux = SessionMux(
        _FakeEngine(inner), [_ZOO["double"]()],
        config=MuxConfig(max_sessions=2),
    )
    try:
        a = mux.open()
        b = mux.open()
        mux._closed = True  # stop the pump; drive the demux loop by hand
        mux._pump.join(timeout=5)
        # retire a through the real drain protocol: closing + empty
        # ingress queues its flush token, the token's egress retires it
        a._closing = True
        mux._pump_ingress()
        assert any(isinstance(x, _FlushToken) for x in inner.accepted)
        inner.out = [_FlushToken(a.sid)]
        mux._pump_egress()
        assert a._drained.is_set()
        assert a.sid in mux._retired
        # late outputs of the retired sid arrive interleaved with b's
        inner.out = [(a.sid, 111), (b.sid, 7), (a.sid, 222)]
        mux._pump_egress()
        assert mux._undeliverable == 2
        assert list(b._results) == [7]  # b's stream untouched
        assert a.poll() == []  # nothing leaked into the retired session
        stats = mux.stats()
        assert stats["undeliverable"] == 2
        assert stats["traffic"]["undeliverable"] == 2
        # a duplicate flush token after retirement is idempotent
        inner.out = [_FlushToken(a.sid)]
        mux._pump_egress()
        assert mux._undeliverable == 2
    finally:
        mux._closed = True
        if mux._pump.is_alive():
            mux._pump.join(timeout=5)


@pytest.mark.timeout(120)
def test_slow_consumer_does_not_stall_other_sessions():
    """A consumer that never reads must not delay another session's
    results: its backlog hits result_budget, its ingress stops being
    admitted, and the shared egress keeps flowing."""
    with _mux(
        "thread", 4, ["double"], max_sessions=2,
        result_budget=32, ingress_depth=64, quantum=4, push_timeout=0.2,
    ) as mux:
        slow = mux.open()
        fast = mux.open()
        # feed the slow consumer until shedding proves its lane is full
        with pytest.raises(AdmissionError) as exc_info:
            slow.push(range(10_000), timeout=0.2)
        assert exc_info.value.reason == "ingress_full"
        assert exc_info.value.sid == slow.sid
        assert slow.pushed < 10_000
        # the fast session (which *does* consume) must still stream
        # promptly end to end, staying under its own result budget
        t0 = time.perf_counter()
        got = []
        for lo in range(0, 200, 16):
            n = fast.push(range(lo, min(lo + 16, 200)))
            got.extend(fast.results(max_items=n, timeout=20))
        elapsed = time.perf_counter() - t0
        assert got == [2 * v for v in range(200)]
        assert elapsed < 10, f"fast session stalled {elapsed:.1f}s"
        # slow lane: undelivered backlog bounded near result_budget (plus
        # tuples already in flight when admission stopped), never the flood
        snap = mux.stats()["sessions"][slow.sid]
        assert snap["undelivered"] <= 32 + 512
        drained = list(slow.results(max_items=slow.pushed, timeout=30))
        assert drained == [2 * v for v in range(slow.pushed)]
        slow.close()
        fast.close()


@pytest.mark.timeout(60)
def test_starvation_snapshot_carries_per_session_backlog():
    with _mux("thread", 1, ["double"], max_sessions=2) as mux:
        quiet = mux.open()
        busy = mux.open()
        busy.push([1, 2, 3])
        with pytest.raises(SessionStarvation) as exc_info:
            list(quiet.results(timeout=0.3))
        snap = exc_info.value.snapshot
        assert set(snap["sessions"]) == {quiet.sid, busy.sid}
        for stats in snap["sessions"].values():
            for key in ("pushed", "admitted", "egressed", "undelivered",
                        "ingress_queued", "weight"):
                assert key in stats
        assert snap["open_sessions"] == 2
        assert list(busy.results(max_items=3, timeout=20)) == [2, 4, 6]
        quiet.close()
        busy.close()


# ---------------------------------------------------------------- admission
@pytest.mark.timeout(60)
def test_admission_control_max_sessions_and_churn_frees_slots():
    with _mux("thread", 1, ["double"], max_sessions=2) as mux:
        a = mux.open()
        b = mux.open()
        with pytest.raises(AdmissionError) as exc_info:
            mux.open()
        assert exc_info.value.reason == "max_sessions"
        assert exc_info.value.limit == 2
        assert "sessions" in exc_info.value.snapshot
        a.push([1, 2])
        assert list(a.results(max_items=2, timeout=20)) == [2, 4]
        a.close()  # graceful churn: retiring a session frees its slot
        c = mux.open()
        c.push([5])
        assert list(c.results(max_items=1, timeout=20)) == [10]
        b.close()
        c.close()
    stats = mux.stats()
    assert stats["retired"][a.sid] == {"pushed": 2, "egressed": 2}
    assert stats["undeliverable"] == 0


@pytest.mark.timeout(60)
def test_mux_closed_rejects_new_sessions_and_pushes():
    mux = _mux("thread", 1, ["double"], max_sessions=4)
    s = mux.open()
    s.push([1])
    assert list(s.results(max_items=1, timeout=20)) == [2]
    mux.close()
    with pytest.raises(AdmissionError) as exc_info:
        mux.open()
    assert exc_info.value.reason == "mux_closed"
    with pytest.raises(RuntimeError):
        s.try_push(9)
    assert mux.close() is mux.report  # idempotent


def test_mux_config_validation():
    for bad in (
        {"max_sessions": 0},
        {"ingress_depth": 0},
        {"result_budget": 0},
        {"quantum": 0},
        {"state_partitions": 0},
    ):
        with pytest.raises(ValueError):
            MuxConfig(**bad).validate()
    with _mux("thread", 1, ["double"]) as mux:
        with pytest.raises(ValueError):
            mux.open(weight=0.0)


# ------------------------------------------------------------ load generator
def test_arrival_shapes_hit_requested_mean_rate():
    n = 4000
    for shape in ("poisson", "lognormal", "pareto", "bursty", "diurnal"):
        cfg = ArrivalConfig(shape=shape, rate=500.0, seed=13, period_s=0.5)
        times = arrival_times(cfg, n)
        assert len(times) == n
        assert all(b >= a for a, b in zip(times, times[1:]))
        achieved = n / times[-1]
        assert 0.6 * cfg.rate < achieved < 1.7 * cfg.rate, (shape, achieved)
    with pytest.raises(ValueError):
        arrival_times(ArrivalConfig(shape="nope"), 1)
    with pytest.raises(ValueError):
        arrival_times(ArrivalConfig(shape="pareto", alpha=0.9), 1)
    with pytest.raises(ValueError):
        arrival_times(ArrivalConfig(shape="bursty", burst_duty=1.5), 1)


def test_modulated_arrivals_unbiased_when_trough_gap_rivals_period():
    """Regression (Lewis-Shedler thinning): at low nominal rates the old
    generator stepped by the local rate at each gap's *start*, so one
    trough-drawn gap (mean ~ 1/low_rate, comparable to the whole period)
    leapt entire bursts and the realized mean rate landed at a fraction of
    nominal (~0.23x for these parameters).  Thinned sampling must realize
    the nominal mean within sampling noise for both modulated shapes."""
    for cfg in (
        ArrivalConfig(shape="bursty", rate=18.0, burst_factor=4.0,
                      burst_duty=0.35, period_s=1.0, seed=11),
        ArrivalConfig(shape="diurnal", rate=10.0, period_s=2.0, seed=11),
    ):
        times = arrival_times(cfg, 400)
        realized = 400 / times[-1]
        assert 0.75 <= realized / cfg.rate <= 1.33, (cfg.shape, realized)
        assert all(b >= a for a, b in zip(times, times[1:]))
    # the bursty square wave's analytic mean must stay pinned to cfg.rate
    # even when the trough floor binds (duty * factor > 1)
    from repro.serve.loadgen import _bursty_factors
    for duty, factor in ((0.2, 8.0), (0.35, 4.0), (0.5, 3.0), (0.225, 4.0)):
        cfg = ArrivalConfig(shape="bursty", burst_duty=duty,
                            burst_factor=factor)
        high, low = _bursty_factors(cfg)
        mean = duty * high + (1.0 - duty) * low
        assert mean == pytest.approx(1.0), (duty, factor, mean)
        assert high > 1.0 > low > 0.0


class _PacedHandle:
    """Fake session: ignores pushes, emits ``n`` completions at a fixed
    ``pace`` once ``start`` is set — a deterministic server for exercising
    run_open_loop's measurement windows without a real runtime."""

    def __init__(self, sid, n, pace, start, done):
        self.sid = sid
        self._n, self._pace = n, pace
        self._start, self._done = start, done

    def try_push(self, value):
        return True

    def close(self, drain_timeout=None):
        pass

    def results(self, timeout=None):
        self._start.wait(timeout)
        for k in range(self._n):
            time.sleep(self._pace)
            yield k
        self._done.set()


class _PacedMux:
    """Serves sessions *sequentially* (session 1 only starts once session 0
    has drained) — the maximally uneven progress that inflates a naive
    warmup-window rate."""

    def __init__(self, n, pace):
        first = threading.Event()
        first.set()
        self._events = [first]
        self._n, self._pace = n, pace
        self._opened = 0

    def open(self, weight=1.0):
        nxt = threading.Event()
        h = _PacedHandle(self._opened, self._n, self._pace,
                         self._events[-1], nxt)
        self._events.append(nxt)
        self._opened += 1
        return h


def test_warmup_rate_counts_only_steady_window_completions():
    """Regression (serving probe warm-up): ``achieved_rate`` must divide
    the completions *inside* the steady-state window by that window.  The
    pre-fix probe had no warmup handling at all (cold-start ramp deflated
    capacity), and the first cut divided every post-warmup completion by a
    window that opens only when the slowest session exits warmup — with
    uneven per-session progress that inflates the rate ~2x (here: two
    sessions served back to back at exactly 200/s each)."""
    n, pace = 60, 0.005
    rep = run_open_loop(
        _PacedMux(n, pace), sessions=2, requests=n, warmup=30,
        arrivals=ArrivalConfig(shape="poisson", rate=1e6, seed=3),
    )
    # true service rate is 200/s whenever anything is being served; the
    # naive all-completions/late-window quotient reads ~400/s
    assert 140.0 < rep.achieved_rate < 280.0, rep.achieved_rate
    # warmup requests are excluded from the percentile population
    assert rep.per_session[0]["n"] == n - 30
    with pytest.raises(ValueError):
        run_open_loop(_PacedMux(n, pace), sessions=1, requests=10,
                      warmup=10, arrivals=ArrivalConfig(rate=1e6))


def test_percentile_nearest_rank():
    vals = [float(i) for i in range(1, 101)]
    assert percentile(vals, 50) == 50.0
    assert percentile(vals, 99) == 99.0
    assert percentile(vals, 99.9) == 100.0
    assert percentile([], 50) != percentile([], 50)  # NaN


@pytest.mark.timeout(120)
def test_open_loop_latency_charges_queueing_to_the_request():
    """Coordinated-omission check with a ~1ms/tuple operator: a burst
    offered far beyond capacity must report latencies dominated by queueing
    (charged from the *scheduled* arrival), far above the lightly-loaded
    run's service-time latencies."""
    def build():
        eng = Engine(EngineConfig(backend="thread", num_workers=2,
                                  batch_size=1))
        return SessionMux(eng, [OpSpec("spin", "stateless", _spin_double)],
                          config=MuxConfig(max_sessions=4))

    with build() as mux:
        light = run_open_loop(
            mux, sessions=4, requests=20,
            arrivals=ArrivalConfig(rate=25.0, seed=5),
        )
    with build() as mux:
        slam = run_open_loop(
            mux, sessions=4, requests=20,
            arrivals=ArrivalConfig(rate=1e6, seed=5),
        )
    assert light.completed == slam.completed == 80
    # 80 requests x ~1ms arrive "instantly": the tail must carry the queue
    assert slam.p99 > 0.02, slam.p99
    assert slam.p99 > 2 * light.p50, (slam.p99, light.p50)
    assert len(light.per_session) == 4
    for summary in light.per_session.values():
        assert summary["n"] == 20


@pytest.mark.timeout(120)
def test_open_loop_slow_consumer_injection_confined():
    """Slow-consumer injection via the load generator: the victim's own
    completions slow down, everyone else's p99 stays sane."""
    with _mux(
        "thread", 4, ["double"], max_sessions=4, result_budget=8
    ) as mux:
        rep = run_open_loop(
            mux, sessions=4, requests=30,
            arrivals=ArrivalConfig(rate=400.0, seed=9),
            slow_consumers={0: 0.02},
        )
    assert rep.completed == 120
    victim = rep.per_session[0]["p99"]
    others = max(rep.per_session[i]["p99"] for i in (1, 2, 3))
    assert victim > others, (victim, others)
