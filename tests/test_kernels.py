"""Per-kernel tests: Pallas (interpret=True) vs pure-jnp oracle, sweeping
shapes and dtypes (deliverable c)."""
import numpy as np
import pytest

jax = pytest.importorskip(
    "jax",
    reason="pallas kernel tests need jax; the core runtime's tier-1 "
    "coverage runs without it (pure-NumPy reference backends)",
)
import jax.numpy as jnp

# ----------------------------------------------------------------- reorder
from repro.kernels.reorder import ops as reorder_ops
from repro.kernels.reorder.ref import commit_ref, init_state


@pytest.mark.parametrize("size,width", [(8, 128), (64, 128), (32, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reorder_kernel_matches_ref(size, width, dtype):
    rng = np.random.RandomState(0)
    state_k = init_state(size, width, dtype)
    state_r = init_state(size, width, dtype)
    emitted_k, emitted_r = [], []
    serial_pool = list(rng.permutation(3 * size))
    while serial_pool:
        kbatch = min(8, len(serial_pool))
        # take only serials within the ref window to respect back-pressure
        nxt = int(state_r.next)
        batch = [s for s in serial_pool if nxt <= s < nxt + size][:kbatch]
        for s in batch:
            serial_pool.remove(s)
        serials = jnp.array(batch + [-1] * (8 - len(batch)), jnp.int32)
        payloads = jnp.asarray(
            rng.randn(8, width), dtype
        )
        sk, ek, ck, ak = reorder_ops.commit(state_k, serials, payloads, use_kernel=True)
        sr, er, cr, ar = commit_ref(state_r, serials, payloads)
        assert int(ck) == int(cr)
        assert int(sk.next) == int(sr.next)
        np.testing.assert_array_equal(np.asarray(ak), np.asarray(ar))
        np.testing.assert_allclose(
            np.asarray(ek[: int(ck)], np.float32),
            np.asarray(er[: int(cr)], np.float32),
            rtol=1e-5,
        )
        state_k, state_r = sk, sr
        emitted_k.append(np.asarray(ek[: int(ck)], np.float32))
        emitted_r.append(np.asarray(er[: int(cr)], np.float32))
    # everything drained, in order
    assert int(state_r.next) == 3 * size
    assert not np.any(np.asarray(state_r.present))


def test_reorder_ref_emits_in_serial_order():
    state = init_state(16, 4)
    payload = lambda t: jnp.full((1, 4), t, jnp.float32)
    emitted_serials = []
    order = [3, 1, 0, 2, 5, 4, 7, 6, 9, 8, 11, 10, 13, 12, 15, 14]
    for t in order:
        state, em, c, acc = commit_ref(state, jnp.array([t]), payload(t))
        emitted_serials.extend(np.asarray(em[: int(c), 0], np.int32).tolist())
    assert emitted_serials == list(range(16))


# ----------------------------------------------------------------- dispatch
from repro.kernels.dispatch import ops as dispatch_ops
from repro.kernels.dispatch.ref import dispatch_ref


@pytest.mark.parametrize("T,P,C,W", [(64, 8, 16, 128), (128, 4, 8, 128), (32, 16, 4, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatch_kernel_matches_ref(T, P, C, W, dtype):
    rng = np.random.RandomState(1)
    ids = jnp.asarray(rng.randint(-1, P, T), jnp.int32)
    payloads = jnp.asarray(rng.randn(T, W), dtype)
    bk, ck, dk = dispatch_ops.dispatch(ids, payloads, P, C, use_kernel=True)
    br, cr, dr = dispatch_ref(ids, payloads, P, C)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
    np.testing.assert_allclose(
        np.asarray(bk, np.float32), np.asarray(br, np.float32), rtol=1e-5, atol=1e-5
    )


def test_dispatch_preserves_arrival_order():
    """Theorem 4.1(2) vectorized: within a partition, buffer order = arrival."""
    T, P, C, W = 32, 2, 32, 4
    ids = jnp.asarray([t % P for t in range(T)], jnp.int32)
    payloads = jnp.arange(T, dtype=jnp.float32)[:, None] * jnp.ones((1, W))
    buf, counts, dest = dispatch_ops.dispatch(ids, payloads, P, C)
    for p in range(P):
        got = np.asarray(buf[p, : int(counts[p]), 0])
        expect = np.asarray([t for t in range(T) if t % P == p], np.float32)
        np.testing.assert_array_equal(got, expect)


# ----------------------------------------------------------------- attention
from repro.kernels.attention.flash import flash_attention as flash_fwd
from repro.kernels.attention.ref import attention_ref


@pytest.mark.parametrize(
    "B,S,H,Hkv,Dh", [(1, 128, 2, 2, 64), (2, 256, 4, 2, 64), (1, 256, 8, 1, 128)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, H, Hkv, Dh, dtype, causal):
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(keys[0], (B, S, H, Dh), dtype)
    k = jax.random.normal(keys[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(keys[2], (B, S, Hkv, Dh), dtype)
    out = flash_fwd(q, k, v, causal=causal, block_q=128, block_k=128)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_grad_path():
    """custom_vjp: kernel fwd + jnp bwd must be differentiable and close to
    full-jnp gradients."""
    from repro.kernels.attention.ops import flash_attention

    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, Dh = 1, 128, 2, 64
    q = jax.random.normal(keys[0], (B, S, H, Dh))
    k = jax.random.normal(keys[1], (B, S, H, Dh))
    v = jax.random.normal(keys[2], (B, S, H, Dh))
    g1 = jax.grad(lambda q_: flash_attention(q_, k, v, True).sum())(q)
    g2 = jax.grad(lambda q_: attention_ref(q_, k, v, True).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------- ssd
from repro.kernels.ssd import ops as ssd_ops
from repro.models.ssm import ssd_chunked


@pytest.mark.parametrize("B,L,H,P,N,chunk", [(1, 128, 2, 64, 128, 64), (2, 256, 4, 64, 128, 128), (1, 512, 2, 128, 64, 128)])
def test_ssd_kernel_matches_ref(B, L, H, P, N, chunk):
    keys = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(keys[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(keys[2], (H,)) * 0.3)
    Bm = jax.random.normal(keys[3], (B, L, N)) * 0.3
    Cm = jax.random.normal(keys[4], (B, L, N)) * 0.3
    yk, hk = ssd_ops.ssd(x, dt, A, Bm, Cm, chunk=chunk)
    yr, hr = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), rtol=2e-4, atol=2e-4)
