"""Tests for the §Perf serving levers: int8 KV decode, EP MoE, TP-resident
param specs, seq-parallel — semantics must be preserved."""
import dataclasses
import functools

import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="serving-optimization tests need jax (jax-native levers)"
)
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models.common import init_params, param_defs, param_pspecs
from repro.models.transformer import decode_step, forward_train, prefill

@functools.lru_cache(maxsize=None)
def KEY():
    # Lazy: a module-level PRNGKey would initialize the jax client at
    # pytest collection time and deadlock every forked process-backend
    # jax device worker later in the session (docs/columnar.md).
    return jax.random.PRNGKey(0)


def _quantize_cache(cache):
    """bf16 cache -> int8 cache with per-(b,h,s) scales (host-side helper,
    mirrors the prefill->decode hand-off a serving engine would do)."""

    def q(slice_):
        out = {}
        for k, v in slice_.items():
            if k in ("k", "v"):
                a = v.astype(jnp.float32)
                scale = jnp.max(jnp.abs(a), axis=-1) / 127.0 + 1e-9
                out[k] = jnp.clip(jnp.round(a / scale[..., None]), -127, 127).astype(jnp.int8)
                out[f"{k}_scale"] = scale
            else:
                out[k] = v
        return out

    return {si: q(sl) for si, sl in cache.items()}


def test_int8_kv_decode_close_to_bf16():
    cfg = smoke_config("olmo-1b")
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    params = init_params(cfg, KEY())
    B, S = 2, 24
    toks = jax.random.randint(KEY(), (B, S), 0, cfg.vocab_size)
    full_logits, _ = forward_train(cfg, params, toks)
    _, cache = prefill(cfg, params, toks[:, : S - 1], max_len=S + 4)
    qcache = _quantize_cache(cache)
    lg_q, new_cache = decode_step(
        cfg_q, params, toks[:, S - 1], qcache, jnp.full((B,), S - 1, jnp.int32)
    )
    ref = full_logits[:, S - 1]
    scale = float(jnp.abs(ref).max())
    err = float(jnp.abs(lg_q - ref).max())
    assert err < 0.08 * scale, f"int8 KV decode error {err} vs scale {scale}"
    # cache stays int8 (no silent dequantized copies in state)
    assert new_cache["0"]["k"].dtype == jnp.int8


def test_ep_moe_matches_dense_path():
    cfg = dataclasses.replace(smoke_config("qwen2-moe-a2.7b"), capacity_factor=64.0)
    cfg_ep = dataclasses.replace(cfg, moe_ep=True)
    params = init_params(cfg, KEY())
    toks = jax.random.randint(KEY(), (2, 16), 0, cfg.vocab_size)
    base, _ = forward_train(cfg, params, toks)
    ep, _ = forward_train(cfg_ep, params, toks)
    np.testing.assert_allclose(
        np.asarray(base, np.float32), np.asarray(ep, np.float32), rtol=2e-2, atol=2e-2
    )


def test_tp_resident_strips_fsdp_axis():
    cfg = smoke_config("olmo-1b")
    cfg_tp = dataclasses.replace(cfg, fsdp_params=False)
    specs = param_pspecs(cfg_tp)
    flat = jax.tree.leaves(
        jax.tree.map(lambda s: "data" in tuple(a for a in s if a), specs,
                     is_leaf=lambda x: hasattr(x, "index") and not isinstance(x, dict))
    )
    # no param spec mentions the FSDP axis
    import jax.sharding as shd

    def has_data(spec):
        return any(a == "data" or (isinstance(a, tuple) and "data" in a) for a in spec)

    for d in param_defs(cfg_tp).values():
        assert not has_data(d.spec), d


def test_seq_parallel_is_semantics_preserving():
    cfg = smoke_config("glm4-9b")
    cfg_sp = dataclasses.replace(cfg, seq_parallel=True)
    params = init_params(cfg, KEY())
    toks = jax.random.randint(KEY(), (2, 16), 0, cfg.vocab_size)
    a, _ = forward_train(cfg, params, toks)
    b, _ = forward_train(cfg_sp, params, toks)  # no mesh: constraint no-ops
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
