"""Chaos battery for the fault-tolerance subsystem: deterministic fault
injection (``repro.core.faults``) driving epoch-checkpoint recovery,
heartbeat stall detection, router re-forks, dead-letter accounting, and
graceful-signal teardown.  Every scenario asserts the recovered egress is
*exactly* the sequential reference — recovery that loses, duplicates, or
reorders tuples is a correctness bug, not a degraded mode — and that no
shared-memory segment leaks."""
import os
import signal
import subprocess
import sys
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    DeadLetter,
    FaultOptions,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    OpSpec,
    ProcessRuntime,
)
from repro.core.checkpoint import CheckpointStore, decode_barrier, encode_barrier
from repro.core.faults import (
    HANG,
    KILL,
    OP_ERROR,
    ROUTER_KILL,
    SPILL_DELAY,
    resolve_policies,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------- helpers
def _shm_segments():
    try:
        return {f for f in os.listdir("/dev/shm") if f.startswith("repro_")}
    except FileNotFoundError:  # non-Linux: nothing to check
        return set()


def _double(v):
    return [v * 2]


def _mod7(v):
    return v % 7


def _zero():
    return 0


def _ksum(s, k, v):
    s = (s or 0) + v
    return s, [(k, s)]


def _chain():
    """stateless double -> keyed running sum: the minimal shape that has
    both a replayable stage and a stage whose recovery needs a snapshot."""
    return [
        OpSpec("double", "stateless", _double),
        OpSpec(
            "acc", "partitioned", _ksum, key_fn=_mod7, num_partitions=14,
            init_state=_zero,
        ),
    ]


def _reference(n):
    states, out = {}, []
    for v in range(1, n + 1):
        d = v * 2
        k = d % 7
        states[k] = states.get(k, 0) + d
        out.append((k, states[k]))
    return out


def _slow_source(n, every=400, nap=0.02):
    """Feed with periodic naps so injected faults land mid-stream rather
    than after the pipeline has already drained."""
    for v in range(1, n + 1):
        if v % every == 0:
            time.sleep(nap)
        yield v


# -------------------------------------------------- fault-plan determinism
def test_fault_plan_generate_is_deterministic():
    kw = dict(n_faults=6, stage_widths=[2, 3], max_serial=5000,
              kinds=(KILL, HANG, OP_ERROR))
    a = FaultPlan.generate(7, **kw)
    b = FaultPlan.generate(7, **kw)
    assert a.specs == b.specs
    assert FaultPlan.generate(8, **kw).specs != a.specs
    # the delivery-path split partitions the schedule: signal faults fire
    # from the supervisor, op_error/spill_delay ride the fork arguments
    sup = {id(s) for s in a.supervisor_specs()}
    child = {
        id(s)
        for st_ in range(2)
        for w in range(3)
        for by_serial in a.child_specs(st_, w).values()
        for s in by_serial.values()
    }
    assert sup.isdisjoint(child)
    assert all(s.kind in (KILL, HANG, ROUTER_KILL) for s in a.supervisor_specs())


def test_fault_spec_and_options_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="explode").validate()
    with pytest.raises(ValueError, match="serial"):
        FaultSpec(kind=KILL, serial=0).validate()
    with pytest.raises(ValueError, match="on_error"):
        FaultOptions(on_error="explode").validate()
    opts = FaultOptions(
        plan=FaultPlan(specs=[FaultSpec(kind=SPILL_DELAY, delay=0.01)]),
        on_error={"acc": "dead_letter"},
    )
    opts.validate()
    rebuilt = FaultOptions.from_dict(opts.to_dict())
    assert rebuilt.plan.specs == opts.plan.specs
    assert rebuilt.policy_for("acc") == "dead_letter"
    assert rebuilt.policy_for("other") == "raise"
    assert resolve_policies({"acc": "skip"}, _chain()) == ("raise", "skip")


# ------------------------------------------------- checkpoint store (unit)
def test_checkpoint_store_epoch_protocol():
    store = CheckpointStore()
    assert store.latest(1) is None
    # acks complete only when every worker in the width has answered
    store.ack(1, 0, epoch=1, boundary=64, blob=b"w0", width=2)
    assert store.latest(1) is None
    store.ack(1, 1, epoch=1, boundary=64, blob=b"w1", width=2)
    snap = store.latest(1)
    assert snap is not None
    assert snap.boundary == 64 and snap.blobs == {0: b"w0", 1: b"w1"}
    # stale acks at or below the committed boundary are ignored
    store.ack(1, 0, epoch=1, boundary=64, blob=b"late", width=2)
    assert store.latest(1).blobs[0] == b"w0"
    # a forced (synthetic) checkpoint advances the epoch label
    store.force(1, boundary=128, blobs={0: b"x", 1: b"y"})
    assert store.latest(1).boundary == 128
    assert store.latest(1).epoch > snap.epoch
    store.clear_pending(1)
    assert store.latest(1).boundary == 128  # committed state survives


def test_barrier_codec_roundtrip():
    for epoch in (0, 1, 2**40):
        assert decode_barrier(encode_barrier(epoch)) == epoch


# ------------------------------------------- keyed kill -> snapshot replay
@pytest.mark.timeout(120)
def test_keyed_worker_kill_restores_from_checkpoint_exact_egress():
    """SIGKILL a keyed worker mid-stream: the supervisor must restore the
    last committed epoch snapshot, replay the tail of the feeder log, and
    produce byte-identical ordered egress."""
    n = 4000
    before = _shm_segments()
    plan = FaultPlan(specs=[
        FaultSpec(kind=KILL, stage=1, worker=1, serial=1500),
    ], seed=11)
    rt = ProcessRuntime.from_chain(
        _chain(), num_workers=3, collect_outputs=True, io_batch=8,
        checkpoint_interval=64, fault_plan=plan,
    )
    report = rt.run(_slow_source(n))
    assert rt.outputs == _reference(n)
    assert report.tuples_out == n
    assert rt.restarts >= 1 and rt.recoveries >= 1
    assert rt.dead_letters == []
    assert _shm_segments() == before


# --------------------------------------------------- router-kill recovery
@pytest.mark.timeout(120)
def test_router_kill_mid_stream_recovers_exact_egress():
    n = 4000
    before = _shm_segments()
    plan = FaultPlan(specs=[
        FaultSpec(kind=ROUTER_KILL, stage=1, serial=800),
    ], seed=3)
    rt = ProcessRuntime.from_chain(
        _chain(), num_workers=3, collect_outputs=True, io_batch=8,
        checkpoint_interval=64, fault_plan=plan,
    )
    rt.run(_slow_source(n))
    assert rt.outputs == _reference(n)
    assert rt.restarts >= 1 and rt.recoveries >= 1
    assert _shm_segments() == before


# ------------------------------------------------ SIGSTOP-hang stall soak
@pytest.mark.timeout(120)
def test_sigstop_hang_soak_stall_detector_recovers():
    """Seeded hang soak: SIGSTOPped workers are hung-not-dead, so only the
    heartbeat stall detector can find them; it must SIGKILL each into the
    ordinary crash path and the run must still finish exactly."""
    n = 4000
    before = _shm_segments()
    plan = FaultPlan(specs=[
        FaultSpec(kind=HANG, stage=1, worker=1, serial=600),
        FaultSpec(kind=HANG, stage=1, worker=0, serial=2400),
    ], seed=5)
    rt = ProcessRuntime.from_chain(
        _chain(), num_workers=3, collect_outputs=True, io_batch=8,
        checkpoint_interval=64, fault_plan=plan, stall_timeout=0.5,
    )
    rt.run(_slow_source(n))
    assert rt.outputs == _reference(n)
    assert rt.restarts >= 2, "both hung workers must be reaped"
    assert rt.recoveries >= 1
    assert _shm_segments() == before


# ------------------------------------------- kill during an elastic replan
def _spin(v):
    x = float(v)
    for _ in range(300):
        x = (x * 1.0000001 + 1.31) % 97.0
    return [int(x * 1000)]


def _mod9(v):
    return v % 9


def _spin_ksum(s, k, v):
    s = (s or 0) + v
    return s, [(k, s % 99991)]


@pytest.mark.timeout(120)
def test_keyed_kill_while_elastic_replans_churn():
    """Deliberately wrong priors force mid-run resizes of the stateless
    stage while an injected SIGKILL lands in the keyed stage: checkpoint
    restore and elastic replanning must compose without losing a tuple.
    (A restore that collides with a same-stage replan in its collect phase
    is unrecoverable by design; cross-stage it must abort the replan and
    proceed.)"""
    specs = [
        OpSpec("hot", "stateless", _spin, cost_us=1),  # lie: ~25 µs
        OpSpec(
            "cold", "partitioned", _spin_ksum, key_fn=_mod9,
            num_partitions=18, init_state=_zero, cost_us=60,  # lie: ~2
        ),
    ]
    n = 20000
    states, expected = {}, []
    for v in range(1, n + 1):
        out = _spin(v)[0]
        k = out % 9
        states[k] = states.get(k, 0) + out
        expected.append((k, states[k] % 99991))

    before = _shm_segments()
    plan = FaultPlan(specs=[
        FaultSpec(kind=KILL, stage=1, worker=0, serial=n // 2),
    ], seed=23)
    rt = ProcessRuntime.from_chain(
        specs, num_workers="auto", worker_budget=3, collect_outputs=True,
        cost_priors={"hot": 1.0, "cold": 60.0},
        replan_interval=0.05, replan_patience=2, batch_size=32,
        checkpoint_interval=128, fault_plan=plan,
    )
    report = rt.run(range(1, n + 1))
    assert rt.replans >= 1, "priors lie hard enough that a replan must fire"
    assert rt.restarts >= 1 and rt.recoveries >= 1
    assert rt.outputs == expected
    assert report.tuples_in == n
    assert _shm_segments() == before


# ------------------------------------------- dead-letter accounting (prop)
@pytest.mark.timeout(120)
@settings(max_examples=5, deadline=None)
@given(
    io_batch=st.sampled_from([1, 2, 8, 32]),
    bad=st.sets(st.integers(min_value=1, max_value=240), min_size=1, max_size=5),
)
def test_dead_letter_accounting_across_batch_sizes(io_batch, bad):
    """``on_error="dead_letter"`` quarantines exactly the faulted serials
    — for every dispatch-unit size — and every surviving tuple egresses in
    order.  Serial ownership is decided by dispatch, so a spec is planted
    per worker; only the owner fires it."""
    n = 240
    specs = [OpSpec("double", "stateless", _double)]
    plan = FaultPlan(specs=[
        FaultSpec(kind=OP_ERROR, stage=0, worker=w, serial=s)
        for s in sorted(bad) for w in range(2)
    ], seed=1)
    rt = ProcessRuntime.from_chain(
        specs, num_workers=2, collect_outputs=True, io_batch=io_batch,
        fault_plan=plan, on_error="dead_letter",
    )
    report = rt.run(range(1, n + 1))
    assert report.tuples_out == n - len(bad)
    assert sorted(d.serial for d in rt.dead_letters) == sorted(bad)
    assert all(
        isinstance(d, DeadLetter) and d.op == "double" and "InjectedFault" in d.error
        for d in rt.dead_letters
    )
    assert rt.outputs == [v * 2 for v in range(1, n + 1) if v not in bad]


@pytest.mark.timeout(60)
def test_on_error_policies_raise_and_skip():
    plan = FaultPlan(specs=[
        FaultSpec(kind=OP_ERROR, stage=0, worker=w, serial=5) for w in range(2)
    ])
    rt = ProcessRuntime.from_chain(
        [OpSpec("double", "stateless", _double)], num_workers=2,
        collect_outputs=True, fault_plan=plan,
    )
    with pytest.raises(RuntimeError, match="InjectedFault"):
        rt.run(range(1, 101))
    rt = ProcessRuntime.from_chain(
        [OpSpec("double", "stateless", _double)], num_workers=2,
        collect_outputs=True, fault_plan=plan, on_error="skip",
    )
    report = rt.run(range(1, 101))
    assert report.tuples_out == 99
    assert rt.dead_letters == []  # skip drops silently, no quarantine
    assert rt.outputs == [v * 2 for v in range(1, 101) if v != 5]


# ------------------------------------------------- graceful SIGTERM teardown
_SIGTERM_CHILD = """
import sys, time
sys.path.insert(0, {src!r})
from repro.core import OpSpec, ProcessRuntime

def spin(v):
    x = float(v)
    for _ in range(2000):
        x = (x * 1.0000001 + 1.31) % 97.0
    return [x]

def src():
    i = 0
    while True:
        yield i
        i += 1

rt = ProcessRuntime.from_chain(
    [OpSpec("spin", "stateless", spin)], num_workers=2,
)
print("READY", flush=True)
rt.run(src(), drain_timeout=300)
"""


@pytest.mark.timeout(120)
def _col_widen(v):
    return [(v, v * 3)]


def _col_ksum(s, k, t):
    s = (s or 0) + t[0]
    return s, [(k, s + t[1])]


def _col_chain():
    """Columnar-eligible chain: numeric tuples ride TAG_COLBLOCK through the
    stateless stage, then fall back to pickle at the keyed stage."""
    return [
        OpSpec("widen", "stateless", _col_widen),
        OpSpec("acc", "partitioned", _col_ksum, key_fn=_col_mod, num_partitions=14,
               init_state=_zero),
    ]


def _col_mod(t):
    return t[0] % 7


def _col_reference(n):
    states, out = {}, []
    for v in range(1, n + 1):
        t = (v, v * 3)
        k = t[0] % 7
        states[k] = states.get(k, 0) + t[0]
        out.append((k, states[k] + t[1]))
    return out


@pytest.mark.timeout(120)
def test_worker_kill_mid_columnar_stream_exact_egress_no_leak():
    """SIGKILL a stateless worker while the stream rides the columnar
    TAG_COLBLOCK path: re-fork + replay must re-derive byte-identical
    ordered egress (the columnar encoding is replay-indifferent — a
    replayed unit may re-publish as a block or as pickle and the reorder
    ring cannot tell), with zero shm segment leaks."""
    n = 4000
    before = _shm_segments()
    plan = FaultPlan(specs=[
        FaultSpec(kind=KILL, stage=0, worker=0, serial=1200),
        FaultSpec(kind=KILL, stage=1, worker=1, serial=2500),
    ], seed=23)
    rt = ProcessRuntime.from_chain(
        _col_chain(), num_workers=3, collect_outputs=True, io_batch=8,
        checkpoint_interval=64, fault_plan=plan, columnar=True,
    )
    report = rt.run(_slow_source(n))
    assert rt.outputs == _col_reference(n)
    assert report.tuples_out == n
    assert rt.restarts >= 2 and rt.recoveries >= 1
    assert rt.dead_letters == []
    assert _shm_segments() == before


@pytest.mark.timeout(120)
def test_device_worker_kill_recovers_via_checkpoint_replay():
    """SIGKILL a device-stage worker mid-stream: device batches span
    ingress units (advance-before-publish), so recovery must ride the
    checkpoint/replay-log group restore — and the recovered egress must
    stay bit-identical to the NumPy reference."""
    from repro.columnar import Schema, device_op

    n = 3000
    before = _shm_segments()
    dev = device_op("dev", "affine", Schema.of("i8", "i8"),
                    params={"a": 3, "b": -1}, backend="numpy")
    plan = FaultPlan(specs=[
        FaultSpec(kind=KILL, stage=1, worker=0, serial=900),
    ], seed=29)
    rt = ProcessRuntime.from_chain(
        [OpSpec("widen", "stateless", _col_widen), dev],
        num_workers=2, collect_outputs=True, io_batch=8,
        checkpoint_interval=64, fault_plan=plan, columnar=True,
        device_batch=32,
    )
    report = rt.run(_slow_source(n))
    assert rt.outputs == [(v * 3 - 1, v * 9 - 1) for v in range(1, n + 1)]
    assert report.tuples_out == n
    assert rt.restarts >= 1 and rt.recoveries >= 1
    assert _shm_segments() == before


def test_sigterm_mid_run_tears_down_without_shm_leak():
    """SIGTERM during a live stream must convert to SystemExit(143), run
    the normal teardown (reap children, unlink every segment), and exit
    with the conventional 128+15 status — not die mid-critical-section."""
    before = _shm_segments()
    script = _SIGTERM_CHILD.format(src=os.path.join(REPO_ROOT, "src"))
    proc = subprocess.Popen(
        [sys.executable, "-c", script],
        stdout=subprocess.PIPE, text=True, cwd=REPO_ROOT,
    )
    try:
        assert proc.stdout.readline().strip() == "READY"
        time.sleep(0.8)  # let the stream and its segments come up
        proc.send_signal(signal.SIGTERM)
        rc = proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)
    assert rc == 143, f"expected graceful SystemExit(143), got {rc}"
    assert _shm_segments() == before


# --------------------------------------------------- spill-deadline context
def test_spill_timeout_error_carries_stage_context():
    from repro.core.procrun import _await_spill

    with pytest.raises(TimeoutError) as ei:
        _await_spill(
            {}, 7, lambda: None, timeout=0.05,
            describe=lambda: "stage 1 (acc) worker 0; backlog=[3, 9]",
        )
    msg = str(ei.value)
    assert "serial 7" in msg
    assert "stage 1 (acc) worker 0" in msg
    assert "spill_timeout" in msg  # points at the ProcessOptions knob


@pytest.mark.timeout(60)
def test_spill_delay_fault_still_drains():
    """An injected spill-relay delay must slow delivery, not break it:
    oversized bundles still arrive and egress stays exact."""
    n = 40
    payload = bytes(200_000)

    def fat(v):
        return [(v, payload)]

    plan = FaultPlan(specs=[
        FaultSpec(kind=SPILL_DELAY, stage=0, worker=w, serial=10, delay=0.05)
        for w in range(2)
    ])
    rt = ProcessRuntime.from_chain(
        [OpSpec("fat", "stateless", fat)], num_workers=2,
        collect_outputs=True, io_batch=2, fault_plan=plan,
    )
    report = rt.run(range(1, n + 1))
    assert report.tuples_out == n
    assert [v for v, _ in rt.outputs] == list(range(1, n + 1))


# ------------------------------------------- serving mux churn under crashes
def _accsum(s, v):
    s = (s or 0) + v
    return s, [s]


@pytest.mark.timeout(180)
def test_mux_session_churn_survives_keyed_worker_kill():
    """Session churn on a multiplexed process runtime while a keyed worker
    is SIGKILLed mid-stream (docs/serving.md): checkpoint restore + replay
    must keep every session's egress exact — state is per-session, so any
    cross-session leakage or replay duplication corrupts the running sums —
    retire closing sessions cleanly, admit a new session into the freed
    slot, and leak no shared memory."""
    from repro.core.api import Engine, EngineConfig, ProcessOptions
    from repro.serve import MuxConfig, SessionMux

    before = _shm_segments()
    plan = FaultPlan(specs=[
        FaultSpec(kind=KILL, stage=1, worker=1, serial=1200),
    ], seed=11)
    eng = Engine(EngineConfig(
        backend="process", num_workers=3, batch_size=8,
        process=ProcessOptions(checkpoint_interval=64, io_batch=8),
        faults=FaultOptions(plan=plan),
    ))
    chain = [
        OpSpec("double", "stateless", _double),
        OpSpec("acc", "stateful", _accsum),  # mux makes this sid-partitioned
    ]
    inputs = {
        name: [(ord(name) * 37 + j) % 501 + 1 for j in range(n)]
        for name, n in (("a", 500), ("b", 700), ("c", 400), ("d", 300))
    }

    def oracle(vals):
        out, s = [], 0
        for v in vals:
            s += 2 * v
            out.append(s)
        return out

    mux = SessionMux(eng, chain, config=MuxConfig(max_sessions=3))
    with mux:
        handles = {k: mux.open() for k in "abc"}  # wave 1
        # interleave wave-1 ingress with naps so the injected kill lands
        # mid-stream (serial 1200 of the ~1600 wave-1 tuples)
        cursors = dict.fromkeys("abc", 0)
        while any(cursors[k] < len(inputs[k]) for k in "abc"):
            for k in "abc":
                lo = cursors[k]
                if lo >= len(inputs[k]):
                    continue
                handles[k].push(inputs[k][lo:lo + 40])
                cursors[k] = lo + 40
            time.sleep(0.01)
        # churn across the crash window: drain + retire a, admit d into
        # the freed slot while b/c still have tuples in flight
        want_a = oracle(inputs["a"])
        got_a = list(handles["a"].results(max_items=len(want_a), timeout=90))
        assert got_a == want_a
        handles["a"].close()
        assert handles["a"].poll() == []
        handles["d"] = mux.open()
        handles["d"].push(inputs["d"])
        for k in "bcd":
            want = oracle(inputs[k])
            got = list(handles[k].results(max_items=len(want), timeout=90))
            assert got == want, f"session {k}: egress diverged after recovery"
            handles[k].close()
            assert handles[k].poll() == []
        rt = mux._inner._rt
        assert rt.restarts >= 1 and rt.recoveries >= 1, (
            "injected keyed-worker kill never fired"
        )
    assert _shm_segments() == before


@pytest.mark.timeout(180)
def test_traffic_resize_survives_keyed_worker_kill_and_retired_sessions():
    """Chaos: SIGKILL a sid-partitioned worker while *traffic-triggered*
    elasticity is live-resizing that same stage, with session churn across
    the window (one session retires mid-run, another is admitted into the
    freed slot).  The combination must stay exact: per-session running
    sums survive checkpoint restore + replay at whatever width the policy
    chose, the retired session's slot is reusable, and any late replay
    output of a retired sid is counted undeliverable — never delivered to
    the wrong session, never a crash."""
    from repro.core.api import Engine, EngineConfig, ProcessOptions
    from repro.serve import MuxConfig, SessionMux

    before = _shm_segments()
    plan = FaultPlan(specs=[
        # worker 0 always exists, so the kill cannot go moot if it fires
        # before the first grow; serial 600 of ~1900 lands after the
        # saturation-triggered resize in practice
        FaultSpec(kind=KILL, stage=1, worker=0, serial=600),
    ], seed=23)
    eng = Engine(EngineConfig(
        backend="process", num_workers=1, batch_size=8,
        process=ProcessOptions(
            worker_budget=3, checkpoint_interval=64, io_batch=8,
            replan_interval=600.0,  # occupancy monitor parked: traffic only
            traffic_elastic=True, traffic_interval=0.05,
            traffic_grow_util=0.65, traffic_shrink_util=0.30,
            traffic_patience=1, traffic_cooldown=0.2,
        ),
        faults=FaultOptions(plan=plan),
    ))
    chain = [
        OpSpec("double", "stateless", _double),
        OpSpec("acc", "stateful", _accsum),  # mux makes this sid-partitioned
    ]
    inputs = {
        name: [(ord(name) * 41 + j) % 503 + 1 for j in range(n)]
        for name, n in (("a", 400), ("b", 700), ("c", 500), ("d", 300))
    }

    def oracle(vals):
        out, s = [], 0
        for v in vals:
            s += 2 * v
            out.append(s)
        return out

    mux = SessionMux(eng, chain, config=MuxConfig(
        max_sessions=3, state_partitions=4, load_signal_interval=0.02,
    ))
    with mux:
        handles = {k: mux.open() for k in "abc"}
        # flood the DRR queues: admission pressure trips the policy's
        # saturation override, so a grow fires early in the stream and the
        # serial-600 kill lands in/around the resize window
        cursors = dict.fromkeys("abc", 0)
        while any(cursors[k] < len(inputs[k]) for k in "abc"):
            for k in "abc":
                lo = cursors[k]
                if lo >= len(inputs[k]):
                    continue
                handles[k].push(inputs[k][lo:lo + 80])
                cursors[k] = lo + 80
        # churn across the crash/resize window: retire a, admit d
        want_a = oracle(inputs["a"])
        got_a = list(handles["a"].results(max_items=len(want_a), timeout=90))
        assert got_a == want_a
        handles["a"].close()
        assert handles["a"].poll() == []
        handles["d"] = mux.open()
        handles["d"].push(inputs["d"])
        for k in "bcd":
            want = oracle(inputs[k])
            got = list(handles[k].results(max_items=len(want), timeout=90))
            assert got == want, f"session {k}: egress diverged"
            handles[k].close()
            assert handles[k].poll() == []
        rt = mux._inner._rt
        assert rt.restarts >= 1 and rt.recoveries >= 1, (
            "injected keyed-worker kill never fired"
        )
        assert rt.grows >= 1, "traffic policy never grew the keyed stage"
        stats = mux.stats()
        assert stats["undeliverable"] >= 0  # counted, not delivered/crashed
    assert _shm_segments() == before
