"""Engine / Plan / Session API surface tests (the compile→plan→execute
redesign): strict config parsing with did-you-mean hints, the legacy shims'
uniform return contract across backends, golden ``PhysicalPlan.explain()``
renderings, ``to_dict``/``from_dict`` round-trip properties, and the
streaming ``Session`` push/results ordering property on both backends.
"""
import os
import warnings

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline env: degrade to seeded randomized sampling
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    ConfigError,
    Engine,
    EngineConfig,
    Merge,
    OpSpec,
    PhysicalPlan,
    ProcessOptions,
    Session,
    SessionStarvation,
    Split,
    ThreadOptions,
    UnstagedGraphWarning,
    run_graph,
    run_pipeline,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


# ---------------------------------------------------------------- operators
def _ident(v):
    return [v]


def _double(v):
    return [v * 2]


def _drop_mod3(v):
    return [v] if v % 3 else []


def _mod8(v):
    return v % 8


def _zero():
    return 0


def _ksum(s, k, v):
    s = (s or 0) + v
    return s, [(k, s)]


def _kcount(s, k, v):
    return (s or 0) + 1, [v]


def _sf_sum(s, v):
    s += v
    return s, [s]


def _keyed_chain():
    return [
        OpSpec("pre", "stateless", _ident, cost_us=3),
        OpSpec("hot", "partitioned", _kcount, key_fn=_mod8, num_partitions=64,
               init_state=_zero, cost_us=96),
        OpSpec("post", "stateless", _ident, cost_us=3),
    ]


def _session_chain():
    return [
        OpSpec("double", "stateless", _double, cost_us=2),
        OpSpec("ksum", "partitioned", _ksum, key_fn=_mod8, num_partitions=16,
               init_state=_zero, cost_us=4),
    ]


def _session_reference(values):
    state = {}
    out = []
    for v in values:
        d = v * 2
        k = d % 8
        state[k] = state.get(k, 0) + d
        out.append((k, state[k]))
    return out


def _split_merge_graph():
    nodes = {
        "pre": OpSpec("pre", "stateless", _ident, cost_us=4),
        "split": Split("round_robin"),
        "a": OpSpec("a", "stateless", _ident, cost_us=6),
        "b": OpSpec("b", "stateless", _ident, cost_us=6),
        "merge": Merge(),
        "sf": OpSpec("sf", "stateful", _sf_sum, init_state=_zero, cost_us=2),
    }
    edges = [
        ("pre", "split"), ("split", "a"), ("split", "b"),
        ("a", "merge"), ("b", "merge"), ("merge", "sf"),
    ]
    return nodes, edges


# --------------------------------------------------------- config validation
def test_unknown_kwarg_raises_config_error_with_suggestion():
    """The satellite bugfix: a typo like worker_budgett used to be silently
    swallowed by the process backend's **_ignored; now every legacy entry
    point parses through EngineConfig and raises a structured ConfigError."""
    with pytest.raises(ConfigError, match="worker_budget"):
        run_pipeline(_session_chain(), range(10), backend="process",
                     worker_budgett=8)
    err = None
    try:
        EngineConfig.from_kwargs(worker_budgett=8, backend="process")
    except ConfigError as e:
        err = e
    assert err is not None
    assert err.key == "worker_budgett"
    assert err.suggestion == "worker_budget"
    # a ConfigError is a ValueError: legacy except-clauses keep working
    assert isinstance(err, ValueError)


def test_process_only_option_on_thread_backend_conflicts():
    with pytest.raises(ConfigError, match="process-backend-only"):
        run_pipeline(_session_chain(), range(10), stages=2)
    with pytest.raises(ConfigError, match="io_batch"):
        EngineConfig.from_kwargs(io_batch=16)  # backend defaults to thread


@pytest.mark.parametrize("kw", [
    {"backend": "volcano"},
    {"num_workers": 0},
    {"num_workers": 2.5},
    {"batch_size": 0},
    {"heuristic": "nope"},
    {"reorder_scheme": "chaotic"},
    {"worklist_scheme": "mystery"},
    {"backend": "process", "stages": 0},
    {"backend": "process", "replan_threshold": 2.0},
    {"cost_priors": {"op": "cheap"}},
])
def test_invalid_values_raise_config_error(kw):
    with pytest.raises(ConfigError):
        EngineConfig.from_kwargs(**kw)


def test_run_graph_shim_validates_too():
    nodes, edges = _split_merge_graph()
    with pytest.raises(ConfigError, match="heuristc"):
        run_graph(nodes, edges, range(10), heuristc="ct")


def test_engine_rejects_config_plus_kwargs():
    with pytest.raises(ConfigError):
        Engine(EngineConfig(), num_workers=2)


def test_flat_and_subconfig_forms_conflict():
    with pytest.raises(ConfigError):
        EngineConfig.from_kwargs(
            backend="process", io_batch=8, process=ProcessOptions(io_batch=16)
        )


def test_config_dict_round_trip():
    cfg = EngineConfig(
        backend="process", num_workers="auto", batch_size=16,
        cost_priors={"hot": 12.5},
        thread=ThreadOptions(heuristic="lp"),
        process=ProcessOptions(worker_budget=3, stages=2),
    )
    d = cfg.to_dict()
    assert EngineConfig.from_dict(d).to_dict() == d


# --------------------------------------------------- legacy return contract
@pytest.mark.timeout(60)
def test_shim_return_contract_parity_across_backends():
    """run_pipeline(backend='process') used to return the runtime where the
    thread path returned a pipeline; both now return a JobResult-backed
    proxy with an identical documented surface."""
    src = list(range(1, 400))
    handles = {}
    for backend in ("thread", "process"):
        with pytest.warns(DeprecationWarning):
            handle, report = run_pipeline(
                _session_chain(), src, num_workers=2, backend=backend,
                collect_outputs=True,
            )
        handles[backend] = handle
        assert report.tuples_in == len(src)
    expected = _session_reference(src)
    for backend, handle in handles.items():
        assert type(handle).__name__ == "JobHandle"
        assert handle.outputs == expected, backend
        assert handle.egress_count == len(expected)
        assert isinstance(handle.markers, list) and handle.markers
        assert handle.result.plan.backend == backend
    # backend-specific introspection still passes through
    assert handles["process"].num_stages >= 1
    assert isinstance(handles["process"].stage_widths(), list)
    assert handles["thread"].specs[0].name == "double"


# ----------------------------------------------------------- golden explain
def _read_golden(name):
    with open(os.path.join(GOLDEN, name)) as f:
        return f.read().rstrip("\n")


def test_explain_golden_keyed_chain():
    eng = Engine(EngineConfig(
        backend="process", num_workers="auto", batch_size=32,
        process=ProcessOptions(worker_budget=5),
    ))
    plan = eng.plan(_keyed_chain())
    assert plan.explain() == _read_golden("plan_keyed_chain.txt")
    # widths came from the cost model: the hot keyed stage got the budget
    assert plan.stage_widths() == [1, 4]


def test_explain_golden_split_merge_dag_with_unstaged_tail():
    nodes, edges = _split_merge_graph()
    eng = Engine(EngineConfig(backend="process", num_workers=2))
    with pytest.warns(UnstagedGraphWarning):
        plan = eng.plan((nodes, edges))
    assert plan.explain() == _read_golden("plan_split_merge_dag.txt")
    assert plan.unstaged == ["a", "b", "merge", "sf", "split"]
    assert plan.routing == ["split", "merge"]


def _device_chain():
    from repro.columnar import Schema, device_op

    return [
        OpSpec("pre", "stateless", _ident, cost_us=3.0),
        device_op("affine", "affine", Schema.of("i8", scalar=True),
                  params={"a": 3, "b": 1}, cost_us=20.0),
        OpSpec("post", "stateless", _ident, cost_us=3.0),
    ]


def test_explain_golden_device_chain():
    """A columnar device chain renders the device stage, the columnar knob
    line, and the PV41x-verified footer deterministically."""
    eng = Engine(EngineConfig(
        backend="process", num_workers=2, batch_size=32,
        process=ProcessOptions(worker_budget=4, columnar=True,
                               device_batch=128),
    ))
    plan = eng.plan(_device_chain())
    assert plan.explain() == _read_golden("plan_device_chain.txt")
    # device stage is width-pinned (no elastic headroom) and checkpointed
    dev = [s for s in plan.stages if s.kind == "device"]
    assert len(dev) == 1 and dev[0].workers == dev[0].max_workers == 1
    assert dev[0].checkpointed
    # the device op row carries its declared schema width
    assert [op.schema_width for op in plan.ops] == [None, 1, None]


def test_device_plan_dict_round_trip_preserves_verification():
    eng = Engine(EngineConfig(
        backend="process", num_workers=2,
        process=ProcessOptions(worker_budget=4, columnar=True),
    ))
    plan = eng.plan(_device_chain())
    clone = PhysicalPlan.from_dict(plan.to_dict())
    assert clone.explain() == plan.explain()
    assert clone.verify(raise_on_violation=False) == []
    # degrade the clone: widen the device stage past its pin -> PV410
    dev = [s for s in clone.stages if s.kind == "device"][0]
    dev.workers = 3
    rules = {v.rule for v in clone.verify(raise_on_violation=False)}
    assert "PV410" in rules
    # degrade the ring: device batch below a dispatch unit -> PV411
    clone2 = PhysicalPlan.from_dict(plan.to_dict())
    clone2.ring["device_batch"] = 1
    rules2 = {v.rule for v in clone2.verify(raise_on_violation=False)}
    assert "PV411" in rules2
    # strip the schema claim -> PV412
    clone3 = PhysicalPlan.from_dict(plan.to_dict())
    clone3.ops[1].schema_width = None
    rules3 = {v.rule for v in clone3.verify(raise_on_violation=False)}
    assert "PV412" in rules3


# ------------------------------------------------------- plan dict round-trip
_KINDS = st.sampled_from(["stateless", "filter", "keyed", "stateful"])


def _op_from_kind(kind, i):
    if kind == "stateless":
        return OpSpec(f"sl{i}", "stateless", _double, cost_us=2 + i)
    if kind == "filter":
        return OpSpec(f"f{i}", "stateless", _drop_mod3, cost_us=3,
                      selectivity=0.66)
    if kind == "keyed":
        return OpSpec(f"k{i}", "partitioned", _kcount, key_fn=_mod8,
                      num_partitions=8 + i, init_state=_zero, cost_us=5 + i)
    return OpSpec(f"sf{i}", "stateful", _sf_sum, init_state=_zero, cost_us=4)


@settings(max_examples=20, deadline=None)
@given(
    kinds=st.lists(_KINDS, min_size=1, max_size=6),
    backend=st.sampled_from(["thread", "process"]),
    workers=st.sampled_from([1, 2, "auto"]),
    batch=st.sampled_from([1, 32]),
)
def test_plan_to_dict_from_dict_round_trip(kinds, backend, workers, batch):
    """Property: for random chains and configs, a plan survives the dict
    round-trip exactly — same dict, same explain() rendering."""
    specs = [_op_from_kind(k, i) for i, k in enumerate(kinds)]
    cfg = EngineConfig.from_kwargs(
        backend=backend, num_workers=workers, batch_size=batch,
        **({"worker_budget": 4} if backend == "process" else {}),
    )
    plan = Engine(cfg).plan(specs)
    d = plan.to_dict()
    revived = PhysicalPlan.from_dict(d)
    assert revived.to_dict() == d
    assert revived.explain() == plan.explain()
    assert not revived.bound
    with pytest.raises(ConfigError, match="unbound"):
        revived.graph
    # re-binding restores executability metadata
    assert revived.bind(specs).bound


def test_unbound_plan_cannot_run_but_bound_copy_can():
    specs = _session_chain()
    eng = Engine(EngineConfig(num_workers=2, collect_outputs=True))
    revived = PhysicalPlan.from_dict(eng.plan(specs).to_dict())
    with pytest.raises(ConfigError, match="unbound"):
        eng.run(revived, range(10))
    result = eng.run(revived.bind(specs), range(50))
    assert result.outputs == _session_reference(range(50))


def test_bind_rejects_mismatched_graph():
    eng = Engine(EngineConfig(num_workers=2))
    revived = PhysicalPlan.from_dict(eng.plan(_session_chain()).to_dict())
    with pytest.raises(ConfigError, match="do not match"):
        revived.bind(_keyed_chain())
    # same names, different kind: a cached plan must not pin widths onto a
    # graph whose operators changed shape underneath it
    impostor = [
        OpSpec("double", "stateless", _double, cost_us=2),
        OpSpec("ksum", "stateless", _double, cost_us=4),
    ]
    with pytest.raises(ConfigError, match="do not match"):
        revived.bind(impostor)


# ------------------------------------------------------------- engine.run
@pytest.mark.timeout(60)
def test_run_executes_pinned_plan_widths():
    """engine.run(plan, src) must execute THE plan: with elastic replanning
    off, the executed widths equal the planned widths (no recalibration)."""
    eng = Engine(EngineConfig(
        backend="process", num_workers="auto", batch_size=16,
        collect_outputs=True,
        process=ProcessOptions(worker_budget=4, elastic=False),
    ))
    plan = eng.plan(_session_chain())
    result = eng.run(plan, range(1, 500))
    assert result.plan.stage_widths() == plan.stage_widths()
    assert result.replans == 0
    assert result.outputs == _session_reference(range(1, 500))
    assert result.report.tuples_in == 499


def test_run_rejects_plan_for_other_backend():
    thread_plan = Engine(EngineConfig(num_workers=2)).plan(_session_chain())
    proc_engine = Engine(EngineConfig(backend="process", num_workers=2))
    with pytest.raises(ConfigError, match="backend"):
        proc_engine.run(thread_plan, range(10))


# ----------------------------------------------------------------- sessions
@pytest.mark.timeout(60)
@settings(max_examples=6, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=300),
    chunk=st.sampled_from([1, 7, 64]),
    read_between=st.sampled_from([0, 5]),
    backend=st.sampled_from(["thread", "process"]),
    batch=st.sampled_from([1, 16]),
)
def test_property_session_push_results_preserves_order(
    n, chunk, read_between, backend, batch
):
    """Property: arbitrary push chunking interleaved with partial results()
    reads yields exactly the sequential reference, in order, on both
    backends (the Session tentpole's correctness contract)."""
    values = list(range(n))
    expected = _session_reference(values)
    engine = Engine(EngineConfig.from_kwargs(
        backend=backend, num_workers=2, batch_size=batch,
    ))
    got = []
    with engine.open(engine.plan(_session_chain())) as session:
        for off in range(0, n, chunk):
            session.push(values[off:off + chunk])
            if read_between:
                # never ask for more than has been pushed: results() blocks
                # until the requested items exist (by design)
                pushed = min(off + chunk, n)
                want = min(read_between, pushed - len(got))
                if want > 0:
                    got.extend(session.results(max_items=want))
        report = session.close()
        got.extend(session.results())
    assert got == expected
    assert report.tuples_in == n
    assert report.tuples_out == n
    assert session.report is report


@pytest.mark.timeout(60)
def test_session_surface_and_stats_on_both_backends():
    for backend in ("thread", "process"):
        engine = Engine(EngineConfig.from_kwargs(backend=backend, num_workers=2))
        session = engine.open(_session_chain())
        assert isinstance(session, Session)
        session.push(range(100))
        stats = session.stats()
        assert stats["backend"] == backend
        assert stats["pushed"] == 100
        assert not stats["closed"]
        if backend == "process":
            assert stats["stage_widths"] == [2, 2]
        else:
            assert [op["op"] for op in stats["ops"]] == ["double", "ksum"]
        report = session.close()
        assert report.tuples_out == 100
        assert session.close() is report  # idempotent
        with pytest.raises(RuntimeError, match="closed"):
            session.push([1])


@pytest.mark.timeout(60)
def test_session_trims_consumed_outputs_on_both_backends():
    """A long-lived session must not retain its full egress history: once
    results() consumes past the trim threshold, the backing output list
    shrinks (bounded by in-flight work, not total traffic)."""
    n = 3 * Session._TRIM_THRESHOLD
    for backend in ("thread", "process"):
        engine = Engine(EngineConfig.from_kwargs(
            backend=backend, num_workers=2, batch_size=32,
        ))
        with engine.open(_session_chain()) as session:
            got = 0
            for off in range(0, n, 2048):
                session.push(range(off, min(off + 2048, n)))
                got += sum(1 for _ in session.results(max_items=2048))
            session.close()
            got += sum(1 for _ in session.results())
            backing = (
                session._pipe.outputs if backend == "thread"
                else session._rt.collected_outputs()
            )
        assert got == n, backend
        assert len(backing) < n // 2, (backend, len(backing))
        assert session.stats()["pushed"] == n


@pytest.mark.timeout(60)
def test_thread_session_push_applies_input_backpressure():
    """The thread backend's worklists are unbounded deques; the session's
    push gate must keep the input-side backlog bounded even when the
    producer is much faster than a lone worker."""
    slow = [OpSpec("slowish", "stateless", _spin_op, cost_us=50)]
    engine = Engine(EngineConfig(num_workers=1))
    with engine.open(slow) as session:
        session.push(range(30_000))
        backlog = sum(n.worklist_size() for n in session._pipe.nodes)
        cap = session._inflight_cap
        session.close()
    # the sweep is amortized over _GATE_EVERY pushes, so the gate admits at
    # most cap + _GATE_EVERY before it closes
    assert backlog <= cap + type(session)._GATE_EVERY, (backlog, cap)


def _spin_op(v):
    x = float(v)
    for _ in range(400):
        x = (x * 1.0000001 + 1.31) % 97.0
    return [x]


@pytest.mark.timeout(60)
def test_session_results_timeout_raises_with_snapshot():
    engine = Engine(EngineConfig(num_workers=1))
    with engine.open(_session_chain()) as session:
        with pytest.raises(SessionStarvation) as info:
            list(session.results(timeout=0.05))
        # diagnosable from the exception alone: live counters attached
        assert info.value.snapshot.get("pushed") == 0
        assert "snapshot" in str(info.value)
        # starvation does not poison the session: it keeps serving
        session.push([1])
        assert list(session.results(max_items=1)) == _session_reference([1])


@pytest.mark.timeout(60)
def test_thread_session_raises_on_worker_death_instead_of_hanging():
    """A raising op kills its worker thread; push/results/close must raise a
    clear RuntimeError instead of spinning on backpressure forever."""
    engine = Engine(EngineConfig(num_workers=1))
    session = engine.open([OpSpec("boom", "stateless", _boom)])
    with pytest.raises(RuntimeError, match="kaboom"):
        session.push(range(30_000))  # enough to close the gate post-death
        session.close()
    session._abort()
    with pytest.raises(RuntimeError, match="aborted"):
        list(session.results())


def test_two_op_tuple_is_a_chain_not_a_graph_pair():
    """A 2-tuple of OpSpecs must plan as a chain; a (specs, source) mistake
    must raise a structured ConfigError, not a raw TypeError."""
    eng = Engine(EngineConfig(num_workers=1))
    plan = eng.plan(tuple(_session_chain()))
    assert [op.name for op in plan.ops] == ["double", "ksum"]
    with pytest.raises(ConfigError, match="OpSpec"):
        eng.plan((_session_chain(), range(10)))


@pytest.mark.timeout(60)
def test_process_session_propagates_worker_errors():
    specs = [OpSpec("boom", "stateless", _boom)]
    engine = Engine(EngineConfig(backend="process", num_workers=2))
    session = engine.open(specs)
    with pytest.raises(RuntimeError, match="kaboom"):
        session.push(range(200))
        session.close()
    session._abort()  # teardown after failure must not leak shm


def _boom(v):
    if v == 37:
        raise ValueError("kaboom")
    return [v]


# ------------------------------------------------------------ run_query path
@pytest.mark.timeout(60)
def test_run_query_native_engine_path_keeps_contract():
    from repro.streams.tpcxbb import run_query

    handle, report = run_query("q15", n=2000, num_workers=2,
                               collect_outputs=True)
    assert report.tuples_in == 2000
    assert handle.egress_count == len(handle.outputs)
    with pytest.raises(ConfigError, match="stages"):
        run_query("q15", n=10, stages=2)  # thread backend: conflicting knob
