"""Substrate tests: optimizer, checkpoint/restore (elastic), data pipeline,
gradient compression, serving engine, training driver."""
import os

import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="substrate tests need jax (optimizer/checkpoint/engine "
    "are jax-native)"
)
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models.common import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, OrderedTokenPipeline
from repro.train.optimizer import OptConfig, apply_adamw, init_opt_state, schedule


# ----------------------------------------------------------------- optimizer
def test_adamw_reduces_loss_quadratic():
    ocfg = OptConfig(peak_lr=0.1, warmup_steps=2, decay_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = init_opt_state(ocfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, m = apply_adamw(ocfg, params, g, state)
    assert float(loss(params)) < 1e-2 * l0


def test_adamw_bf16_moments_master_off():
    ocfg = OptConfig(
        moment_dtype=jnp.bfloat16, master_fp32=False, peak_lr=0.5, warmup_steps=1
    )
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_opt_state(ocfg, params)
    assert "master" not in state
    assert state["mu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    params2, state2, _ = apply_adamw(ocfg, params, g, state)
    assert params2["w"].dtype == jnp.bfloat16
    assert float(params2["w"][0]) < 1.0


def test_schedule_warmup_and_decay():
    ocfg = OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    assert float(schedule(ocfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(schedule(ocfg, jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(schedule(ocfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)}, "opt": {"mu": jnp.ones(3)}}
    for step in (1, 2, 3):
        mgr.save(step, state, extra={"data_serial": step * 10})
    assert mgr.all_steps() == [2, 3]  # gc keeps 2
    step, restored, extra = mgr.restore()
    assert step == 3 and extra["data_serial"] == 30
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.arange(6.0).reshape(2, 3)
    )


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto a different 'topology' (here: default device placement but
    explicit shardings path) — shapes/dtypes/values must survive."""
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(7, state, extra={})
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    step, restored, _ = mgr.restore(shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(16.0).reshape(4, 4))


# ----------------------------------------------------------------- data
def test_data_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=256, seq_len=16, global_batch=4, seed=3)
    p1 = OrderedTokenPipeline(cfg)
    batches = [next(p1) for _ in range(5)]
    p2 = OrderedTokenPipeline(cfg, start_serial=3)
    b3 = next(p2)
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    assert all(b["tokens"].max() < 256 for b in batches)
    # exactly-once: resume cursor reproduces the identical stream
    p1.seek(0)
    again = next(p1)
    np.testing.assert_array_equal(again["tokens"], batches[0]["tokens"])


# ----------------------------------------------------------------- compression
def test_grad_compression_error_feedback_unbiased_over_steps():
    from repro.train.grad_compression import _dequantize, _quantize

    rng = np.random.RandomState(0)
    g_true = jnp.asarray(rng.randn(64) * 0.01)
    err = jnp.zeros(64)
    acc_q = jnp.zeros(64)
    acc_true = jnp.zeros(64)
    for _ in range(50):
        compensated = g_true + err
        q, s = _quantize(compensated)
        deq = _dequantize(q, s)
        err = compensated - deq
        acc_q = acc_q + deq
        acc_true = acc_true + g_true
    # error feedback: accumulated quantized sum tracks the true sum
    rel = float(jnp.linalg.norm(acc_q - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel


# ----------------------------------------------------------------- serving
# The serving tests pay one-time jit compilation for prefill+decode; on a
# loaded host that can dwarf the run itself, so they carry an explicit
# watchdog budget (still scaled by REPRO_TIMEOUT_SCALE, see conftest).
@pytest.mark.timeout(300)
def test_ordered_serving_engine_preserves_arrival_order():
    from repro.serve.engine import OrderedServingEngine

    cfg = smoke_config("olmo-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = OrderedServingEngine(cfg, params, max_slots=3, max_len=48)
    rng = np.random.RandomState(0)
    serials = [
        eng.submit(
            rng.randint(0, cfg.vocab_size, size=rng.randint(4, 12)),
            max_new_tokens=int(rng.randint(2, 10)),
        )
        for _ in range(8)
    ]
    comps = eng.run_to_completion()
    assert [c.serial for c in comps] == sorted(serials)
    assert eng.stats["prefills"] == 8


@pytest.mark.timeout(300)
def test_serving_matches_generate_reference():
    """Engine decode must agree with the pure generate() oracle per request."""
    from repro.models.transformer import generate
    from repro.serve.engine import OrderedServingEngine

    cfg = smoke_config("olmo-1b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompt = np.asarray([5, 9, 2, 77, 31], np.int32)
    n_new = 6
    eng = OrderedServingEngine(cfg, params, max_slots=2, max_len=32)
    eng.submit(prompt, max_new_tokens=n_new)
    comps = eng.run_to_completion()
    ref = generate(cfg, params, jnp.asarray(prompt)[None, :], num_steps=n_new - 1)
    np.testing.assert_array_equal(comps[0].tokens, np.asarray(ref[0]))


@pytest.mark.timeout(300)
def test_serving_decode_position_buffer_never_aliased():
    """Regression for the historical full-suite serving flake: when numpy
    happens to hand ``OrderedServingEngine.position`` a 64-byte-aligned
    buffer, ``jnp.asarray`` zero-copies it on CPU, and the engine's in-place
    ``position += active`` / prefill writes race the asynchronously
    dispatched decode — the kernel can read a *later* position and emit a
    wrong token (~15% of runs when aligned).  Force the aligned worst case
    deterministically and assert (a) every position handed to the jitted
    decode keeps its call-time value for the whole run, and (b) the output
    still matches the generate() oracle.
    """
    from repro.models.transformer import generate
    from repro.serve.engine import OrderedServingEngine

    cfg = smoke_config("olmo-1b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompt = np.asarray([5, 9, 2, 77, 31], np.int32)
    n_new = 6
    ref = np.asarray(generate(cfg, params, jnp.asarray(prompt)[None, :],
                              num_steps=n_new - 1)[0])

    def aligned_i32(n, align=64):
        base = np.zeros(n + align // 4, np.int32)
        off = (-base.__array_interface__["data"][0] % align) // 4
        view = base[off:off + n]
        assert view.__array_interface__["data"][0] % align == 0
        return base, view

    keep_alive = []  # distinct allocations: stop numpy reusing one block
    for _ in range(4):
        eng = OrderedServingEngine(cfg, params, max_slots=2, max_len=32)
        base, pos = aligned_i32(eng.max_slots)
        keep_alive.append(base)
        eng.position = pos
        captured = []  # (call-time copy, live reference handed to decode)
        inner = eng._decode

        def spy(p, toks, cache, position, _inner=inner, _cap=captured):
            _cap.append((np.asarray(position).copy(), position))
            return _inner(p, toks, cache, position)

        eng._decode = spy
        eng.submit(prompt, max_new_tokens=n_new)
        comps = eng.run_to_completion()
        np.testing.assert_array_equal(comps[0].tokens, ref)
        assert captured, "decode was never invoked"
        for at_call, held in captured:
            # an aliased buffer would now show the mutated (later) positions
            np.testing.assert_array_equal(np.asarray(held), at_call)


@pytest.mark.timeout(300)
def test_serving_engine_small_reorder_ring_no_livelock():
    """Regression: with a slow head-of-line request and a reorder ring smaller
    than the number of later completions, the single-threaded engine used to
    spin forever in send_blocking. Overflow completions must park host-side
    and the engine must terminate in bounded steps with ordered egress."""
    from repro.serve.engine import OrderedServingEngine

    cfg = smoke_config("olmo-1b")
    params = init_params(cfg, jax.random.PRNGKey(2))
    eng = OrderedServingEngine(cfg, params, max_slots=4, max_len=64, reorder_size=4)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, size=6)
    serials = []
    for i in range(64):
        # request 0 is the long head-of-line straggler; the rest finish fast
        serials.append(eng.submit(prompt, max_new_tokens=40 if i == 0 else 2))
    comps = eng.run_to_completion(max_steps=5000)
    assert [c.serial for c in comps] == sorted(serials)
    assert eng._reorder.parked_count() == 0
    assert eng.stats["emitted"] == 64


# ----------------------------------------------------------------- trainer
@pytest.mark.timeout(300)
def test_train_driver_end_to_end_with_resume(tmp_path):
    from repro.launch.train import main

    d = str(tmp_path / "ck")
    losses = main(
        [
            "--arch", "olmo-1b", "--smoke", "--steps", "8", "--batch", "2",
            "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "4",
        ]
    )
    assert len(losses) == 8
    # resume from step 8 checkpoint and continue to 12
    losses2 = main(
        [
            "--arch", "olmo-1b", "--smoke", "--steps", "12", "--batch", "2",
            "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "4", "--resume",
        ]
    )
    assert len(losses2) == 4  # steps 8..11 only
