#!/usr/bin/env python
"""Public-API lint (wired into ``scripts/verify.sh``).

Every name in ``repro.core.__all__``, ``repro.analysis.__all__``,
``repro.serve.__all__``, and ``repro.columnar.__all__`` must
(a) import — a stale ``__all__`` entry is a broken promise — and (b) carry a
non-empty docstring when it is a class or function (constants are exempt:
their meaning is documented where they are defined).  Classes are
additionally checked for docstrings on their public methods, so the Engine
and analysis surfaces cannot grow undocumented entry points.

Exit code 0 = clean, 1 = violations (listed on stderr).

Usage:  PYTHONPATH=src python scripts/api_lint.py
"""
from __future__ import annotations

import inspect
import sys


def _lint_module(mod, problems: list) -> int:
    """Lint one module's ``__all__``; returns the number of exported names."""
    label = mod.__name__
    exported = getattr(mod, "__all__", None)
    if not exported:
        problems.append(f"{label}: has no __all__")
        return 0
    for name in exported:
        try:
            obj = getattr(mod, name)
        except AttributeError:
            problems.append(
                f"{label}.{name}: listed in __all__ but not importable"
            )
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue  # constants / instances: documented at definition site
        if not (getattr(obj, "__doc__", None) or "").strip():
            problems.append(f"{label}.{name}: missing docstring")
            continue
        if inspect.isclass(obj):
            for mname, member in vars(obj).items():
                if mname.startswith("_"):
                    continue
                fn = member
                if isinstance(member, (staticmethod, classmethod)):
                    fn = member.__func__
                elif isinstance(member, property):
                    fn = member.fget
                if not inspect.isfunction(fn):
                    continue
                if fn.__name__ == "<lambda>":
                    continue  # dataclass field default, not an entry point
                if not (getattr(fn, "__doc__", None) or "").strip():
                    problems.append(f"{label}.{name}.{mname}: missing docstring")
    return len(exported)


def main() -> int:
    import repro.analysis as analysis
    import repro.columnar as columnar
    import repro.core as core
    import repro.serve as serve

    problems: list[str] = []
    total = (
        _lint_module(core, problems)
        + _lint_module(analysis, problems)
        + _lint_module(serve, problems)
        + _lint_module(columnar, problems)
    )
    if problems:
        print(f"api-lint: {len(problems)} violation(s)", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print(f"api-lint: OK ({total} exported names)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
