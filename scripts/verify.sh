#!/usr/bin/env bash
# Tier-1 verification + perf-plumbing smoke + docs link check (see ROADMAP.md).
#
#   ./scripts/verify.sh          # full: gated tier-1 + bench smoke + docs-check
#   ./scripts/verify.sh --fast   # gated tier-1 pytest only
#
# scripts/api_lint.py gates the public surface first: every name in
# repro.core.__all__, repro.analysis.__all__, repro.serve.__all__, and
# repro.columnar.__all__ must import and every exported class/function (and
# public method) must carry a docstring — the Engine, analysis, serving, and
# columnar APIs cannot grow undocumented entry points.
#
# The static-analysis gate (python -m repro.analysis --check) runs the
# guarded-by / lock-order / fork-safety passes over src/repro/core and fails
# on any finding outside the committed ANALYSIS_BASELINE.json (see
# docs/static-analysis.md).
#
# The tier-1 suite runs under scripts/coverage_gate.py: pytest -x -q with
# --durations=10 (slow-test regressions surface in every run) plus a
# line-coverage floor of 80% over src/repro/core/, src/repro/analysis/,
# src/repro/serve/, and src/repro/columnar/ independently (plus a stricter
# 85% per-file floor on core/api.py, the public surface) — a drop below any
# floor fails verification.  The bench smoke (~30 s) runs the thread/
# process/batched/staged/auto-allocated backends end to end — including the
# open-loop multiplexed `serving` workload (docs/serving.md) and the
# columnar-vs-pickle + device-offload rows (docs/columnar.md) — and rewrites
# BENCH_core.json, so the perf plumbing cannot silently rot.  The docs check
# (scripts/check_links.py) keeps docs/, the root markdown files, and
# benchmarks/README.md free of broken relative links.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python scripts/api_lint.py
python -m repro.analysis --check
python scripts/coverage_gate.py

if [[ "${1:-}" != "--fast" ]]; then
    python -m benchmarks.bench_core --smoke
    python scripts/check_links.py
fi
echo "verify: OK"
