#!/usr/bin/env bash
# Tier-1 verification + perf-plumbing smoke (see ROADMAP.md).
#
#   ./scripts/verify.sh          # full tier-1 pytest + bench_core smoke
#   ./scripts/verify.sh --fast   # pytest only
#
# The bench smoke (~3-5 s) runs the thread/process/batched backends end to
# end and rewrites BENCH_core.json, so the perf plumbing cannot silently rot.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    python -m benchmarks.bench_core --smoke
fi
echo "verify: OK"
