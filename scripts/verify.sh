#!/usr/bin/env bash
# Tier-1 verification + perf-plumbing smoke + docs link check (see ROADMAP.md).
#
#   ./scripts/verify.sh          # full: tier-1 pytest + bench smoke + docs-check
#   ./scripts/verify.sh --fast   # pytest only
#
# The bench smoke (~5 s) runs the thread/process/batched/staged backends end
# to end and rewrites BENCH_core.json, so the perf plumbing cannot silently
# rot.  The docs check (scripts/check_links.py) keeps docs/, the root
# markdown files, and benchmarks/README.md free of broken relative links.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    python -m benchmarks.bench_core --smoke
    python scripts/check_links.py
fi
echo "verify: OK"
