#!/usr/bin/env python
"""Tier-1 test run with a line-coverage floor over ``src/repro/core/`` and
``src/repro/analysis/`` (each package must clear the floor on its own).

The container has neither ``coverage`` nor ``pytest-cov``, so this gate
implements just enough with the stdlib: a ``sys.settrace`` line tracer
scoped to the core package (non-core frames are rejected at call time, so
test/benchmark code runs untraced), per-code-object early-out once every
line of a function has been seen, and a fork-child hook
(``repro.core.procrun._COV_HOOK``) so the process backend's workers and
routers — which exit via ``os._exit`` — dump their hit lines to a shared
directory before dying.  Executable lines come from walking each module's
compiled code objects (``co_lines``, PEP 626).

Runs the full tier-1 suite (``pytest -x -q --durations=10``) under the
tracer, merges parent + child hits, prints a per-file table, and exits
non-zero if aggregate core coverage falls below the floor.

Usage:  PYTHONPATH=src python scripts/coverage_gate.py [--floor PCT] [pytest args...]
"""
from __future__ import annotations

import argparse
import glob
import os
import sys
import tempfile
import threading
import uuid

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORE_DIR = os.path.join(REPO, "src", "repro", "core")
ANALYSIS_DIR = os.path.join(REPO, "src", "repro", "analysis")
SERVE_DIR = os.path.join(REPO, "src", "repro", "serve")
COLUMNAR_DIR = os.path.join(REPO, "src", "repro", "columnar")
# Each gated package must independently clear the floor: a well-covered core
# cannot paper over an untested analysis pass (or vice versa).
GATED_DIRS = [CORE_DIR, ANALYSIS_DIR, SERVE_DIR, COLUMNAR_DIR]
DEFAULT_FLOOR = 80.0
# Stricter per-file floors: the public Engine surface (core/api.py) must stay
# well-exercised even if the aggregate floor would tolerate a gap there.
PER_FILE_FLOORS = {
    "api.py": 85.0,
    # the fault-tolerance subsystem must stay exercised by the chaos battery
    "checkpoint.py": 80.0,
    "faults.py": 80.0,
    # allocation + occupancy/traffic elasticity policies (the serving tier's
    # grow/shrink loop lives here and must keep its unit battery)
    "costmodel.py": 80.0,
}

_hits: set = set()  # (abspath, lineno)
_remaining: dict = {}  # code object -> set of not-yet-seen lines
_done: set = set()  # fully covered code objects (skip tracing new calls)
_core_files: frozenset = frozenset()
_dump_dir = ""


def _line_tracer(frame, event, arg):
    if event == "line":
        code = frame.f_code
        rem = _remaining.get(code)
        if rem is None:
            rem = _remaining[code] = {
                ln for (_s, _e, ln) in code.co_lines() if ln
            }
        _hits.add((code.co_filename, frame.f_lineno))
        rem.discard(frame.f_lineno)
        if not rem:
            _done.add(code)
    return _line_tracer


def _call_tracer(frame, event, arg):
    if event != "call":
        return None
    code = frame.f_code
    if code.co_filename not in _core_files or code in _done:
        return None
    return _line_tracer


def _dump_child():
    """Installed as procrun._COV_HOOK: forked workers/routers call this just
    before os._exit so their (inherited + own) hit lines reach the parent
    via the dump directory."""
    try:
        path = os.path.join(
            _dump_dir, f"cov-{os.getpid()}-{uuid.uuid4().hex[:8]}.txt"
        )
        with open(path, "w") as f:
            for fn, ln in _hits:
                f.write(f"{fn}\t{ln}\n")
    except Exception:
        pass


def _executable_lines(path: str) -> set:
    with open(path, "r") as f:
        src = f.read()
    lines: set = set()
    stack = [compile(src, path, "exec")]
    while stack:
        code = stack.pop()
        lines.update(ln for (_s, _e, ln) in code.co_lines() if ln)
        for const in code.co_consts:
            if hasattr(const, "co_lines"):
                stack.append(const)
    return lines


class _CoveragePlugin:
    def pytest_sessionstart(self, session):
        # trace BEFORE the first core import so module bodies are counted
        threading.settrace(_call_tracer)
        sys.settrace(_call_tracer)
        import repro.core.procrun as procrun

        procrun._COV_HOOK = _dump_child

    def pytest_sessionfinish(self, session, exitstatus):
        sys.settrace(None)
        threading.settrace(None)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--floor", type=float, default=DEFAULT_FLOOR,
                    help=f"minimum aggregate %% (default {DEFAULT_FLOOR})")
    ap.add_argument("pytest_args", nargs="*",
                    help="extra pytest args (default: -x -q --durations=10)")
    args = ap.parse_args(argv)

    global _core_files, _dump_dir
    gated_paths = {
        d: sorted(glob.glob(os.path.join(d, "*.py"))) for d in GATED_DIRS
    }
    _core_files = frozenset(p for paths in gated_paths.values() for p in paths)
    _dump_dir = tempfile.mkdtemp(prefix="repro_cov_")
    # Watchdog headroom: line tracing slows the hot core paths, so the
    # conftest scales per-test limits by this factor under the gate.
    os.environ.setdefault("REPRO_TIMEOUT_SCALE", "3")

    import pytest

    pytest_args = args.pytest_args or ["-x", "-q", "--durations=10"]
    rc = pytest.main(pytest_args, plugins=[_CoveragePlugin()])
    if rc != 0:
        return int(rc)

    # merge child dumps
    for path in glob.glob(os.path.join(_dump_dir, "cov-*.txt")):
        try:
            with open(path) as f:
                for line in f:
                    fn, _, ln = line.rstrip("\n").partition("\t")
                    if fn in _core_files and ln:
                        _hits.add((fn, int(ln)))
            os.unlink(path)
        except (OSError, ValueError):
            pass
    try:
        os.rmdir(_dump_dir)
    except OSError:
        pass

    file_failures = []
    pkg_failures = []
    for pkg_dir, paths in gated_paths.items():
        rel_pkg = os.path.relpath(pkg_dir, REPO)
        print(f"\ncoverage gate: {rel_pkg}/ (floor {args.floor:.0f}%)")
        total_exec = total_hit = 0
        for path in paths:
            execable = _executable_lines(path)
            hit = {ln for (fn, ln) in _hits if fn == path} & execable
            total_exec += len(execable)
            total_hit += len(hit)
            pct = 100.0 * len(hit) / len(execable) if execable else 100.0
            file_floor = PER_FILE_FLOORS.get(os.path.basename(path))
            mark = ""
            if file_floor is not None:
                mark = f"  (file floor {file_floor:.0f}%)"
                if pct < file_floor:
                    file_failures.append((path, pct, file_floor))
            print(f"  {os.path.relpath(path, REPO):<38} "
                  f"{len(hit):>5}/{len(execable):<5} {pct:6.1f}%{mark}")
        agg = 100.0 * total_hit / total_exec if total_exec else 100.0
        print(f"  {'TOTAL':<38} {total_hit:>5}/{total_exec:<5} {agg:6.1f}%")
        if agg < args.floor:
            pkg_failures.append((rel_pkg, agg))
    for rel_pkg, agg in pkg_failures:
        print(f"coverage gate: FAIL — {rel_pkg}/ {agg:.1f}% < floor "
              f"{args.floor:.0f}%")
    for path, pct, file_floor in file_failures:
        print(f"coverage gate: FAIL — {os.path.relpath(path, REPO)} "
              f"{pct:.1f}% < file floor {file_floor:.0f}%")
    if pkg_failures or file_failures:
        return 2
    print("coverage gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
