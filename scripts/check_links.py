#!/usr/bin/env python3
"""Markdown link check (``make docs-check``): every relative link in the
repo's markdown tree must resolve to an existing file/directory, and heading
anchors must exist in the target document.

Scope: ``docs/**/*.md``, every ``*.md`` at the repo root, and
``benchmarks/README.md`` — except ``SNIPPETS.md``/``PAPERS.md``, which quote
exemplar text from *other* repos verbatim (their anchors point into
documents we do not have).  External links (http/https/mailto) are NOT
fetched — this check must stay offline-safe and fast; it guards against the
common rot (renamed files, moved sections) only.

Exit code 0 = clean, 1 = broken links (listed on stderr).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# [text](target) — excludes images' leading ! only for nicer messages;
# image targets are checked the same way.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _anchor(heading: str) -> str:
    """GitHub-style heading -> anchor slug."""
    slug = heading.strip().lower()
    slug = re.sub(r"[`*_]", "", slug)
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def _anchors(md_path: Path) -> set[str]:
    text = md_path.read_text(encoding="utf-8", errors="replace")
    return {_anchor(h) for h in _HEADING.findall(_CODE_FENCE.sub("", text))}


# Quoted third-party exemplar content: not ours to keep link-clean.
_EXCLUDE = {"SNIPPETS.md", "PAPERS.md"}


def _md_files() -> list[Path]:
    files = sorted((ROOT / "docs").glob("**/*.md")) if (ROOT / "docs").is_dir() else []
    files += sorted(p for p in ROOT.glob("*.md") if p.name not in _EXCLUDE)
    extra = ROOT / "benchmarks" / "README.md"
    if extra.is_file():
        files.append(extra)
    return files


def check() -> list[str]:
    errors: list[str] = []
    for md in _md_files():
        text = _CODE_FENCE.sub("", md.read_text(encoding="utf-8", errors="replace"))
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            rel = md.relative_to(ROOT)
            if not path_part:  # pure in-document anchor
                if frag and _anchor(frag) not in _anchors(md):
                    errors.append(f"{rel}: missing anchor #{frag}")
                continue
            dest = (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{rel}: broken link -> {target}")
                continue
            if frag and dest.suffix == ".md":
                if _anchor(frag) not in _anchors(dest):
                    errors.append(f"{rel}: missing anchor {path_part}#{frag}")
    return errors


def main() -> int:
    files = _md_files()
    errors = check()
    if errors:
        for e in errors:
            print(f"docs-check: {e}", file=sys.stderr)
        print(f"docs-check: {len(errors)} broken link(s) in {len(files)} files",
              file=sys.stderr)
        return 1
    print(f"docs-check: OK ({len(files)} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
