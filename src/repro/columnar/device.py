"""Device-offload execution for ``DEVICE`` operator stages.

A device stage accumulates columnar micro-batches until it holds a
device-sized batch, dispatches the batch to a jax/pallas kernel
*asynchronously* (jax dispatch returns before the computation finishes),
and only synchronises — ``jax.block_until_ready`` — when a result must
cross the ordered-egress boundary.  With ``device_inflight >= 2`` batches
in flight, host-side ingest/encode overlaps device compute
(double-buffering).  See ``docs/columnar.md`` for the dispatch protocol.

Everything jax lives behind function-local imports: this module imports
cleanly without jax, and :func:`resolve_backend` picks the pure-NumPy
reference backend when jax is absent (``auto``) or when the caller pins
``backend="numpy"``.  The NumPy backend evaluates the same elementwise
math eagerly so ordered egress is bit-identical between backends for
integer schemas; float results may differ in the last ulp across
backends because XLA fuses multiply-add (see ``docs/columnar.md``).

Kernels are elementwise column maps ``fn(*cols) -> cols`` registered in
:data:`KERNELS` under a name; each entry supplies a NumPy factory and a
jax factory.  ``affine_pallas`` is the pallas-backed entry — it lowers
through :func:`pl.pallas_call` (interpret mode, so it runs on CPU jax).
Batch boundaries never change results precisely *because* kernels are
elementwise; that is what lets the runtime flush partial batches on
barriers, EOF, or upstream stalls without forking the output.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import Any, Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np

from ..core.operators import DEVICE, OpSpec
from .block import ColumnBlock, Schema

Params = Tuple[Tuple[str, Any], ...]


def have_jax() -> bool:
    """True when jax is importable (cached by the import system itself)."""
    try:
        import jax  # noqa: F401
    except Exception:
        return False
    return True


def jax_fork_hazard() -> bool:
    """True when THIS process has already initialized a jax backend client.

    Forking after client initialization is unrecoverable: the child
    inherits XLA/LLVM threadpool locks whose owner threads do not exist,
    so its first jax computation deadlocks (clearing the backend registry
    in the child does not help — verified experimentally).  Merely
    *importing* jax is safe; only running a computation (or e.g.
    ``jax.random.PRNGKey``) creates the client.  The process runtime
    checks this before forking jax device workers and fails fast with
    guidance instead of hanging until the drain timeout."""
    import sys

    if "jax" not in sys.modules:
        return False
    try:
        from jax._src import xla_bridge as xb

        return bool(xb.backends_are_initialized())
    except Exception:
        return False


def resolve_backend(name: Optional[str] = "auto") -> str:
    """Resolve a backend request to ``"jax"`` or ``"numpy"``.

    ``auto`` prefers jax when importable; pinning ``jax`` without jax
    installed is an error (tests use it behind ``importorskip``)."""
    if name in (None, "", "auto"):
        return "jax" if have_jax() else "numpy"
    if name == "jax":
        if not have_jax():
            raise RuntimeError(
                "device backend 'jax' requested but jax is not importable; "
                "use backend='auto' to fall back to the NumPy reference"
            )
        return "jax"
    if name == "numpy":
        return "numpy"
    raise ValueError(f"unknown device backend {name!r} (auto|jax|numpy)")


# --------------------------------------------------------------- kernels
def _np_affine(params: Params) -> Callable[..., tuple]:
    kw = dict(params)
    a, b = kw.get("a", 1), kw.get("b", 0)

    def fn(*cols):
        return tuple(np.asarray(c * a + b, dtype=c.dtype) for c in cols)

    return fn


def _jax_affine(params: Params) -> Callable[..., tuple]:
    kw = dict(params)
    a, b = kw.get("a", 1), kw.get("b", 0)

    def fn(*cols):
        return tuple(c * a + b for c in cols)

    return fn


def _np_square(params: Params) -> Callable[..., tuple]:
    def fn(*cols):
        return tuple(np.asarray(c * c, dtype=c.dtype) for c in cols)

    return fn


def _jax_square(params: Params) -> Callable[..., tuple]:
    def fn(*cols):
        return tuple(c * c for c in cols)

    return fn


def _pallas_affine_body(x_ref, o_ref, *, a, b):
    o_ref[...] = x_ref[...] * a + b


def _jax_affine_pallas(params: Params) -> Callable[..., tuple]:
    import jax
    from jax.experimental import pallas as pl

    kw = dict(params)
    a, b = kw.get("a", 1), kw.get("b", 0)
    body = functools.partial(_pallas_affine_body, a=a, b=b)

    def fn(*cols):
        return tuple(
            pl.pallas_call(
                body,
                out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
                interpret=True,
            )(c)
            for c in cols
        )

    return fn


#: kernel name -> (numpy factory, jax factory); factories take the frozen
#: params tuple and return an elementwise column map ``fn(*cols) -> cols``.
KERNELS = {
    "affine": (_np_affine, _jax_affine),
    "square": (_np_square, _jax_square),
    "affine_pallas": (_np_affine, _jax_affine_pallas),
}


def make_kernel(
    kernel: str, backend: str, params: Params = ()
) -> Callable[..., tuple]:
    """Instantiate a registered kernel for a resolved backend."""
    try:
        np_factory, jax_factory = KERNELS[kernel]
    except KeyError:
        raise ValueError(
            f"unknown device kernel {kernel!r} (registered: {sorted(KERNELS)})"
        ) from None
    return jax_factory(params) if backend == "jax" else np_factory(params)


@functools.lru_cache(maxsize=None)
def _ref_kernel(kernel: str, params: Params) -> Callable[..., tuple]:
    return make_kernel(kernel, "numpy", params)


def ref_apply(value, kernel: str, params: Params, schema: Schema) -> list:
    """Per-value NumPy reference apply — the ``OpSpec.fn`` of a device op.

    This is what the thread backend, cost calibration, and correctness
    tests run; the batched device path must match it (bit-exactly for
    integer schemas)."""
    block = ColumnBlock.from_values([value], schema=schema)
    if block is None:
        raise TypeError(
            f"device-op input {value!r} does not fit schema {schema}"
        )
    outs = _ref_kernel(kernel, params)(*block.columns)
    return ColumnBlock.from_columns(schema, list(outs)).to_values()


def device_op(
    name: str,
    kernel: str,
    schema: Schema,
    *,
    params: Optional[dict] = None,
    device_batch: int = 0,
    backend: str = "auto",
    cost_us: float = 1.0,
) -> OpSpec:
    """Build a ``DEVICE``-kind :class:`OpSpec`.

    ``device_batch=0`` defers to the runtime's ``device_batch`` knob.
    The spec's ``fn`` is the NumPy reference (:func:`ref_apply`), so the
    same spec runs unchanged on the thread backend."""
    if kernel not in KERNELS:
        raise ValueError(
            f"unknown device kernel {kernel!r} (registered: {sorted(KERNELS)})"
        )
    frozen: Params = tuple(sorted((params or {}).items()))
    return OpSpec(
        name=name,
        kind=DEVICE,
        fn=functools.partial(
            ref_apply, kernel=kernel, params=frozen, schema=schema
        ),
        cost_us=cost_us,
        schema=schema,
        device_kernel=(kernel, frozen),
        device_batch=int(device_batch),
        device_backend=backend,
    )


class DeviceExecutor:
    """Double-buffered batch executor behind a device-stage worker.

    ``submit`` absorbs per-unit :class:`ColumnBlock`\\ s; once accumulated
    rows reach ``batch`` the pending blocks are concatenated and
    dispatched.  Up to ``inflight`` dispatched batches ride concurrently;
    submitting past the window synchronises on the *oldest* batch only,
    so with jax the newest dispatch overlaps both host ingest and the
    older batches still computing.  Completed batches are split back into
    the original per-unit blocks — serials and marks untouched — so the
    caller publishes each unit exactly as it arrived (the replay-identity
    requirement: re-fed units re-derive identical publishes regardless of
    how device batches regrouped them)."""

    def __init__(
        self,
        spec: OpSpec,
        batch: int = 256,
        inflight: int = 2,
        backend: str = "auto",
    ):
        if spec.kind != DEVICE or spec.device_kernel is None:
            raise ValueError(f"op {spec.name!r} is not a device op")
        kernel, params = spec.device_kernel
        self.schema: Schema = spec.schema
        self.batch = max(int(spec.device_batch or batch), 1)
        self.inflight_limit = max(int(inflight), 1)
        self.backend = resolve_backend(spec.device_backend or backend)
        fn = make_kernel(kernel, self.backend, params)
        if self.backend == "jax":
            import jax

            fn = jax.jit(fn)
        self._fn = fn
        self._pending: List[ColumnBlock] = []
        self._pending_rows = 0
        self._inflight: Deque[Tuple[Any, list]] = deque()
        #: dispatched batch count (observability)
        self.dispatches = 0

    @property
    def pending_rows(self) -> int:
        """Rows accumulated but not yet dispatched."""
        return self._pending_rows

    @property
    def inflight(self) -> int:
        """Dispatched batches not yet synchronised."""
        return len(self._inflight)

    def submit(self, block: ColumnBlock) -> List[ColumnBlock]:
        """Absorb one unit's block; returns any units whose batches
        completed (possibly none, never blocks unless the window is full)."""
        self._pending.append(block)
        self._pending_rows += len(block)
        if self._pending_rows < self.batch:
            return []
        self._dispatch()
        ready: List[ColumnBlock] = []
        while len(self._inflight) > self.inflight_limit:
            ready.extend(self._pop())
        return ready

    def flush(self) -> List[ColumnBlock]:
        """Dispatch any partial batch and synchronise everything in
        flight (barrier / EOF / upstream-stall path)."""
        if self._pending:
            self._dispatch()
        out: List[ColumnBlock] = []
        while self._inflight:
            out.extend(self._pop())
        return out

    def _dispatch(self) -> None:
        big = ColumnBlock.concat(self._pending)
        units = [(b.serials, b.marks) for b in self._pending]
        self._pending = []
        self._pending_rows = 0
        if self.backend == "jax":
            import jax.numpy as jnp

            # fresh np.concatenate output: safe to alias zero-copy, the
            # host never mutates it after dispatch
            outs = self._fn(*(jnp.asarray(c) for c in big.columns))
        else:
            outs = self._fn(*big.columns)
        self.dispatches += 1
        self._inflight.append((outs, units))

    def _pop(self) -> List[ColumnBlock]:
        outs, units = self._inflight.popleft()
        if self.backend == "jax":
            import jax

            outs = jax.block_until_ready(outs)
        cols = [
            np.asarray(o).astype(dt, copy=False)
            for o, dt in zip(outs, self.schema.dtypes)
        ]
        blocks: List[ColumnBlock] = []
        off = 0
        for serials, marks in units:
            n = len(serials)
            blocks.append(
                ColumnBlock(
                    self.schema,
                    [c[off : off + n] for c in cols],
                    serials,
                    list(marks),
                )
            )
            off += n
        return blocks
