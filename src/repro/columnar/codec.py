"""TAG_COLBLOCK wire format: :class:`ColumnBlock` <-> one shm span slot.

A block travels through the ring as a single contiguous frame written via
the same span-publish path bundles use (``core.shm`` only moves the bytes;
this module owns their meaning).  Layout, all little-endian::

    [nrows:4][flags:1][ncols:1][head_serial:8]      _HDR, 14 bytes
    [ncols field-code bytes]                        see block._CODE_BYTE
    [serials: nrows * i8]                           only if flags & EXPLICIT_SERIALS
    [column 0 raw bytes][column 1 raw bytes]...     nrows * itemsize each
    [marks pickle]                                  only if flags & HAS_MARKS

Scalar-vs-tuple row shape rides ``flags & SCALAR``.  Contiguous serials
(``head, head+1, ...`` — the overwhelmingly common dispatch-unit shape) are
elided from the wire and rebuilt from ``head_serial``; only reordered
device egress pays the explicit-serials vector.  Field *names* never hit
the wire: the decoder rebuilds a positional ``c0..ck`` schema, which is
sufficient because stage exchanges address columns by position.

Decoding is zero-copy for cell data: columns are ``np.frombuffer`` views
over the received payload bytes.  Ragged markers are the one pickled
sidecar (they are rare control records, not per-row data).
"""
from __future__ import annotations

import pickle
import struct
from functools import lru_cache
from typing import Optional, Tuple

import numpy as np

from .block import ColumnBlock, Schema, byte_to_code, code_to_byte

_PICKLE = pickle.HIGHEST_PROTOCOL

_HDR = struct.Struct("<IBBq")  # nrows:4  flags:1  ncols:1  head_serial:8

EXPLICIT_SERIALS = 1  # serials vector present (non-contiguous blocks)
HAS_MARKS = 2  # pickled marks sidecar trails the columns
SCALAR = 4  # rows decode as bare scalars, not 1-tuples

_I64 = np.dtype("<i8")


def encode_block(block: ColumnBlock) -> bytes:
    """Serialise a block to one TAG_COLBLOCK payload frame."""
    n = len(block)
    flags = 0
    if not block.contiguous_serials():
        flags |= EXPLICIT_SERIALS
    if block.marks:
        flags |= HAS_MARKS
    if block.schema.scalar:
        flags |= SCALAR
    parts = [
        _HDR.pack(n, flags, block.schema.width, block.head_serial),
        bytes(code_to_byte(c) for c in block.schema.codes),
    ]
    if flags & EXPLICIT_SERIALS:
        parts.append(np.ascontiguousarray(block.serials, dtype=_I64).tobytes())
    for col in block.columns:
        parts.append(np.ascontiguousarray(col).tobytes())
    if flags & HAS_MARKS:
        parts.append(pickle.dumps(block.marks, _PICKLE))
    return b"".join(parts)


@lru_cache(maxsize=256)
def _wire_schema(code_bytes: bytes, scalar: bool) -> Schema:
    # streams see the same few schemas for millions of frames; Schema
    # construction (dataclass + validation) is ~2µs, the cache hit ~100ns
    codes = tuple(byte_to_code(b) for b in code_bytes)
    return Schema.of(*codes, scalar=scalar)


def decode_block(data: bytes) -> ColumnBlock:
    """Rebuild a block from a TAG_COLBLOCK frame (zero-copy columns)."""
    n, flags, ncols, head = _HDR.unpack_from(data, 0)
    off = _HDR.size
    schema = _wire_schema(data[off : off + ncols], bool(flags & SCALAR))
    off += ncols
    if flags & EXPLICIT_SERIALS:
        serials = np.frombuffer(data, dtype=_I64, count=n, offset=off)
        off += n * 8
    else:
        serials = np.arange(head, head + n, dtype=_I64)
    cols = []
    for dt in schema.dtypes:
        cols.append(np.frombuffer(data, dtype=dt, count=n, offset=off))
        off += n * dt.itemsize
    marks = list(pickle.loads(data[off:])) if flags & HAS_MARKS else []
    return ColumnBlock(schema, cols, serials, marks)


class ColumnarCodec:
    """Builder half of the columnar dispatch path.

    The dispatcher feeds it contiguous ``(values, marks)`` micro-batches; it
    answers with an encoded frame when the batch fits a fixed-width schema
    and ``None`` when the batch must fall back to pickle.  The schema is
    locked by the first encodable batch so a stream cannot silently flip
    layouts mid-flight (a later mismatched batch just falls back)."""

    def __init__(self, schema: Optional[Schema] = None):
        self.schema = schema
        #: batches diverted to pickle (observability: bench/tests read this)
        self.fallbacks = 0

    def try_encode_unit(
        self, vals: list, marks: list, head_serial: int
    ) -> Optional[Tuple[bytes, int]]:
        """Encode one dispatch unit; returns ``(payload, span)`` or ``None``
        (pickle fallback).  ``marks`` is the dispatcher's ragged
        ``(row_offset, marker)`` sidecar for this unit."""
        block = ColumnBlock.from_values(
            vals, head_serial=head_serial, marks=marks, schema=self.schema
        )
        if block is None:
            self.fallbacks += 1
            return None
        if self.schema is None:
            self.schema = block.schema
        return encode_block(block), len(block)
