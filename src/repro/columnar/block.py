"""Typed fixed-width columnar micro-batches: :class:`Schema` / :class:`ColumnBlock`.

The streaming runtime moves tuples between stages in micro-batches; this
module gives those batches a *columnar* in-memory form — one NumPy vector
per field plus a per-row serial vector and a ragged marker sidecar — so a
numeric batch can cross a shared-memory ring as a handful of contiguous
buffer writes instead of a per-tuple pickle (see :mod:`.codec` for the wire
format and ``docs/columnar.md`` for the subsystem overview).

Schema rules
------------

A schema is an ordered list of fixed-width numeric fields.  Supported field
codes: ``i8``/``f8`` (the Python-exact widths — ``int``/``float`` round-trip
bitwise) and ``i4``/``f4`` (device-friendly narrow widths, used by
:class:`~.device.DeviceExecutor` schemas; narrowing casts are the declared
operator semantics, not an encoding artifact).  ``scalar=True`` marks a
one-field schema whose rows are bare scalars rather than 1-tuples — the two
decode differently and must not be conflated.

:meth:`Schema.infer` only ever infers ``i8``/``f8`` (from ``int``/``float``
cells), so inference never narrows a value.  Bools, ragged tuples, and any
non-int/float cell make a batch non-columnar: builders return ``None`` and
callers fall back to pickle.
"""
from __future__ import annotations

from array import array
from dataclasses import dataclass, field
from itertools import chain
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

#: field code -> numpy dtype string (little-endian, fixed width)
DTYPES = {"i8": "<i8", "f8": "<f8", "i4": "<i4", "f4": "<f4"}
#: field code -> encoded byte (wire stability: codes are append-only)
_CODE_BYTE = {"i8": 0, "f8": 1, "i4": 2, "f4": 3}
_BYTE_CODE = {b: c for c, b in _CODE_BYTE.items()}


def code_to_byte(code: str) -> int:
    """Wire byte for a field code (:mod:`.codec` helper)."""
    return _CODE_BYTE[code]


def byte_to_code(b: int) -> str:
    """Field code for a wire byte; raises ``ValueError`` on unknown bytes."""
    try:
        return _BYTE_CODE[b]
    except KeyError:
        raise ValueError(f"unknown columnar field-code byte {b}") from None


@dataclass(frozen=True)
class Schema:
    """Ordered fixed-width field layout of a :class:`ColumnBlock`.

    ``fields`` is a tuple of ``(name, code)`` pairs with codes from
    :data:`DTYPES`; ``scalar`` marks the bare-scalar single-field form.
    Frozen (hashable, fork-picklable) so operator specs can carry one.
    """

    fields: Tuple[Tuple[str, str], ...]
    scalar: bool = False

    def __post_init__(self):
        if not self.fields:
            raise ValueError("schema needs at least one field")
        names = [n for n, _c in self.fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate schema field names: {names}")
        for name, code in self.fields:
            if code not in DTYPES:
                raise ValueError(
                    f"field {name!r}: unknown code {code!r} "
                    f"(pick from {sorted(DTYPES)})"
                )
        if self.scalar and len(self.fields) != 1:
            raise ValueError("scalar schemas have exactly one field")

    # ------------------------------------------------------------ factories
    @classmethod
    def of(cls, *codes: str, scalar: bool = False) -> "Schema":
        """Positional shorthand: ``Schema.of("i8", "f8")`` names fields
        ``c0..ck``."""
        return cls(
            tuple((f"c{i}", code) for i, code in enumerate(codes)),
            scalar=scalar,
        )

    @classmethod
    def infer(cls, value: Any) -> Optional["Schema"]:
        """Schema for one sample value, or ``None`` when it is not a
        fixed-width numeric scalar/tuple (bools excluded on purpose)."""
        if type(value) is int:
            return cls((("c0", "i8"),), scalar=True)
        if type(value) is float:
            return cls((("c0", "f8"),), scalar=True)
        if type(value) is not tuple or not value:
            return None
        codes = []
        for cell in value:
            if type(cell) is int:
                codes.append("i8")
            elif type(cell) is float:
                codes.append("f8")
            else:
                return None
        return cls.of(*codes)

    # ---------------------------------------------------------- properties
    @property
    def width(self) -> int:
        """Number of columns."""
        return len(self.fields)

    @property
    def names(self) -> Tuple[str, ...]:
        """Field names, in column order."""
        return tuple(n for n, _c in self.fields)

    @property
    def codes(self) -> Tuple[str, ...]:
        """Field codes, in column order."""
        return tuple(c for _n, c in self.fields)

    @property
    def dtypes(self) -> Tuple[np.dtype, ...]:
        """NumPy dtypes, in column order (computed once per instance — the
        builder hot path reads this per block)."""
        dts = self.__dict__.get("_dtypes")
        if dts is None:
            dts = tuple(np.dtype(DTYPES[c]) for _n, c in self.fields)
            object.__setattr__(self, "_dtypes", dts)
        return dts

    @property
    def row_bytes(self) -> int:
        """Fixed bytes per row (the planner's transfer-cost input)."""
        return sum(dt.itemsize for dt in self.dtypes)


_I64 = np.dtype("<i8")

#: the only cell type each Python-exact code admits (bools, numpy scalars,
#: Decimals, … must fall back to pickle so egress types are untouched)
_EXACT_KIND = {"i8": int, "f8": float}


def _pack_column(col: Sequence[Any], code: str, dt: np.dtype):
    """One column of Python cells -> typed vector, or ``None`` on any cell
    that breaks the column's declared type.

    The hot path of :meth:`ColumnBlock.from_values`.  ``i8``/``f8`` columns
    pack through :mod:`array` (a single C loop) and gate on an exact type
    scan — ``set(map(type, col))`` is C-speed, unlike a per-cell genexpr.
    ``i4``/``f4`` columns are declared-cast device schemas, so they take the
    plain NumPy conversion (which raises on junk; the caller catches).
    May raise ``TypeError``/``ValueError``/``OverflowError`` — the caller's
    fallback signal alongside ``None``.
    """
    kind = _EXACT_KIND.get(code)
    if kind is None:  # i4/f4: casting is the declared semantics
        return np.asarray(col, dtype=dt)
    if set(map(type, col)) != {kind}:
        return None
    packed = array("q" if code == "i8" else "d", col)
    return np.frombuffer(packed, dtype=dt)


@dataclass
class ColumnBlock:
    """One columnar micro-batch: per-field NumPy vectors, per-row serials,
    and a ragged ``(row_offset, marker)`` sidecar.

    Invariants: every column (and ``serials``) has the same length;
    column ``i`` has ``schema.dtypes[i]``; ``marks`` offsets are in
    ``[0, len(block))`` and strictly increasing (dispatch order).
    Slicing returns NumPy *views* — blocks are treated as immutable once
    built (the zero-copy contract: decode and slice never copy cell data).
    """

    schema: Schema
    columns: List[np.ndarray]
    serials: np.ndarray
    marks: List[Tuple[int, Any]] = field(default_factory=list)

    # ----------------------------------------------------------- builders
    @classmethod
    def from_values(
        cls,
        values: Sequence[Any],
        head_serial: int = 1,
        marks: Optional[Sequence[Tuple[int, Any]]] = None,
        schema: Optional[Schema] = None,
    ) -> Optional["ColumnBlock"]:
        """Build a block from Python row values, or ``None`` when any row
        breaks the (inferred or given) schema — the pickle-fallback signal.

        Rows are scalars (``scalar`` schema) or equal-width tuples; serials
        are contiguous from ``head_serial`` (the dispatch-unit shape)."""
        if not values:
            return None
        if schema is None:
            schema = Schema.infer(values[0])
            if schema is None:
                return None
        try:
            if schema.scalar:
                col = _pack_column(values, schema.codes[0], schema.dtypes[0])
                if col is None:
                    return None
                cols = [col]
            else:
                k = schema.width
                for v in values:
                    if type(v) is not tuple or len(v) != k:
                        return None
                codes = schema.codes
                kind = _EXACT_KIND.get(codes[0])
                if kind is not None and codes.count(codes[0]) == k:
                    # homogeneous Python-exact schema (the common numeric
                    # unit): pack every cell row-major in ONE C pass, type-
                    # gate in one more, and view columns out of the matrix
                    packed = array(
                        "q" if codes[0] == "i8" else "d",
                        chain.from_iterable(values),
                    )
                    if set(map(type, chain.from_iterable(values))) != {kind}:
                        return None
                    mat2 = np.frombuffer(
                        packed, dtype=schema.dtypes[0]
                    ).reshape(len(values), k)
                    cols = list(mat2.T)
                else:
                    # mixed/narrow schema: per-column pack via transpose
                    cols_py = list(zip(*values))
                    mat: List[np.ndarray] = []
                    for i, dt in enumerate(schema.dtypes):
                        col = _pack_column(cols_py[i], codes[i], dt)
                        if col is None:
                            return None
                        mat.append(col)
                    cols = mat
        except (TypeError, ValueError, OverflowError):
            return None
        n = len(values)
        serials = np.arange(head_serial, head_serial + n, dtype=_I64)
        return cls(schema, cols, serials, list(marks or ()))

    @classmethod
    def from_columns(
        cls,
        schema: Schema,
        columns: Sequence[np.ndarray],
        head_serial: int = 1,
        serials: Optional[np.ndarray] = None,
        marks: Optional[Sequence[Tuple[int, Any]]] = None,
    ) -> "ColumnBlock":
        """Wrap ready-made column vectors (device-result path); casts each
        column to its schema dtype (no-op when already exact)."""
        cols = [
            np.ascontiguousarray(c, dtype=dt)
            for c, dt in zip(columns, schema.dtypes)
        ]
        if len(cols) != schema.width:
            raise ValueError(
                f"{len(cols)} columns for a {schema.width}-field schema"
            )
        n = len(cols[0]) if cols else 0
        if any(len(c) != n for c in cols):
            raise ValueError("ragged columns")
        if serials is None:
            serials = np.arange(head_serial, head_serial + n, dtype=_I64)
        else:
            serials = np.ascontiguousarray(serials, dtype=_I64)
            if len(serials) != n:
                raise ValueError("serials length != column length")
        return cls(schema, cols, serials, list(marks or ()))

    @classmethod
    def concat(cls, blocks: Sequence["ColumnBlock"]) -> "ColumnBlock":
        """Stack same-schema blocks (device batch accumulation)."""
        if not blocks:
            raise ValueError("concat of zero blocks")
        schema = blocks[0].schema
        if any(b.schema != schema for b in blocks):
            raise ValueError("concat of mixed-schema blocks")
        cols = [
            np.concatenate([b.columns[i] for b in blocks])
            for i in range(schema.width)
        ]
        serials = np.concatenate([b.serials for b in blocks])
        marks: List[Tuple[int, Any]] = []
        off = 0
        for b in blocks:
            marks.extend((off + i, m) for i, m in b.marks)
            off += len(b)
        return cls(schema, cols, serials, marks)

    # ------------------------------------------------------------- access
    def __len__(self) -> int:
        return len(self.serials)

    @property
    def nrows(self) -> int:
        """Row count (``len`` alias for readability at call sites)."""
        return len(self.serials)

    @property
    def head_serial(self) -> int:
        """Serial of row 0 (the span head for contiguous blocks)."""
        return int(self.serials[0]) if len(self.serials) else 0

    def contiguous_serials(self) -> bool:
        """Whether serials are ``head, head+1, ...`` (span-slot shape)."""
        n = len(self.serials)
        if n == 0:
            return True
        head = int(self.serials[0])
        return bool(
            (self.serials == np.arange(head, head + n, dtype=_I64)).all()
        )

    def slice(self, start: int, stop: int) -> "ColumnBlock":
        """Row-range view (zero-copy columns/serials; marks re-offset)."""
        marks = [
            (i - start, m) for i, m in self.marks if start <= i < stop
        ]
        return ColumnBlock(
            self.schema,
            [c[start:stop] for c in self.columns],
            self.serials[start:stop],
            marks,
        )

    def with_serials(self, head_serial: int) -> "ColumnBlock":
        """Copy of this block re-stamped with contiguous serials from
        ``head_serial`` (exchange routers re-assign serials per stage)."""
        n = len(self)
        return ColumnBlock(
            self.schema,
            self.columns,
            np.arange(head_serial, head_serial + n, dtype=_I64),
            self.marks,
        )

    def to_values(self) -> list:
        """Back to Python row values — ``int``/``float`` cells are exact for
        ``i8``/``f8`` columns (NumPy ``tolist`` round-trips them bitwise)."""
        if self.schema.scalar:
            return self.columns[0].tolist()
        return list(zip(*[c.tolist() for c in self.columns]))
