"""Columnar zero-copy batch subsystem + device-offload execution.

Micro-batches of fixed-width numeric tuples travel between stages as
:class:`ColumnBlock`\\ s — NumPy column vectors with per-row serials and a
ragged marker sidecar — written straight into shm ring span slots
(``TAG_COLBLOCK``) instead of round-tripping through pickle.  On top of
the block layer, ``DEVICE``-kind operators batch blocks up to device size
and dispatch them asynchronously to jax/pallas kernels with a pure-NumPy
reference backend.  See ``docs/columnar.md``.

Submodules import lazily (PEP 562, same pattern as :mod:`repro.serve`) so
``import repro.columnar`` costs nothing until a symbol is touched, and
nothing here ever imports jax at module scope — jax stays strictly
optional.
"""
from __future__ import annotations

_LAZY = {
    "Schema": ".block",
    "ColumnBlock": ".block",
    "DTYPES": ".block",
    "ColumnarCodec": ".codec",
    "encode_block": ".codec",
    "decode_block": ".codec",
    "DeviceExecutor": ".device",
    "device_op": ".device",
    "ref_apply": ".device",
    "make_kernel": ".device",
    "resolve_backend": ".device",
    "have_jax": ".device",
    "jax_fork_hazard": ".device",
    "KERNELS": ".device",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(_LAZY[name], __name__)
        value = getattr(mod, name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
