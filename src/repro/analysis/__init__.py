"""Concurrency invariant checker for the ``repro`` tree.

Three AST pass families over :mod:`repro.core` (see docs/static-analysis.md
for the annotation grammar and the full rule catalog):

- :mod:`.guards` — ``# guarded-by:`` lock-discipline lint (GB1xx),
- :mod:`.lockgraph` — interprocedural lock-order + blocking-call analysis
  (LK2xx),
- :mod:`.forksafety` — fork/shared-memory hygiene for the process backend
  (FS3xx),

plus :mod:`.plancheck` (PV4xx), the plan-time ordering-safety catalog behind
:meth:`repro.core.api.PhysicalPlan.verify`.

Run it: ``python -m repro.analysis [--check] [--json] [paths...]`` (or
``make analyze``).  ``--check`` gates on the committed baseline
(``ANALYSIS_BASELINE.json``): new findings fail, grandfathered ones pass.
"""
from .common import (
    RULES,
    Finding,
    SourceModule,
    analyze_paths,
    diff_baseline,
    load_baseline,
    write_baseline,
)
from .plancheck import CATALOG_VERSION, PlanViolation, verify_plan

__all__ = [
    "RULES",
    "Finding",
    "SourceModule",
    "analyze_paths",
    "diff_baseline",
    "load_baseline",
    "write_baseline",
    "CATALOG_VERSION",
    "PlanViolation",
    "verify_plan",
]
