"""Fork-safety lint (rules FS301–FS303).

The staged process backend (:mod:`repro.core.procrun`) forks workers with
the ``fork`` start method: the child inherits a snapshot of the parent's
memory.  Two classes of bug follow:

- **FS301** — a module that forks (`...Process(...)` / ``os.fork``) must not
  create ``threading`` primitives: a thread does not survive the fork, and a
  lock held at fork time stays locked *forever* in the child.  Any use of
  the ``threading`` module (or names imported from it) in a forking module
  is flagged — the supervisor is designed single-threaded, keep it that way.
- **FS302** — shared-memory segments (``SharedMemory(create=True)`` or the
  :mod:`repro.core.shm` ring classes built on it) must stay inside the
  unlink discipline: every class (or module-level function scope) that
  creates a segment must also call ``.unlink()`` somewhere, or the segment
  leaks into ``/dev/shm`` past process exit.  (Exactly one ``unlink`` per
  created name is the runtime rule; the lint checks the weaker static
  property that an unlink path exists at all.)
- **FS303** — a function registered as a signal handler
  (``signal.signal(SIG, handler)``) must not acquire locks: the handler can
  fire while the *same thread* already holds the lock mid-critical-section,
  and a non-reentrant acquire then deadlocks the process from the inside.
  Flagged inside handler bodies: ``.acquire()`` calls and ``with``-blocks
  over lock-ish objects (names matching ``lock``/``mutex``).  Handlers must
  stay lock-free — set a flag or raise, like
  :func:`repro.core.procrun._sig_raise`.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Set, Tuple

from .common import Finding, SourceModule

_SHM_CTORS = {"ShmSpscRing", "ShmReorderRing", "ExchangeRing"}


def _forks(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr in ("Process", "fork"):
                return True
            if isinstance(fn, ast.Name) and fn.id == "Process":
                return True
    return False


def _threading_names(tree: ast.Module) -> Set[str]:
    """Names bound from ``threading`` by ``from threading import X``."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "threading":
            for alias in node.names:
                out.add(alias.asname or alias.name)
    return out


def _creates_shm(call: ast.Call) -> Optional[str]:
    """The shm artifact a call creates, or None."""
    fn = call.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else None
    )
    if name == "SharedMemory":
        for k in call.keywords:
            if (
                k.arg == "create"
                and isinstance(k.value, ast.Constant)
                and k.value.value is True
            ):
                return "SharedMemory(create=True)"
        return None  # attach-only: the creator owns the unlink
    if name in _SHM_CTORS:
        return name
    return None


_LOCKISH_RE = re.compile(r"lock|mutex", re.IGNORECASE)


def _signal_handlers(tree: ast.Module) -> Tuple[Set[str], List[ast.Lambda]]:
    """Handler names (and inline lambdas) registered via ``signal.signal``."""
    names: Set[str] = set()
    lambdas: List[ast.Lambda] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or len(node.args) < 2:
            continue
        fn = node.func
        is_register = (
            (isinstance(fn, ast.Attribute) and fn.attr == "signal")
            or (isinstance(fn, ast.Name) and fn.id == "signal")
        )
        if not is_register:
            continue
        handler = node.args[1]
        if isinstance(handler, ast.Name):
            names.add(handler.id)
        elif isinstance(handler, ast.Lambda):
            lambdas.append(handler)
    return names, lambdas


def _lockish_name(expr: ast.AST) -> Optional[str]:
    """The lock-ish identifier a ``with`` context expression names, if any."""
    if isinstance(expr, ast.Call):
        expr = expr.func  # with self._lock(): / with threading.Lock():
    if isinstance(expr, ast.Attribute):
        name = expr.attr
    elif isinstance(expr, ast.Name):
        name = expr.id
    else:
        return None
    return name if _LOCKISH_RE.search(name) else None


def _lock_acquisitions(fn_node: ast.AST) -> List[Tuple[int, str]]:
    """(line, description) of every lock acquisition inside a handler."""
    hits: List[Tuple[int, str]] = []
    for node in ast.walk(fn_node):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "acquire"
        ):
            hits.append((node.lineno, ".acquire() call"))
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                name = _lockish_name(item.context_expr)
                if name:
                    hits.append(
                        (item.context_expr.lineno, f"'with {name}:' block")
                    )
    return hits


def _scope_of(tree: ast.Module, lineno: int) -> str:
    """Qualified ``Class.method`` scope containing a line (best effort)."""
    best = "<module>"
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            end = node.end_lineno or node.lineno
            if node.lineno <= lineno <= end:
                if best == "<module>":
                    best = node.name
                else:
                    best = f"{best}.{node.name}"
    return best


def check_module(mod: SourceModule) -> List[Finding]:
    """Run the fork-safety lint over one parsed module."""
    findings: List[Finding] = []
    tree = mod.tree

    # FS301: threading primitives in a forking module.
    if _forks(tree):
        from_names = _threading_names(tree)
        for node in ast.walk(tree):
            hit = None
            if (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "threading"
            ):
                hit = f"threading.{node.attr}"
            elif isinstance(node, ast.Name) and node.id in from_names:
                hit = node.id
            if hit:
                findings.append(
                    Finding(
                        rule="FS301",
                        path=mod.path,
                        line=node.lineno,
                        scope=_scope_of(tree, node.lineno),
                        message=f"{hit} used in a forking module: threads "
                        "don't survive fork and inherited locks stay "
                        "locked in the child",
                    )
                )

    # FS303: signal handlers must stay lock-free.
    handler_names, handler_lambdas = _signal_handlers(tree)
    handlers: List[ast.AST] = list(handler_lambdas)
    if handler_names:
        handlers.extend(
            n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n.name in handler_names
        )
    for fn_node in handlers:
        label = getattr(fn_node, "name", "<lambda>")
        for line, what in _lock_acquisitions(fn_node):
            findings.append(
                Finding(
                    rule="FS303",
                    path=mod.path,
                    line=line,
                    scope=_scope_of(tree, line),
                    message=f"{what} inside signal handler {label}: the "
                    "handler can interrupt the holder mid-critical-section "
                    "and deadlock; handlers must stay lock-free",
                )
            )

    # FS302: shm creation scopes must contain an unlink path.
    scopes: List[ast.AST] = [
        n for n in tree.body if isinstance(n, ast.ClassDef)
    ]
    module_level = [n for n in tree.body if not isinstance(n, ast.ClassDef)]
    for scope in scopes:
        creations = []
        has_unlink = False
        for node in ast.walk(scope):
            if isinstance(node, ast.Call):
                made = _creates_shm(node)
                if made:
                    creations.append((made, node.lineno))
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "unlink"
                ):
                    has_unlink = True
        if creations and not has_unlink:
            made, line = creations[0]
            findings.append(
                Finding(
                    rule="FS302",
                    path=mod.path,
                    line=line,
                    scope=scope.name,
                    message=f"{scope.name} creates {made} but never calls "
                    ".unlink(): the segment leaks past process exit",
                )
            )
    mod_creations = []
    mod_unlink = False
    for top in module_level:
        for node in ast.walk(top):
            if isinstance(node, ast.Call):
                made = _creates_shm(node)
                if made:
                    mod_creations.append((made, node.lineno))
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "unlink"
                ):
                    mod_unlink = True
    if mod_creations and not mod_unlink:
        made, line = mod_creations[0]
        findings.append(
            Finding(
                rule="FS302",
                path=mod.path,
                line=line,
                scope=_scope_of(tree, line),
                message=f"{made} created outside the unlink discipline "
                "(no module-level .unlink() call)",
            )
        )
    return findings
