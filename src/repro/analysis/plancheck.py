"""Plan-time ordering-safety rule catalog (rules PV401–PV408, PV410–PV412).

:meth:`repro.core.api.PhysicalPlan.verify` delegates here.  The rules assert
the structural invariants that make a plan's parallel execution externally
indistinguishable from the single-threaded reference (the paper's ordering
contract) — they hold by construction for every plan :meth:`Engine.plan`
builds, but a hand-built or deserialized-and-edited plan can violate them:

- **PV401** — a stateful stage must have width 1 (a single state box cannot
  be shared by two workers; :class:`~repro.core.procrun.StagePlan` pins it).
- **PV402** — a keyed stage's width must not exceed the smallest partition
  count among its operators (extra workers would split a partition's state).
- **PV403** — ring capacity must cover the publish span: ``reorder_size >=
  io_batch`` (a span publish must fit the entry window or it can never be
  admitted) and ``max_inflight <= reorder_size`` (procrun's clamp: serials
  in flight must fit the reorder window or the dispatcher livelocks).
- **PV404** — elastic headroom: ``max_workers >= workers`` per stage (the
  exchange is built with ``max_workers`` ingress rings; a width above it has
  no ring to read from).
- **PV405** — every stage with width > 1 must drain through a reorder ring
  (the plan must carry ring geometry with ``reorder_size >= 1``).
- **PV406** — per-operator caps must match kinds on any backend: a stateful
  operator's ``max_dop`` is exactly 1, a partitioned operator's is >= 1.
- **PV407** — checkpoint geometry: only keyed/stateful/device stages may be
  marked ``checkpointed`` (stateless workers carry no state to snapshot —
  they recover by re-fork + replay alone; device stages ride group restore
  because their batches span ingress units), and when any stage checkpoints
  the plan's epoch interval must cover a full dispatch unit
  (``checkpoint_interval >= io_batch``: barriers ride unit boundaries, a
  shorter interval cannot be honored).
- **PV408** — traffic-elasticity policy geometry: the hysteresis band must
  be non-empty (``traffic_shrink_util < traffic_grow_util`` — a shrink
  threshold at or above the grow threshold makes the policy oscillate a
  width forever), the p99-guard budget, when set, must be positive, and an
  *explicitly* armed policy (``traffic_elastic=True``) must have at least
  one stage it can ever act on (non-stateful with ``max_workers > 1``) —
  a policy with no resizable stage silently never fires.
- **PV410** — device stages are width-pinned: a device stage's planned
  ``workers`` must equal the ring geometry's ``device_workers`` pin and its
  ``max_workers`` (per-worker batching state strands half-filled batches
  under elastic resize, so device stages carry zero elastic headroom).
- **PV411** — device batching geometry: ``device_batch >= io_batch`` (a
  device batch smaller than a dispatch unit splits units across dispatches
  for no win) and ``device_batch × device_inflight <= reorder_size`` (the
  rows a device worker may hold unpublished must fit the reorder window or
  ordered egress can livelock behind them).
- **PV412** — columnar claims need fixed-width schemas: when the plan arms
  the columnar path (or cuts a device stage), every device operator must
  declare a fixed-width schema (``schema_width >= 1``) — the block codec
  cannot type a column vector without one.

The module deliberately imports nothing from :mod:`repro.core` — it reads
the plan duck-typed — so ``core.api`` can import it lazily with no cycle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

CATALOG_VERSION = 4


@dataclass(frozen=True)
class PlanViolation:
    """One ordering-safety violation found in a :class:`PhysicalPlan`."""

    rule: str
    message: str
    stage: Optional[int] = None  # stage index, if stage-scoped
    op: Optional[str] = None  # operator name, if op-scoped

    def render(self) -> str:
        """One-line human-readable form (used by the raised error)."""
        where = ""
        if self.stage is not None:
            where = f" [stage {self.stage}]"
        elif self.op is not None:
            where = f" [op {self.op}]"
        return f"{self.rule}{where}: {self.message}"


def verify_plan(plan) -> List[PlanViolation]:
    """Check ``plan`` (a :class:`~repro.core.api.PhysicalPlan`) against the
    ordering-safety catalog; returns violations (empty = safe)."""
    v: List[PlanViolation] = []
    op_caps = {}
    for op in plan.ops:
        op_caps[op.name] = op.max_dop
        if op.kind == "stateful" and op.max_dop != 1:
            v.append(
                PlanViolation(
                    rule="PV406",
                    op=op.name,
                    message=f"stateful operator has max_dop={op.max_dop!r}; "
                    "a single state box requires exactly 1",
                )
            )
        elif op.kind == "partitioned" and (op.max_dop is None or op.max_dop < 1):
            v.append(
                PlanViolation(
                    rule="PV406",
                    op=op.name,
                    message=f"partitioned operator has max_dop={op.max_dop!r}; "
                    "needs its partition count (>= 1)",
                )
            )

    ring = getattr(plan, "ring", None) or {}
    if plan.backend == "process":
        widest = max((s.workers for s in plan.stages), default=1)
        if widest > 1 and not ring.get("reorder_size"):
            v.append(
                PlanViolation(
                    rule="PV405",
                    message=f"a stage runs {widest} workers but the plan "
                    "carries no reorder-ring geometry to drain through",
                )
            )
        if ring:
            io_batch = ring.get("io_batch") or 1
            reorder = ring.get("reorder_size") or 0
            inflight = ring.get("max_inflight") or 0
            if reorder < io_batch:
                v.append(
                    PlanViolation(
                        rule="PV403",
                        message=f"reorder_size={reorder} < io_batch={io_batch}: "
                        "a full span can never enter the ring window",
                    )
                )
            if inflight > reorder:
                v.append(
                    PlanViolation(
                        rule="PV403",
                        message=f"max_inflight={inflight} > reorder_size="
                        f"{reorder}: in-flight serials overrun the window",
                    )
                )
        ckpt_stages = [
            s for s in getattr(plan, "stages", ())
            if getattr(s, "checkpointed", False)
        ]
        for s in ckpt_stages:
            if s.kind not in ("keyed", "stateful", "device"):
                v.append(
                    PlanViolation(
                        rule="PV407",
                        stage=s.index,
                        message=f"{s.kind} stage marked checkpointed; only "
                        "keyed/stateful/device stages carry recovery state",
                    )
                )
        if ckpt_stages:
            interval = ring.get("checkpoint_interval") or 0
            io_batch = ring.get("io_batch") or 1
            if interval < 1:
                v.append(
                    PlanViolation(
                        rule="PV407",
                        message="stages are marked checkpointed but the plan "
                        "carries no checkpoint_interval in its ring geometry",
                    )
                )
            elif interval < io_batch:
                v.append(
                    PlanViolation(
                        rule="PV407",
                        message=f"checkpoint_interval={interval} < io_batch="
                        f"{io_batch}: epoch barriers ride dispatch-unit "
                        "boundaries, a shorter interval cannot be honored",
                    )
                )
        popts = getattr(getattr(plan, "config", None), "process", None)
        if popts is not None:
            grow = getattr(popts, "traffic_grow_util", None)
            shrink = getattr(popts, "traffic_shrink_util", None)
            if (
                grow is not None and shrink is not None
                and not (0 < shrink < grow)
            ):
                v.append(
                    PlanViolation(
                        rule="PV408",
                        message=f"traffic policy hysteresis is empty: "
                        f"shrink_util={shrink} must sit strictly inside "
                        f"(0, grow_util={grow}) or widths oscillate",
                    )
                )
            guard = getattr(popts, "resize_latency_budget", None)
            if guard is not None and guard <= 0:
                v.append(
                    PlanViolation(
                        rule="PV408",
                        message=f"resize_latency_budget={guard} must be "
                        "positive (None disables the p99 guard)",
                    )
                )
            if getattr(popts, "traffic_elastic", None) is True:
                stages = list(getattr(plan, "stages", ()))
                if stages and not any(
                    s.kind not in ("stateful", "device") and s.max_workers > 1
                    for s in stages
                ):
                    v.append(
                        PlanViolation(
                            rule="PV408",
                            message="traffic_elastic=True but no stage is "
                            "resizable (non-stateful with max_workers > 1): "
                            "the policy can never act",
                        )
                    )

    for s in getattr(plan, "stages", ()):
        if s.kind == "stateful" and s.workers > 1:
            v.append(
                PlanViolation(
                    rule="PV401",
                    stage=s.index,
                    message=f"stateful stage planned at width {s.workers}; "
                    "stateful stages are pinned at 1",
                )
            )
        if s.kind == "keyed":
            caps = [
                op_caps[name]
                for name in s.ops
                if op_caps.get(name) is not None
            ]
            cap = min(caps) if caps else None
            if cap is not None and s.workers > cap:
                v.append(
                    PlanViolation(
                        rule="PV402",
                        stage=s.index,
                        message=f"keyed stage width {s.workers} exceeds its "
                        f"partition count {cap}",
                    )
                )
        if s.workers > s.max_workers:
            v.append(
                PlanViolation(
                    rule="PV404",
                    stage=s.index,
                    message=f"width {s.workers} exceeds elastic headroom "
                    f"max_workers={s.max_workers}; the exchange has no "
                    "ingress ring for the extra workers",
                )
            )
        if s.kind == "device":
            pin = ring.get("device_workers")
            if pin is not None and s.workers != pin:
                v.append(
                    PlanViolation(
                        rule="PV410",
                        stage=s.index,
                        message=f"device stage planned at width {s.workers} "
                        f"but the ring geometry pins device_workers={pin}",
                    )
                )
            if s.max_workers != s.workers:
                v.append(
                    PlanViolation(
                        rule="PV410",
                        stage=s.index,
                        message=f"device stage has elastic headroom "
                        f"(max_workers={s.max_workers} != workers="
                        f"{s.workers}); per-worker batching state cannot "
                        "survive a resize",
                    )
                )

    dev_stages = [
        s for s in getattr(plan, "stages", ()) if s.kind == "device"
    ]
    if dev_stages and ring:
        io_batch = ring.get("io_batch") or 1
        dbatch = ring.get("device_batch") or 0
        dinflight = ring.get("device_inflight") or 1
        reorder = ring.get("reorder_size") or 0
        if dbatch and dbatch < io_batch:
            v.append(
                PlanViolation(
                    rule="PV411",
                    message=f"device_batch={dbatch} < io_batch={io_batch}: "
                    "a device batch must cover at least one dispatch unit",
                )
            )
        if dbatch and reorder and dbatch * dinflight > reorder:
            v.append(
                PlanViolation(
                    rule="PV411",
                    message=f"device_batch={dbatch} x device_inflight="
                    f"{dinflight} exceeds reorder_size={reorder}: unpublished "
                    "device rows overrun the ordered-egress window",
                )
            )
    if dev_stages or ring.get("columnar"):
        for op in plan.ops:
            if op.kind != "device":
                continue
            width = getattr(op, "schema_width", None)
            if not width or width < 1:
                v.append(
                    PlanViolation(
                        rule="PV412",
                        op=op.name,
                        message="device operator declares no fixed-width "
                        "columnar schema (schema_width must be >= 1)",
                    )
                )
    return v
