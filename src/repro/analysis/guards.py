"""Guarded-by lint (rules GB101–GB104).

Grammar (see docs/static-analysis.md):

``self.attr = ...  # guarded-by: self._lock``
    Every write to ``self.attr`` anywhere in the class must occur lexically
    inside ``with self._lock:`` (constructors are exempt — ``__init__`` runs
    before the object is shared).

``self.attr = ...  # guarded-by(rw): self._lock``
    Reads of ``self.attr`` must be under the lock too.

``def meth(self):  # holds: self._lock``
    The method body may assume the lock is held (its call sites are checked
    by the lock-graph pass, rule LK203).

``self.attr = ...  # lock-free: <why>``
    Documents a deliberately unguarded shared attribute; the pass records it
    but checks nothing (the justification is the point).

Writes are assignments (including tuple targets and ``del``/subscript
stores) plus calls of known mutating container methods (``append``, ``pop``,
``update``, ...) and ``heapq.heappush``/``heappop`` on the attribute.
The match is lexical: aliasing (``s = self.attr; s.append(...)``) is
invisible, so keep guarded state un-aliased.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .common import Finding, SourceModule, norm_expr

_MUTATORS = {
    "append", "extend", "insert", "pop", "popleft", "appendleft",
    "remove", "clear", "update", "setdefault", "add", "discard",
    "sort", "reverse",
}
_HEAP_FNS = {"heappush", "heappop", "heappushpop", "heapreplace"}


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.X`` -> ``"X"``; anything else -> None."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _flat_targets(target: ast.AST) -> List[ast.AST]:
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[ast.AST] = []
        for e in target.elts:
            out.extend(_flat_targets(e))
        return out
    if isinstance(target, ast.Starred):
        return _flat_targets(target.value)
    return [target]


def _target_attr(node: ast.AST) -> Optional[str]:
    """The ``self.X`` an assignment/delete target mutates, if any
    (``self.X = ...``, ``self.X[i] = ...``, ``del self.X[...]``)."""
    attr = _self_attr(node)
    if attr is not None:
        return attr
    if isinstance(node, ast.Subscript):
        return _self_attr(node.value)
    return None


def _stmt_writes(st: ast.stmt) -> List[Tuple[str, int]]:
    """Attributes a simple statement writes, as ``(attr, line)`` pairs."""
    out: List[Tuple[str, int]] = []
    targets: List[ast.AST] = []
    if isinstance(st, ast.Assign):
        for t in st.targets:
            targets.extend(_flat_targets(t))
    elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
        targets.append(st.target)
    elif isinstance(st, ast.Delete):
        targets.extend(st.targets)
    for t in targets:
        attr = _target_attr(t)
        if attr is not None:
            out.append((attr, t.lineno))
    for node in ast.walk(st):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr in _MUTATORS:
            attr = _self_attr(fn.value)
            if attr is not None:
                out.append((attr, node.lineno))
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None
        )
        if name in _HEAP_FNS and node.args:
            attr = _self_attr(node.args[0])
            if attr is not None:
                out.append((attr, node.lineno))
    return out


def _stmt_reads(st: ast.AST) -> List[Tuple[str, int]]:
    """``self.X`` loads inside a statement/expression, as (attr, line)."""
    out = []
    for node in ast.walk(st):
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.ctx, ast.Load)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
        ):
            out.append((node.attr, node.lineno))
    return out


def _with_exprs(st: ast.stmt) -> List[str]:
    """Normalized lock expressions a ``with`` statement acquires."""
    out = []
    for item in st.items:
        expr = item.context_expr
        if isinstance(expr, (ast.Name, ast.Attribute)):
            out.append(norm_expr(ast.unparse(expr)))
    return out


class _ClassChecker:
    """Checks one class body against its guarded-by annotations."""

    def __init__(self, mod: SourceModule, cls: ast.ClassDef):
        self.mod = mod
        self.cls = cls
        self.findings: List[Finding] = []
        self.claimed_lines: Set[int] = set()
        # attr -> (mode, lock expr, declaration line)
        self.annotated: Dict[str, Tuple[str, str, int]] = {}
        self.acquired: Set[str] = set()
        self._discover()

    # ---------------------------------------------------------- discovery
    def _discover(self) -> None:
        span = range(self.cls.lineno, (self.cls.end_lineno or self.cls.lineno) + 1)
        anno_lines = {ln: v for ln, v in self.mod.guarded.items() if ln in span}
        for node in ast.walk(self.cls):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                self.acquired.update(_with_exprs(node))
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                held = self.mod.holds.get(node.lineno)
                if held:
                    self.acquired.add(held)
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    targets.extend(_flat_targets(t))
            elif isinstance(node, ast.AnnAssign):
                targets.append(node.target)
            for t in targets:
                attr = _self_attr(t)
                if attr is None or node.lineno not in anno_lines:
                    continue
                mode, lock = anno_lines[node.lineno]
                self.annotated[attr] = (mode, lock, node.lineno)
                self.claimed_lines.add(node.lineno)
        for attr, (_mode, lock, line) in sorted(self.annotated.items()):
            if lock not in self.acquired:
                self.findings.append(
                    Finding(
                        rule="GB103",
                        path=self.mod.path,
                        line=line,
                        scope=f"{self.cls.name}.{attr}",
                        message=f"guard {lock!r} is never acquired in "
                        f"{self.cls.name} (typo?)",
                    )
                )

    # ------------------------------------------------------------- checking
    def check(self) -> List[Finding]:
        """Run the write/read discipline check over every method."""
        if self.annotated:
            for node in self.cls.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if node.name == "__init__":
                        continue  # constructor-exempt
                    held = frozenset(
                        h for h in [self.mod.holds.get(node.lineno)] if h
                    )
                    self._block(node.body, held, node.name)
        return self.findings

    def _block(self, stmts, held: frozenset, meth: str) -> None:
        for st in stmts:
            if isinstance(st, (ast.With, ast.AsyncWith)):
                self._block(st.body, held | frozenset(_with_exprs(st)), meth)
            elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # A nested def may run after the lock is released: check its
                # body as if no lock were held (conservative).
                self._block(st.body, frozenset(), meth)
            elif isinstance(st, ast.If):
                self._expr(st.test, held, meth)
                self._block(st.body, held, meth)
                self._block(st.orelse, held, meth)
            elif isinstance(st, ast.While):
                self._expr(st.test, held, meth)
                self._block(st.body, held, meth)
                self._block(st.orelse, held, meth)
            elif isinstance(st, ast.For):
                self._expr(st.iter, held, meth)
                self._block(st.body, held, meth)
                self._block(st.orelse, held, meth)
            elif isinstance(st, ast.Try):
                self._block(st.body, held, meth)
                for h in st.handlers:
                    self._block(h.body, held, meth)
                self._block(st.orelse, held, meth)
                self._block(st.finalbody, held, meth)
            else:
                self._simple(st, held, meth)

    def _simple(self, st: ast.stmt, held: frozenset, meth: str) -> None:
        for attr, line in _stmt_writes(st):
            info = self.annotated.get(attr)
            if info and info[1] not in held:
                self._report("GB101", attr, line, meth, info, "write")
        self._expr(st, held, meth)

    def _expr(self, node: ast.AST, held: frozenset, meth: str) -> None:
        for attr, line in _stmt_reads(node):
            info = self.annotated.get(attr)
            if info and info[0] == "rw" and info[1] not in held:
                self._report("GB102", attr, line, meth, info, "read")

    def _report(self, rule, attr, line, meth, info, verb) -> None:
        f = Finding(
            rule=rule,
            path=self.mod.path,
            line=line,
            scope=f"{self.cls.name}.{meth}",
            message=f"{verb} of self.{attr} outside 'with {info[1]}' "
            f"(declared guarded at line {info[2]})",
        )
        if f not in self.findings:
            self.findings.append(f)


def check_module(mod: SourceModule) -> List[Finding]:
    """Run the guarded-by lint over one parsed module."""
    findings: List[Finding] = []
    claimed: Set[int] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef):
            checker = _ClassChecker(mod, node)
            findings.extend(checker.check())
            claimed |= checker.claimed_lines
    for line in sorted(set(mod.guarded) - claimed):
        findings.append(
            Finding(
                rule="GB104",
                path=mod.path,
                line=line,
                scope="<module>",
                message="guarded-by comment is not attached to a "
                "'self.attr = ...' statement inside a class",
            )
        )
    return findings
