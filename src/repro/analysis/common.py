"""Shared infrastructure for the static-analysis passes.

The passes (:mod:`.guards`, :mod:`.lockgraph`, :mod:`.forksafety`) are
pure-stdlib ``ast`` walks over the ``repro.core`` sources.  This module owns
everything they share:

- the source-comment annotation grammar (``# guarded-by:``, ``# lock-free:``,
  ``# holds:``) and the suppression grammar (``# analysis: ignore[RULE]: why``),
- the :class:`Finding` record and its stable baseline key,
- the per-file parse bundle (:class:`SourceModule`) handed to each pass,
- the driver (:func:`analyze_paths`) that runs every pass, applies
  suppressions, and emits the suppression-hygiene findings (AN001/AN002),
- the committed-baseline load/diff used by ``--check``.

Rule IDs are grouped by pass: ``GB1xx`` guards, ``LK2xx`` lock graph,
``FS3xx`` fork safety, ``PV4xx`` plan verification (:mod:`.plancheck`),
``AN0xx`` annotation/suppression hygiene.  docs/static-analysis.md is the
user-facing catalog; keep the two in sync.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# --------------------------------------------------------------------- rules
RULES: Dict[str, str] = {
    "GB101": "write to a guarded-by attribute outside its lock",
    "GB102": "read of a guarded-by(rw) attribute outside its lock",
    "GB103": "guarded-by names a lock never acquired in the class",
    "GB104": "malformed or unattached annotation comment",
    "LK201": "lock-acquisition cycle (potential deadlock)",
    "LK202": "blocking operation while holding a lock",
    "LK203": "call to a '# holds:' function without holding its lock",
    "FS301": "threading primitive in a module that forks workers",
    "FS302": "shared-memory creation without an unlink discipline",
    "FS303": "lock acquisition inside a signal handler",
    "AN001": "suppression without a justification",
    "AN002": "suppression that matches no finding",
    "PV401": "stateful stage planned with width > 1",
    "PV402": "keyed stage width exceeds its partition count",
    "PV403": "reorder-ring capacity cannot cover the publish span",
    "PV404": "elastic headroom below the active stage width",
    "PV405": "parallel stage without a reorder ring to drain through",
    "PV406": "operator parallelism cap inconsistent with its kind",
    "PV407": "checkpoint geometry inconsistent with the stage layout",
    "PV408": "traffic-elasticity policy geometry unsatisfiable",
}


@dataclass(frozen=True)
class Finding:
    """One analysis finding, keyed stably for the baseline file.

    ``scope`` is the enclosing ``Class.method`` (or ``<module>``) so the
    baseline key survives unrelated line churn; ``line`` is only for the
    human-facing report.
    """

    rule: str
    path: str  # repo-relative posix path
    line: int
    scope: str
    message: str

    def key(self) -> str:
        """Line-number-free identity used by the committed baseline."""
        return f"{self.rule}:{self.path}:{self.scope}"

    def render(self) -> str:
        """One-line human-readable report form."""
        return f"{self.path}:{self.line}: {self.rule} [{self.scope}] {self.message}"

    def to_dict(self) -> dict:
        """JSON-able form (``--json`` report rows and baseline entries)."""
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "scope": self.scope,
            "message": self.message,
        }


# --------------------------------------------------------------- annotations
_GUARDED_RE = re.compile(r"#\s*guarded-by(\((?P<mode>rw)\))?:\s*(?P<expr>[^#]+)")
_LOCKFREE_RE = re.compile(r"#\s*lock-free:\s*(?P<why>\S.*)")
_HOLDS_RE = re.compile(r"#\s*holds:\s*(?P<expr>[^#]+)")
_IGNORE_RE = re.compile(
    r"#\s*analysis:\s*ignore\[(?P<rules>[A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)\]"
    r"(?P<rest>.*)"
)


def norm_expr(text: str) -> str:
    """Normalize a lock expression for textual comparison (strip spaces)."""
    return re.sub(r"\s+", "", text)


@dataclass
class Suppression:
    """One ``# analysis: ignore[RULE,...]: justification`` comment."""

    line: int
    rules: Set[str]
    justification: str
    used: bool = False

    @property
    def justified(self) -> bool:
        """A justification must carry real prose after the rule list."""
        return bool(self.justification.strip(" :—-–"))


@dataclass
class SourceModule:
    """A parsed source file plus its line-level annotation side tables."""

    path: str  # repo-relative posix path
    abspath: str
    source: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)
    # line -> (attr-annotation mode, normalized lock expr): "w" or "rw"
    guarded: Dict[int, Tuple[str, str]] = field(default_factory=dict)
    # line -> justification text of a '# lock-free:' declaration
    lock_free: Dict[int, str] = field(default_factory=dict)
    # line -> normalized lock expr of a '# holds:' function contract
    holds: Dict[int, str] = field(default_factory=dict)
    suppressions: List[Suppression] = field(default_factory=list)

    @classmethod
    def parse(cls, abspath: str, relpath: str) -> "SourceModule":
        """Read + parse one file and extract its annotation comments."""
        with open(abspath, "r", encoding="utf-8") as f:
            source = f.read()
        mod = cls(
            path=relpath.replace(os.sep, "/"),
            abspath=abspath,
            source=source,
            tree=ast.parse(source, filename=relpath),
            lines=source.splitlines(),
        )
        for i, text in enumerate(mod.lines, start=1):
            if "#" not in text:
                continue
            m = _GUARDED_RE.search(text)
            if m:
                mod.guarded[i] = (m.group("mode") or "w", norm_expr(m.group("expr")))
            m = _LOCKFREE_RE.search(text)
            if m:
                mod.lock_free[i] = m.group("why").strip()
            m = _HOLDS_RE.search(text)
            if m:
                mod.holds[i] = norm_expr(m.group("expr"))
            m = _IGNORE_RE.search(text)
            if m:
                rules = {r.strip() for r in m.group("rules").split(",")}
                mod.suppressions.append(
                    Suppression(line=i, rules=rules, justification=m.group("rest"))
                )
        return mod

    def suppression_for(self, finding: Finding) -> Optional[Suppression]:
        """The suppression covering ``finding``, if any.

        A suppression applies to findings on its own line and on the line
        directly below it (standalone-comment placement)."""
        for sup in self.suppressions:
            if finding.rule in sup.rules and finding.line in (sup.line, sup.line + 1):
                return sup
        return None


# ------------------------------------------------------------------- driver
_DEFAULT_TARGET = os.path.join("src", "repro", "core")


def iter_py_files(paths: Sequence[str], root: str) -> List[Tuple[str, str]]:
    """Expand files/directories into ``(abspath, relpath)`` python sources."""
    out: List[Tuple[str, str]] = []
    for p in paths:
        ab = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isdir(ab):
            for dirpath, _dirs, files in sorted(os.walk(ab)):
                for f in sorted(files):
                    if f.endswith(".py"):
                        full = os.path.join(dirpath, f)
                        out.append((full, os.path.relpath(full, root)))
        elif ab.endswith(".py"):
            out.append((ab, os.path.relpath(ab, root)))
        else:
            raise FileNotFoundError(f"not a python file or directory: {p}")
    return out


def analyze_paths(
    paths: Optional[Sequence[str]] = None, root: Optional[str] = None
) -> List[Finding]:
    """Run every pass over ``paths`` (default: ``src/repro/core``).

    Applies suppressions (a justified — or merely present — suppression hides
    its finding; an unjustified one additionally raises AN001) and emits
    AN002 for suppressions that matched nothing.  Returns findings sorted by
    (path, line, rule).
    """
    from . import forksafety, guards, lockgraph

    root = root or os.getcwd()
    files = iter_py_files(paths or [_DEFAULT_TARGET], root)
    findings: List[Finding] = []
    for abspath, relpath in files:
        mod = SourceModule.parse(abspath, relpath)
        raw: List[Finding] = []
        raw.extend(guards.check_module(mod))
        raw.extend(lockgraph.check_module(mod))
        raw.extend(forksafety.check_module(mod))
        for f in raw:
            sup = mod.suppression_for(f)
            if sup is None:
                findings.append(f)
                continue
            sup.used = True
        for sup in mod.suppressions:
            if not sup.justified:
                findings.append(
                    Finding(
                        rule="AN001",
                        path=mod.path,
                        line=sup.line,
                        scope=f"ignore[{','.join(sorted(sup.rules))}]",
                        message="suppression needs a justification: "
                        "'# analysis: ignore[RULE]: why this is safe'",
                    )
                )
            elif not sup.used:
                findings.append(
                    Finding(
                        rule="AN002",
                        path=mod.path,
                        line=sup.line,
                        scope=f"ignore[{','.join(sorted(sup.rules))}]",
                        message="suppression matches no finding; delete it",
                    )
                )
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


# ----------------------------------------------------------------- baseline
def load_baseline(path: str) -> Set[str]:
    """Read the committed baseline file into a set of finding keys."""
    if not os.path.exists(path):
        return set()
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    if data.get("version") != 1:
        raise ValueError(f"unknown baseline version {data.get('version')!r}")
    return {e["key"] for e in data.get("findings", [])}


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    """Write the grandfathered-findings baseline for ``--check`` runs."""
    entries = sorted(
        {f.key(): {"key": f.key(), "message": f.message} for f in findings}.values(),
        key=lambda e: e["key"],
    )
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"version": 1, "findings": entries}, f, indent=2, sort_keys=True)
        f.write("\n")


def diff_baseline(
    findings: Sequence[Finding], baseline: Set[str]
) -> Tuple[List[Finding], Set[str]]:
    """Split findings into (new-vs-baseline, stale baseline keys)."""
    seen = {f.key() for f in findings}
    new = [f for f in findings if f.key() not in baseline]
    stale = {k for k in baseline if k not in seen}
    return new, stale
