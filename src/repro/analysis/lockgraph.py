"""Interprocedural lock-order + blocking-call analysis (rules LK201–LK203).

Builds the lock-acquisition graph of a module: a node per lock identity
(``Class:self._lock`` — one node per *declaration site*, so an edge means
"some code path acquires B while holding A"), an edge for every nested
acquisition, including acquisitions reached through resolvable calls
(``self.method()`` within a class, module-level ``fn()`` within the module).

- **LK201** — a cycle in that graph is a potential deadlock (two code paths
  acquiring the same locks in opposite orders).
- **LK202** — a *blocking* operation while holding a lock: ``time.sleep``,
  pipe/queue ``recv``/``recv_bytes``, ``join``, ``wait``, a bare
  ``.acquire()`` (untracked release; blocking unless called with
  ``blocking=False``), an unbounded ``send_blocking`` ring push, or a
  reentrant downstream emit (``self._send_downstream`` / ``self.downstream``)
  — the exact shape of the PR 1 parking-buffer deadlock.  Detection is by
  method *name* (documented heuristic); resolvable calls are searched
  transitively, so a method that takes a lock and calls a helper that sleeps
  is still flagged.
- **LK203** — a call to a function annotated ``# holds: <lock>`` from a site
  that does not lexically hold that lock.

Dynamic calls (stored callables, subscripted targets) are not resolved;
cross-instance aliases of the same lock declaration share one graph node,
which over-approximates (a strict instance ordering cannot be expressed) —
suppress with a justification where the instance order is provably acyclic.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .common import Finding, SourceModule, norm_expr

_BLOCKING_ATTRS = {
    "recv": "pipe/connection recv",
    "recv_bytes": "pipe/connection recv",
    "join": "thread/process join",
    "wait": "event/condition wait",
    "send_blocking": "unbounded ring push (spins until accepted)",
}
_REENTRANT_ATTRS = {
    "_send_downstream": "reentrant downstream emit",
    "downstream": "reentrant downstream emit",
}


@dataclass
class _Fn:
    """One function/method with the facts the graph needs."""

    qualname: str
    node: ast.AST
    cls: Optional[str]
    holds: Optional[str] = None  # lock id asserted held by '# holds:'
    # (lock id, line) acquired directly via `with`
    acquires: List[Tuple[str, int]] = field(default_factory=list)
    # (description, line) of direct blocking operations
    blocking: List[Tuple[str, int]] = field(default_factory=list)
    # resolvable callee qualnames with call lines
    calls: List[Tuple[str, int]] = field(default_factory=list)


def _lock_id(expr: ast.AST, cls: Optional[str]) -> Optional[str]:
    """Stable identity for a lock expression: scope-qualified source text."""
    if isinstance(expr, (ast.Name, ast.Attribute)):
        return f"{cls or '<module>'}:{norm_expr(ast.unparse(expr))}"
    return None


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted-name rendering of a call target."""
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - unparse failure
        return "<?>"


def _call_kw_false(call: ast.Call, kw: str) -> bool:
    """True if the call passes ``kw=False`` or a literal False first arg."""
    for k in call.keywords:
        if k.arg == kw and isinstance(k.value, ast.Constant) and k.value.value is False:
            return True
    if call.args and isinstance(call.args[0], ast.Constant):
        return call.args[0].value is False
    return False


def _blocking_desc(call: ast.Call) -> Optional[str]:
    """Blocking-op description for a call, or None (the name heuristic)."""
    fn = call.func
    dotted = _dotted(fn)
    if dotted == "time.sleep":
        return "time.sleep"
    if not isinstance(fn, ast.Attribute):
        return None
    base = fn.value
    if isinstance(base, ast.Constant):
        return None  # "sep".join(...) and friends
    if fn.attr == "join" and _dotted(base) in ("os.path", "posixpath", "ntpath"):
        return None
    if fn.attr == "acquire":
        if _call_kw_false(call, "blocking"):
            return None
        return "blocking acquire (untracked release)"
    if fn.attr in _BLOCKING_ATTRS:
        return _BLOCKING_ATTRS[fn.attr]
    if fn.attr in _REENTRANT_ATTRS:
        return _REENTRANT_ATTRS[fn.attr]
    return None


class _ModuleGraph:
    """Collects per-function facts, then runs the three checks."""

    def __init__(self, mod: SourceModule):
        self.mod = mod
        self.fns: Dict[str, _Fn] = {}
        self.methods: Dict[str, Set[str]] = {}  # class -> method names
        self.edges: Dict[Tuple[str, str], int] = {}  # (from, to) -> line
        self.findings: List[Finding] = []
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._collect(node, None)
            elif isinstance(node, ast.ClassDef):
                self.methods[node.name] = set()
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        self.methods[node.name].add(sub.name)
                        self._collect(sub, node.name)

    # ------------------------------------------------------------ collection
    def _collect(self, node: ast.AST, cls: Optional[str]) -> None:
        qual = f"{cls}.{node.name}" if cls else node.name
        fn = _Fn(qualname=qual, node=node, cls=cls)
        held_expr = self.mod.holds.get(node.lineno)
        if held_expr:
            fn.holds = f"{cls or '<module>'}:{held_expr}"
        for sub in ast.walk(node):
            if isinstance(sub, (ast.With, ast.AsyncWith)):
                for item in sub.items:
                    lid = _lock_id(item.context_expr, cls)
                    if lid:
                        fn.acquires.append((lid, sub.lineno))
            elif isinstance(sub, ast.Call):
                desc = _blocking_desc(sub)
                if desc:
                    fn.blocking.append((desc, sub.lineno))
                callee = self._resolve(sub.func, cls)
                if callee:
                    fn.calls.append((callee, sub.lineno))
        self.fns[qual] = fn

    def _resolve(self, func: ast.AST, cls: Optional[str]) -> Optional[str]:
        if (
            isinstance(func, ast.Attribute)
            and isinstance(func.value, ast.Name)
            and func.value.id == "self"
            and cls is not None
            and func.attr in self.methods.get(cls, ())
        ):
            return f"{cls}.{func.attr}"
        if isinstance(func, ast.Name) and func.id in self.fns:
            return func.id
        return None

    # --------------------------------------------------------- transitivity
    def _transitive(self, qual: str, what: str, seen=None) -> List[Tuple[str, int]]:
        """Own + callee-reachable ``acquires`` or ``blocking`` facts."""
        seen = seen if seen is not None else set()
        if qual in seen or qual not in self.fns:
            return []
        seen.add(qual)
        fn = self.fns[qual]
        out = list(getattr(fn, what))
        for callee, line in fn.calls:
            for item, _l in self._transitive(callee, what, seen):
                out.append((f"{item} (via {callee})" if what == "blocking" else item,
                            line))
        return out

    # -------------------------------------------------------------- checking
    def run(self) -> List[Finding]:
        """Walk every function with lexical held-lock tracking."""
        for fn in self.fns.values():
            held = [fn.holds] if fn.holds else []
            self._walk(fn, fn.node.body, held)
        self._cycles()
        return self.findings

    def _walk(self, fn: _Fn, stmts, held: List[str]) -> None:
        for st in stmts:
            if isinstance(st, (ast.With, ast.AsyncWith)):
                got = []
                for item in st.items:
                    lid = _lock_id(item.context_expr, fn.cls)
                    if lid:
                        for h in held + got:
                            self.edges.setdefault((h, lid), st.lineno)
                        got.append(lid)
                self._walk(fn, st.body, held + got)
            elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._walk(fn, st.body, [])  # closure may outlive the lock
            elif isinstance(st, (ast.If, ast.While)):
                self._calls_in(fn, st.test, held)
                self._walk(fn, st.body, held)
                self._walk(fn, st.orelse, held)
            elif isinstance(st, ast.For):
                self._calls_in(fn, st.iter, held)
                self._walk(fn, st.body, held)
                self._walk(fn, st.orelse, held)
            elif isinstance(st, ast.Try):
                self._walk(fn, st.body, held)
                for h in st.handlers:
                    self._walk(fn, h.body, held)
                self._walk(fn, st.orelse, held)
                self._walk(fn, st.finalbody, held)
            else:
                self._calls_in(fn, st, held)

    def _calls_in(self, fn: _Fn, node: ast.AST, held: List[str]) -> None:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            desc = _blocking_desc(sub)
            if desc and held:
                self._blocked(fn, desc, sub.lineno, held)
            callee = self._resolve(sub.func, fn.cls)
            if not callee:
                continue
            cfn = self.fns.get(callee)
            if cfn and cfn.holds and cfn.holds not in held:
                self.findings.append(
                    Finding(
                        rule="LK203",
                        path=self.mod.path,
                        line=sub.lineno,
                        scope=fn.qualname,
                        message=f"call to {callee}() requires holding "
                        f"{cfn.holds.split(':', 1)[1]} (declared '# holds:')",
                    )
                )
            if held:
                for lid, _l in self._transitive(callee, "acquires"):
                    for h in held:
                        self.edges.setdefault((h, lid), sub.lineno)
                for bdesc, _l in self._transitive(callee, "blocking"):
                    self._blocked(fn, bdesc, sub.lineno, held)

    def _blocked(self, fn: _Fn, desc: str, line: int, held: List[str]) -> None:
        locks = ", ".join(h.split(":", 1)[1] for h in held)
        f = Finding(
            rule="LK202",
            path=self.mod.path,
            line=line,
            scope=fn.qualname,
            message=f"{desc} while holding {locks}",
        )
        if f not in self.findings:
            self.findings.append(f)

    # ---------------------------------------------------------------- cycles
    def _cycles(self) -> None:
        graph: Dict[str, Set[str]] = {}
        for (a, b), _line in self.edges.items():
            graph.setdefault(a, set()).add(b)
            graph.setdefault(b, set())
        index: Dict[str, int] = {}
        low: Dict[str, int] = {}
        on: Set[str] = set()
        stack: List[str] = []
        counter = [0]
        sccs: List[List[str]] = []

        def strongconnect(v: str) -> None:
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            on.add(v)
            for w in sorted(graph[v]):
                if w not in index:
                    strongconnect(w)
                    low[v] = min(low[v], low[w])
                elif w in on:
                    low[v] = min(low[v], index[w])
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                sccs.append(comp)

        for v in sorted(graph):
            if v not in index:
                strongconnect(v)
        for comp in sccs:
            cyclic = len(comp) > 1 or (
                comp[0] in graph[comp[0]] if comp else False
            )
            if not cyclic:
                continue
            comp = sorted(comp)
            line = min(
                l for (a, b), l in self.edges.items() if a in comp and b in comp
            )
            names = " -> ".join(c.split(":", 1)[1] + f" ({c.split(':', 1)[0]})"
                                for c in comp)
            self.findings.append(
                Finding(
                    rule="LK201",
                    path=self.mod.path,
                    line=line,
                    scope="cycle:" + "+".join(comp),
                    message=f"lock-order cycle: {names} -> (back)",
                )
            )


def check_module(mod: SourceModule) -> List[Finding]:
    """Run the lock-graph analysis over one parsed module."""
    return _ModuleGraph(mod).run()
