"""CLI for the static-analysis passes: ``python -m repro.analysis``.

Modes:

- default: run the passes, print findings as text, exit 0.
- ``--check``: additionally diff against the committed baseline and exit 2
  when findings outside the baseline exist (the CI gate; stale baseline
  entries are reported as warnings so the file gets pruned).
- ``--json``: machine-readable report (findings + summary) on stdout.
- ``--write-baseline``: grandfather the current findings into the baseline.
- ``--rules``: print the rule catalog and exit.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .common import (
    RULES,
    analyze_paths,
    diff_baseline,
    load_baseline,
    write_baseline,
)

DEFAULT_BASELINE = "ANALYSIS_BASELINE.json"


def main(argv=None) -> int:
    """Entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="guarded-by / lock-order / fork-safety static analysis",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files or directories to analyze (default: src/repro/core)",
    )
    ap.add_argument("--json", action="store_true", help="JSON report on stdout")
    ap.add_argument(
        "--check",
        action="store_true",
        help="exit 2 on findings outside the baseline (CI gate)",
    )
    ap.add_argument(
        "--baseline",
        default=DEFAULT_BASELINE,
        help=f"baseline file for --check (default: {DEFAULT_BASELINE})",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="grandfather the current findings into the baseline file",
    )
    ap.add_argument("--rules", action="store_true", help="print the rule catalog")
    args = ap.parse_args(argv)

    if args.rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    root = os.getcwd()
    findings = analyze_paths(args.paths or None, root=root)

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.check else set()
    new, stale = diff_baseline(findings, baseline)

    if args.json:
        print(
            json.dumps(
                {
                    "findings": [f.to_dict() for f in findings],
                    "new": [f.key() for f in new],
                    "stale_baseline": sorted(stale),
                    "summary": {
                        "total": len(findings),
                        "new": len(new),
                        "baselined": len(findings) - len(new),
                    },
                },
                indent=2,
            )
        )
    else:
        for f in findings:
            marker = "" if f.key() in baseline else "NEW " if args.check else ""
            print(f"{marker}{f.render()}")
        for k in sorted(stale):
            print(f"warning: stale baseline entry {k} (fixed? prune it)")
        n = len(new if args.check else findings)
        print(
            f"analysis: {len(findings)} finding(s)"
            + (f", {len(new)} new vs baseline" if args.check else "")
        )
    if args.check and new:
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
