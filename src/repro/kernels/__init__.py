"""Pallas TPU kernels for the performance-critical layers.

Each kernel package has: the pl.pallas_call + BlockSpec implementation,
ops.py (jit'd public wrapper), and ref.py (pure-jnp oracle used by the
allclose tests in tests/test_kernels.py).

reorder/    vectorized non-blocking reorder-commit (paper S3)
dispatch/   vectorized hybrid-queue partition dispatch (paper S4)
attention/  causal flash attention fwd (GQA via BlockSpec index maps)
ssd/        Mamba2 SSD chunk scan (state carried in VMEM scratch)
"""
