"""Pallas TPU kernel: causal flash attention forward (GQA-aware).

Grid: (batch, q_heads, q_blocks); each program streams key blocks of the
causal prefix with the online-softmax recurrence, holding one (Bq, Dh) output
tile + (Bq,) running max/denominator in VMEM. GQA is handled by the KV
BlockSpec index map (kv head = q head // G) — no KV expansion in HBM.

VMEM working set per program: q (Bq,Dh) + k/v (Bk,Dh) + scores (Bq,Bk)
≈ a few hundred KB for Bq=Bk=128..512 — comfortably under the ~16MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, Bq, 1, Dh)
    k_ref,  # (1, S, 1, Dh)
    v_ref,  # (1, S, 1, Dh)
    o_ref,  # (1, Bq, 1, Dh)
    *,
    block_q: int,
    block_k: int,
    seq_len: int,
    causal: bool,
):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (Bq, Dh)
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    q = q * scale

    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, q.shape[-1]), jnp.float32)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q,), 0)

    def body(kb, carry):
        m, l, acc = carry
        # int indices are rejected by pallas load on this jax version; use
        # size-1 dynamic slices and drop the unit axes after the load.
        k = pl.load(
            k_ref,
            (pl.dslice(0, 1), pl.dslice(kb * block_k, block_k), pl.dslice(0, 1),
             slice(None)),
        )[0, :, 0, :].astype(jnp.float32)
        v = pl.load(
            v_ref,
            (pl.dslice(0, 1), pl.dslice(kb * block_k, block_k), pl.dslice(0, 1),
             slice(None)),
        )[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (Bq, Bk)
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_k,), 0
            )
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    upper = (
        jax.lax.div(qi * block_q + block_q + block_k - 1, block_k)
        if causal
        else seq_len // block_k
    )
    upper = jnp.minimum(upper, seq_len // block_k)
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m, l, acc))

    o_ref[0, :, 0, :] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, S, H, Dh)
    k: jax.Array,  # (B, S, Hkv, Dh)
    v: jax.Array,  # (B, S, Hkv, Dh)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0

    grid = (B, H, S // block_q)
    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_len=S,
        causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, Dh), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, S, 1, Dh), lambda b, h, i: (b, 0, h // G, 0)),
            pl.BlockSpec((1, S, 1, Dh), lambda b, h, i: (b, 0, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, Dh), lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
