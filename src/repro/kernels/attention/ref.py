"""Pure-jnp oracle for flash attention (causal GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, S, H, Dh)
    k: jax.Array,  # (B, S, Hkv, Dh)
    v: jax.Array,  # (B, S, Hkv, Dh)
    causal: bool = True,
) -> jax.Array:
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    if causal:
        qi = jnp.arange(S)[:, None]
        ki = jnp.arange(S)[None, :]
        scores = jnp.where((qi >= ki)[None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(q.dtype), v)
