"""Jit'd public wrapper for flash attention, with a pure-jnp VJP so the kernel
is usable in training (bwd = chunked recompute in XLA)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash import flash_attention as _flash_fwd
from .ref import attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, causal: bool = True):
    return _flash_fwd(q, k, v, causal=causal)


def _fwd(q, k, v, causal):
    return _flash_fwd(q, k, v, causal=causal), (q, k, v)


def _bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)

__all__ = ["flash_attention", "attention_ref"]
