"""Jit'd public wrapper for the SSD chunk-scan kernel."""
from __future__ import annotations

import jax

from .ssd import ssd_pallas


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128, h0=None, interpret: bool = True):
    """Mamba2 SSD over (B, L, H, P). Returns (y, final_state (B,H,P,N)).
    ``h0`` is unsupported by the kernel path (serving uses the jnp path for
    state carry-in); must be None."""
    assert h0 is None, "kernel path starts from zero state"
    return ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


__all__ = ["ssd"]
