"""Pallas TPU kernel: Mamba2 SSD chunked scan [arXiv:2405.21060, TPU-native].

Grid: (B, H, num_chunks) with the chunk dim innermost. TPU grids execute
sequentially, so the (P, N) SSM state is carried across chunk iterations in a
VMEM scratch accumulator (reset at chunk 0) — the TPU-idiomatic replacement
for the GPU kernel's inter-block shared-memory recurrence.

Per chunk (all in fp32, on the MXU):
  scores  = C_chunk @ B_chunk^T                       (cl, cl)
  y_intra = (decay-mask * scores) @ (dt * x)          (cl, P)
  y_inter = exp(cumsum dA) * (C_chunk @ state^T)      (cl, P)
  state'  = exp(dA_total) * state + ((dt*decay_to_end*x)^T @ B_chunk)^T
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, cl, 1, P)
    dt_ref,  # (1, cl, 1)
    a_ref,  # (1, 1) fp32  A for this head
    b_ref,  # (1, cl, N)
    c_ref,  # (1, cl, N)
    y_ref,  # (1, cl, 1, P)
    hT_ref,  # (1, 1, P, N)  final state output
    state_ref,  # VMEM scratch (P, N) fp32
    *,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (cl, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (cl,)
    A = a_ref[0, 0]
    Bm = b_ref[0].astype(jnp.float32)  # (cl, N)
    Cm = c_ref[0].astype(jnp.float32)  # (cl, N)

    dA = dt * A  # (cl,)
    cum = jnp.cumsum(dA)  # (cl,)
    total = cum[-1]

    # intra-chunk: masked decay matrix L[q,k] = exp(cum_q - cum_k) for k<=q
    diff = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1)
    L = jnp.where(qi >= ki, jnp.exp(diff), 0.0)  # (cl, cl)
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    xdt = x * dt[:, None]  # (cl, P)
    y = jnp.dot(L * scores, xdt, preferred_element_type=jnp.float32)

    # inter-chunk from carried state
    state = state_ref[...]  # (P, N)
    y += jnp.exp(cum)[:, None] * jnp.dot(
        Cm, state.T, preferred_element_type=jnp.float32
    )

    # state update
    decay_to_end = jnp.exp(total - cum)  # (cl,)
    contrib = jnp.dot(
        (xdt * decay_to_end[:, None]).T, Bm, preferred_element_type=jnp.float32
    )  # (P, N)
    state_ref[...] = jnp.exp(total) * state + contrib

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _():
        hT_ref[0, 0, :, :] = state_ref[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(
    x: jax.Array,  # (B, L, H, P) fp32
    dt: jax.Array,  # (B, L, H) fp32
    A: jax.Array,  # (H,) fp32
    Bm: jax.Array,  # (B, L, N) fp32
    Cm: jax.Array,  # (B, L, N) fp32
    *,
    chunk: int = 128,
    interpret: bool = True,
):
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, "seq len must be a multiple of chunk"
    nc = L // chunk
    grid = (B, H, nc)
    kernel = functools.partial(_ssd_kernel, num_chunks=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.reshape(H, 1), Bm, Cm)
    return y, hT
