"""Pure-jnp oracle for the vectorized hybrid-queue dispatch (paper §4.3).

Given tuples in arrival order with partition ids, produce per-partition FIFO
buffers with bounded capacity:

  buffers[p, r] = payload of the r-th tuple (in arrival order) routed to p
  counts[p]     = number of tuples routed to p (pre-capacity clamp)
  dest[t]       = p * capacity + rank, or -1 if dropped (rank >= capacity)

Arrival order within a partition is preserved — the master-queue property
(Theorem 4.1(2)); capacity is the bounded-delegation analogue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dispatch_ref(
    part_ids: jax.Array,  # (T,) int32, -1 = invalid
    payloads: jax.Array,  # (T, W)
    num_partitions: int,
    capacity: int,
):
    T, W = payloads.shape
    valid = part_ids >= 0
    ids = jnp.where(valid, part_ids, num_partitions)
    onehot = jax.nn.one_hot(ids, num_partitions, dtype=jnp.int32)  # (T, P)
    # rank = number of earlier tuples in the same partition (stable order)
    cum = jnp.cumsum(onehot, axis=0) - onehot  # exclusive prefix count
    rank = jnp.take_along_axis(
        cum, jnp.clip(ids, 0, num_partitions - 1)[:, None], axis=1
    )[:, 0]
    counts = onehot.sum(axis=0)
    keep = valid & (rank < capacity)
    dest = jnp.where(keep, ids * capacity + rank, -1)

    slot = jnp.where(keep, dest, num_partitions * capacity)
    buffers = (
        jnp.zeros((num_partitions * capacity, W), payloads.dtype)
        .at[slot]
        .set(payloads, mode="drop")
        .reshape(num_partitions, capacity, W)
    )
    return buffers, counts, dest
