"""Jit'd public wrapper for the hybrid-queue dispatch kernel."""
from __future__ import annotations

import jax

from .dispatch import dispatch_pallas
from .ref import dispatch_ref


def dispatch(
    part_ids: jax.Array,
    payloads: jax.Array,
    num_partitions: int,
    capacity: int,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
):
    """Route tuples (arrival order = index) into bounded per-partition FIFO
    buffers. Returns (buffers (P,C,W), counts (P,), dest (T,))."""
    if not use_kernel:
        return dispatch_ref(part_ids, payloads, num_partitions, capacity)
    return dispatch_pallas(
        part_ids,
        payloads,
        num_partitions=num_partitions,
        capacity=capacity,
        interpret=interpret,
    )


__all__ = ["dispatch"]
