"""Pallas TPU kernel: hybrid-queue partition dispatch (paper §4.3, TPU-native).

The multicore hybrid queue uses per-partition FIFO queues + delegation
counters. Vectorized: the rank of each tuple within its partition (= its FIFO
position, preserving arrival order) is a prefix-sum over a one-hot partition
matrix, computed as a triangular matmul on the MXU; the scatter into bounded
per-partition buffers is a second one-hot matmul. MoE dispatch is this exact
kernel with partitions = experts.

  onehot (T, P)   : tuple -> partition
  rank            = (strictly-lower-triangular ones (T,T)) @ onehot, row t at its own partition
  buffers (P*C, W)= slot-onehot (P*C, T) @ payloads (T, W)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dispatch_kernel(
    part_ids_ref,  # (T, 1) int32
    payloads_ref,  # (T, W)
    buffers_ref,  # (P*C, W)
    counts_ref,  # (P, 1) int32
    dest_ref,  # (T, 1) int32
    *,
    num_partitions: int,
    capacity: int,
):
    T = part_ids_ref.shape[0]
    ids = part_ids_ref[:, 0]  # (T,)
    valid = ids >= 0

    cols = jax.lax.broadcasted_iota(jnp.int32, (T, num_partitions), 1)
    onehot = ((cols == ids[:, None]) & valid[:, None]).astype(jnp.float32)

    # strictly-lower-triangular ones: rank[t] = # earlier tuples, same partition
    r = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    tri = (c < r).astype(jnp.float32)
    prior = jnp.dot(tri, onehot, preferred_element_type=jnp.float32)  # (T, P)
    rank = jnp.sum(prior * onehot, axis=1).astype(jnp.int32)  # (T,)

    counts = jnp.sum(onehot, axis=0).astype(jnp.int32)  # (P,)
    keep = valid & (rank < capacity)
    dest = jnp.where(keep, ids * capacity + rank, -1)

    # scatter via slot-onehot matmul
    PC = num_partitions * capacity
    slot_rows = jax.lax.broadcasted_iota(jnp.int32, (PC, T), 0)
    slot_onehot = (slot_rows == dest[None, :]).astype(jnp.float32)
    buffers_ref[...] = jnp.dot(
        slot_onehot, payloads_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(buffers_ref.dtype)
    counts_ref[...] = counts[:, None]
    dest_ref[...] = dest[:, None]


@functools.partial(
    jax.jit, static_argnames=("num_partitions", "capacity", "interpret")
)
def dispatch_pallas(
    part_ids: jax.Array,
    payloads: jax.Array,
    *,
    num_partitions: int,
    capacity: int,
    interpret: bool = True,
):
    T, W = payloads.shape
    PC = num_partitions * capacity
    kernel = functools.partial(
        _dispatch_kernel, num_partitions=num_partitions, capacity=capacity
    )
    buffers, counts, dest = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((PC, W), payloads.dtype),
            jax.ShapeDtypeStruct((num_partitions, 1), jnp.int32),
            jax.ShapeDtypeStruct((T, 1), jnp.int32),
        ),
        in_specs=[
            pl.BlockSpec((T, 1), lambda: (0, 0)),
            pl.BlockSpec((T, W), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((PC, W), lambda: (0, 0)),
            pl.BlockSpec((num_partitions, 1), lambda: (0, 0)),
            pl.BlockSpec((T, 1), lambda: (0, 0)),
        ],
        interpret=interpret,
    )(part_ids.astype(jnp.int32)[:, None], payloads)
    return buffers.reshape(num_partitions, capacity, W), counts[:, 0], dest[:, 0]
