"""Pallas TPU kernel: vectorized reorder-commit (paper §3 fig. 4, TPU-native).

Hardware adaptation (DESIGN.md §2): the multicore version relies on CAS
atomics; TPUs have none. Instead a *batch* of K completed (serial, payload)
pairs is committed per call, and both the scatter-into-ring and the in-order
drain are expressed as one-hot matmuls so the permutation work lands on the
MXU (the TPU-idiomatic replacement for random access):

  scatter: onehot (S, K) @ payloads (K, W)  -> ring writes
  drain:   rotation one-hot (S, S) @ ring   -> emitted rows, in serial order

The contiguous-prefix length (how many outputs are ready to send) is a masked
min-reduction over ring distances — the vectorized equivalent of fig. 4's
"while buffer[next % s] != EMPTY" walk.

The whole state lives in VMEM: (S, W) ring + (S,) present + scalar ``next``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _commit_kernel(
    # inputs
    buf_ref,  # (S, W)
    present_ref,  # (S, 1) int32 (bool packed)
    next_ref,  # (1, 1) int32
    serials_ref,  # (K, 1) int32
    payloads_ref,  # (K, W)
    # outputs
    out_buf_ref,  # (S, W)
    out_present_ref,  # (S, 1)
    out_next_ref,  # (1, 1)
    emitted_ref,  # (S, W)
    emit_count_ref,  # (1, 1)
    accepted_ref,  # (K, 1) int32
):
    S, W = buf_ref.shape
    K = serials_ref.shape[0]
    nxt = next_ref[0, 0]
    serials = serials_ref[:, 0]  # (K,)
    present = present_ref[:, 0] > 0  # (S,)

    # ---- try_add (entry condition): one-hot scatter via MXU
    in_window = (serials >= 0) & (serials >= nxt) & (serials < nxt + S)
    slot = jnp.where(in_window, serials % S, -1)  # (K,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (S, K), 0)
    onehot = (rows == slot[None, :]).astype(payloads_ref.dtype)  # (S, K)
    taken = jnp.sum(onehot, axis=1) > 0  # (S,)
    scattered = jnp.dot(
        onehot, payloads_ref[...], preferred_element_type=jnp.float32
    ).astype(buf_ref.dtype)
    buf = jnp.where(taken[:, None], scattered, buf_ref[...])
    present = present | taken

    # ---- drain: contiguous present prefix from ``next``
    idx = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
    pos = (idx - nxt) % S  # ring distance from head
    absent_pos = jnp.where(present, S, pos)
    emit_count = jnp.min(absent_pos)

    # rotation one-hot: emitted[i] = buf[j] where pos[j] == i and i < count
    out_rows = jax.lax.broadcasted_iota(jnp.int32, (S, S), 0)  # i
    rot = (out_rows == pos[None, :]) & (out_rows < emit_count)
    emitted_ref[...] = jnp.dot(
        rot.astype(jnp.float32), buf.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(emitted_ref.dtype)

    present = present & (pos >= emit_count)
    out_buf_ref[...] = buf
    out_present_ref[...] = present.astype(jnp.int32)[:, None]
    out_next_ref[0, 0] = nxt + emit_count
    emit_count_ref[0, 0] = emit_count
    accepted_ref[...] = in_window.astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def commit_pallas(buf, present, nxt, serials, payloads, *, interpret=True):
    """One reorder-commit step. present: (S,) int32; nxt: () int32."""
    S, W = buf.shape
    K = serials.shape[0]
    out_shapes = (
        jax.ShapeDtypeStruct((S, W), buf.dtype),
        jax.ShapeDtypeStruct((S, 1), jnp.int32),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
        jax.ShapeDtypeStruct((S, W), buf.dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
        jax.ShapeDtypeStruct((K, 1), jnp.int32),
    )
    specs = [
        pl.BlockSpec((S, W), lambda: (0, 0)),
        pl.BlockSpec((S, 1), lambda: (0, 0)),
        pl.BlockSpec((1, 1), lambda: (0, 0)),
        pl.BlockSpec((K, 1), lambda: (0, 0)),
        pl.BlockSpec((K, W), lambda: (0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((S, W), lambda: (0, 0)),
        pl.BlockSpec((S, 1), lambda: (0, 0)),
        pl.BlockSpec((1, 1), lambda: (0, 0)),
        pl.BlockSpec((S, W), lambda: (0, 0)),
        pl.BlockSpec((1, 1), lambda: (0, 0)),
        pl.BlockSpec((K, 1), lambda: (0, 0)),
    ]
    return pl.pallas_call(
        _commit_kernel,
        out_shape=out_shapes,
        in_specs=specs,
        out_specs=out_specs,
        interpret=interpret,
    )(
        buf,
        present.astype(jnp.int32)[:, None],
        nxt.reshape(1, 1).astype(jnp.int32),
        serials.astype(jnp.int32)[:, None],
        payloads,
    )
