"""Jit'd public wrapper for the reorder-commit kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import ReorderState, commit_ref, init_state
from .reorder import commit_pallas


def commit(
    state: ReorderState,
    serials: jax.Array,
    payloads: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
) -> tuple[ReorderState, jax.Array, jax.Array, jax.Array]:
    """Batched reorder-commit: scatter K completed (serial, payload) pairs into
    the ring and emit the contiguous ready prefix in serial order.

    Returns (new_state, emitted (S,W), emit_count (), accepted (K,) bool).
    """
    if not use_kernel:
        return commit_ref(state, serials, payloads)
    buf, present, nxt, emitted, count, accepted = commit_pallas(
        state.buf,
        state.present.astype(jnp.int32),
        state.next,
        serials,
        payloads,
        interpret=interpret,
    )
    new_state = ReorderState(
        buf=buf, present=present[:, 0] > 0, next=nxt[0, 0]
    )
    return new_state, emitted, count[0, 0], accepted[:, 0] > 0


__all__ = ["ReorderState", "commit", "init_state"]
