"""Pure-jnp oracle for the vectorized reorder-commit (paper §3, fig. 4).

State mirrors the non-blocking reorder buffer:
  buf     : (S, W) payload ring, slot i holds serial t with t % S == i
  present : (S,) bool
  next    : () int32 — serial number of the next output to send downstream

One ``commit(state, serials, payloads)`` call is the batched equivalent of K
workers invoking ``send`` concurrently followed by one drain:
  try_add  : serial t accepted iff next <= t < next + S (the entry condition)
  drain    : emit the contiguous run of present slots starting at ``next``

Returns (new_state, emitted, emit_count, accepted_mask). ``emitted`` is an
(S, W) buffer whose first ``emit_count`` rows are the in-order outputs.
Invalid serials (< 0) are ignored.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ReorderState(NamedTuple):
    buf: jax.Array  # (S, W)
    present: jax.Array  # (S,) bool
    next: jax.Array  # () int32


def init_state(size: int, width: int, dtype=jnp.float32, start: int = 0) -> ReorderState:
    return ReorderState(
        buf=jnp.zeros((size, width), dtype),
        present=jnp.zeros((size,), bool),
        next=jnp.asarray(start, jnp.int32),
    )


def commit_ref(
    state: ReorderState, serials: jax.Array, payloads: jax.Array
) -> tuple[ReorderState, jax.Array, jax.Array, jax.Array]:
    S, W = state.buf.shape
    nxt = state.next

    # ---- try_add: entry condition (fig. 4 L16)
    valid = serials >= 0
    in_window = valid & (serials >= nxt) & (serials < nxt + S)
    slot = jnp.where(in_window, serials % S, S)  # S = dropped
    buf = state.buf.at[slot].set(payloads, mode="drop")
    present = state.present.at[slot].set(True, mode="drop")

    # ---- drain: contiguous present prefix starting at ``next``
    pos = (jnp.arange(S) - nxt) % S  # ring distance from head
    absent_pos = jnp.where(present, S, pos)
    emit_count = jnp.min(absent_pos)  # first gap == prefix length

    # emitted[i] = buf[(next + i) % S] for i < emit_count
    src = (nxt + jnp.arange(S)) % S
    emitted_all = buf[src]
    emit_mask = jnp.arange(S) < emit_count
    emitted = jnp.where(emit_mask[:, None], emitted_all, 0)

    present = present & (pos >= emit_count)
    new_state = ReorderState(buf=buf, present=present, next=nxt + emit_count)
    return new_state, emitted, emit_count, in_window
