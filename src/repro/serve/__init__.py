"""Serving tier: multiplexed ordered sessions + open-loop load tooling.

- :mod:`.mux` — :class:`SessionMux` / :class:`MuxSession`: many concurrent
  ordered sessions admitted onto one planned Engine runtime (sid-tagged
  ingress, demuxed ordered egress, DRR fairness, admission control,
  graceful churn; see docs/serving.md);
- :mod:`.loadgen` — open-loop load generator (Poisson / heavy-tailed /
  bursty / diurnal arrivals) with coordinated-omission-free p50/p99/p999
  latency accounting;
- :mod:`.engine` — the jax continuous-batching :class:`OrderedServingEngine`
  (model serving embodiment of the ordered-egress problem; imported lazily
  so the stream-processing surface stays importable without pulling jax).
"""
from .loadgen import (
    ArrivalConfig,
    LatencyReport,
    arrival_times,
    percentile,
    run_open_loop,
)
from .mux import AdmissionError, MuxConfig, MuxSession, SessionMux, tag_graph

__all__ = [
    "AdmissionError",
    "ArrivalConfig",
    "LatencyReport",
    "MuxConfig",
    "MuxSession",
    "OrderedServingEngine",
    "SessionMux",
    "arrival_times",
    "percentile",
    "run_open_loop",
    "tag_graph",
]

_LAZY = {"OrderedServingEngine": ".engine"}


def __getattr__(name):  # PEP 562: defer the jax import until first use
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(_LAZY[name], __name__)
        value = getattr(mod, name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
