"""Open-loop load generation with coordinated-omission-free latency.

Closed-loop drivers (push, wait, push) hide overload: a stalled server
slows the *driver*, so measured latencies stay flat while real clients
would be queueing.  This generator is **open-loop**: every request has a
*scheduled* arrival time drawn from an arrival process, the driver never
waits for completions, and each latency is measured from the scheduled
arrival — not the actual push instant — so time spent queueing behind a
saturated runtime is charged to the request (Tene's coordinated-omission
correction).  That is the fig.10-style metric that matters at serving
scale: p50/p99/p999 under sustained load, not drain throughput.

Arrival shapes: ``poisson`` (memoryless), ``lognormal`` / ``pareto``
(heavy-tailed inter-arrivals), ``bursty`` (square-wave modulated rate),
``diurnal`` (sinusoidally modulated rate).  All are seeded and normalized
to the same mean rate so shapes are comparable at equal offered load.
"""
from __future__ import annotations

import heapq
import math
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

__all__ = [
    "ArrivalConfig",
    "LatencyReport",
    "arrival_times",
    "percentile",
    "run_open_loop",
]

_SHAPES = ("poisson", "lognormal", "pareto", "bursty", "diurnal")


@dataclass(frozen=True)
class ArrivalConfig:
    """One arrival process: ``shape`` at mean ``rate`` requests/second.

    ``sigma`` spreads the lognormal; ``alpha`` is the Pareto tail index
    (must be > 1 for a finite mean); ``burst_factor``/``burst_duty``/
    ``period_s`` shape the modulated processes (bursty spends ``duty`` of
    each period at ``factor``× the base rate; diurnal swings ±80% over a
    period — a compressed day)."""

    shape: str = "poisson"
    rate: float = 1000.0
    seed: int = 0
    sigma: float = 1.0
    alpha: float = 1.5
    burst_factor: float = 8.0
    burst_duty: float = 0.2
    period_s: float = 1.0

    def validate(self) -> "ArrivalConfig":
        """Range-check shape and parameters; returns self for chaining."""
        if self.shape not in _SHAPES:
            raise ValueError(f"shape must be one of {_SHAPES}, got {self.shape!r}")
        if self.rate <= 0:
            raise ValueError("rate must be > 0")
        if self.shape == "pareto" and self.alpha <= 1.0:
            raise ValueError("pareto alpha must be > 1 (finite mean)")
        if self.shape == "bursty" and not (0.0 < self.burst_duty < 1.0):
            raise ValueError("burst_duty must be in (0, 1)")
        return self


def arrival_times(cfg: ArrivalConfig, n: int) -> List[float]:
    """``n`` scheduled arrival offsets (seconds from start, nondecreasing)."""
    cfg.validate()
    rng = random.Random(cfg.seed)
    mean_gap = 1.0 / cfg.rate
    times: List[float] = []
    t = 0.0
    if cfg.shape == "poisson":
        for _ in range(n):
            t += rng.expovariate(cfg.rate)
            times.append(t)
    elif cfg.shape == "lognormal":
        # mean of LogNormal(mu, sigma) is exp(mu + sigma^2/2): pin it to the
        # requested mean gap so heavy tails don't change offered load
        mu = math.log(mean_gap) - cfg.sigma ** 2 / 2.0
        for _ in range(n):
            t += rng.lognormvariate(mu, cfg.sigma)
            times.append(t)
    elif cfg.shape == "pareto":
        # paretovariate(alpha) >= 1 with mean alpha/(alpha-1); scale to mean_gap
        scale = mean_gap * (cfg.alpha - 1.0) / cfg.alpha
        for _ in range(n):
            t += scale * rng.paretovariate(cfg.alpha)
            times.append(t)
    else:  # modulated (non-homogeneous) Poisson: bursty / diurnal
        # Lewis-Shedler thinning: homogeneous candidates at the peak rate,
        # each kept with probability rate(t)/peak.  Stepping by the local
        # rate at the gap's *start* (the obvious shortcut) is badly biased
        # once the trough's mean gap rivals the period: a single
        # trough-drawn gap leaps whole bursts, so bursts are systematically
        # under-sampled and the realized mean rate lands far below nominal.
        peak = _peak_rate(cfg)
        while len(times) < n:
            t += rng.expovariate(peak)
            if rng.random() * peak <= _instant_rate(cfg, t):
                times.append(t)
    return times


def _bursty_factors(cfg: ArrivalConfig) -> tuple:
    """``(high, low)`` rate multipliers of the bursty square wave, scaled so
    the wave's analytic mean is exactly ``cfg.rate`` even when the trough
    floor (5% of base) binds because ``duty * factor > 1``."""
    duty, factor = cfg.burst_duty, cfg.burst_factor
    low = max(1.0 - duty * factor, 0.05) / (1.0 - duty)
    norm = duty * factor + (1.0 - duty) * low
    return factor / norm, low / norm


def _instant_rate(cfg: ArrivalConfig, t: float) -> float:
    """Instantaneous rate of the modulated processes at offset ``t``."""
    if cfg.shape == "bursty":
        # square wave at the mean rate: duty of each period at ~factor x
        # the base rate, the remainder at the (floored) low rate
        high, low = _bursty_factors(cfg)
        phase = (t % cfg.period_s) / cfg.period_s
        return cfg.rate * (high if phase < cfg.burst_duty else low)
    # diurnal: +-80% sinusoidal swing over one period
    swing = 1.0 + 0.8 * math.sin(2.0 * math.pi * t / cfg.period_s)
    return max(cfg.rate * swing, cfg.rate * 0.05)


def _peak_rate(cfg: ArrivalConfig) -> float:
    """Upper bound on :func:`_instant_rate` (the thinning envelope)."""
    if cfg.shape == "bursty":
        high, low = _bursty_factors(cfg)
        return cfg.rate * max(high, low)
    return cfg.rate * 1.8  # diurnal peak of the +-80% swing


def percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile of an ascending list (``q`` in [0, 100])."""
    if not sorted_vals:
        return float("nan")
    rank = max(int(math.ceil(q / 100.0 * len(sorted_vals))) - 1, 0)
    return sorted_vals[min(rank, len(sorted_vals) - 1)]


@dataclass
class LatencyReport:
    """Open-loop run outcome: CO-free latency percentiles in seconds."""

    requests: int
    completed: int
    duration_s: float
    offered_rate: float
    achieved_rate: float
    p50: float
    p99: float
    p999: float
    mean: float
    max: float
    per_session: Dict[int, dict] = field(default_factory=dict)

    def row(self) -> dict:
        """Flat dict for benchmark JSON (milliseconds for readability)."""
        return {
            "requests": self.requests,
            "completed": self.completed,
            "duration_s": round(self.duration_s, 4),
            "offered_rate": round(self.offered_rate, 1),
            "achieved_rate": round(self.achieved_rate, 1),
            "p50_ms": round(self.p50 * 1e3, 3),
            "p99_ms": round(self.p99 * 1e3, 3),
            "p999_ms": round(self.p999 * 1e3, 3),
            "mean_ms": round(self.mean * 1e3, 3),
            "max_ms": round(self.max * 1e3, 3),
        }


def _summarize(lat: List[float]) -> dict:
    lat = sorted(lat)
    return {
        "n": len(lat),
        "p50": percentile(lat, 50.0),
        "p99": percentile(lat, 99.0),
        "p999": percentile(lat, 99.9),
        "mean": (sum(lat) / len(lat)) if lat else float("nan"),
        "max": lat[-1] if lat else float("nan"),
    }


def run_open_loop(
    mux,
    *,
    sessions: int,
    requests: int,
    arrivals: ArrivalConfig,
    payload: Callable[[int, int], Any] = lambda sid, i: i,
    slow_consumers: Optional[Dict[int, float]] = None,
    drain_timeout: float = 120.0,
    warmup: int = 0,
) -> LatencyReport:
    """Drive ``sessions`` concurrent sessions open-loop through ``mux``.

    Each session gets ``requests`` scheduled arrivals from its own seeded
    copy of ``arrivals``; the single driver thread pushes strictly by the
    global schedule (``try_push`` retries never advance the clock, so
    backpressure queueing is *charged to the request*).  One consumer
    thread per session records completion times; latency of the k-th
    output of a session is measured against the k-th scheduled arrival, so
    the pipeline must be selectivity-1 end to end (one output per input —
    assert-checked).  ``slow_consumers`` maps a session *index* to a
    per-item sleep, injecting consumer-side stalls (the mux must confine
    the damage to that session).  Returns a :class:`LatencyReport` with a
    ``per_session`` breakdown (latency summaries per session index).

    ``warmup`` discards each session's first ``warmup`` requests from the
    measurement window: they are pushed on schedule (the server still sees
    them) but excluded from the latency percentiles, and ``achieved_rate``
    counts only the completions inside the steady-state window (opening
    when the *last* session finishes its warmup prefix, closing at the
    last completion overall).  Use it when probing steady-state capacity —
    a cold start (fork, first plan, jit) otherwise deflates the probe's
    achieved rate, while dividing all post-warmup completions by a
    late-opening window would inflate it.
    """
    if sessions < 1 or requests < 1:
        raise ValueError("sessions and requests must be >= 1")
    if not (0 <= warmup < requests):
        raise ValueError("warmup must be in [0, requests)")
    slow = dict(slow_consumers or {})
    handles = [mux.open() for _ in range(sessions)]
    # per-session schedules, decorrelated by seed; one global merged heap
    schedules = [
        arrival_times(
            ArrivalConfig(**{**arrivals.__dict__, "seed": arrivals.seed + 1000 * idx}),
            requests,
        )
        for idx in range(sessions)
    ]
    heap = [
        (schedules[idx][k], idx, k)
        for idx in range(sessions)
        for k in (0,)
    ]
    heapq.heapify(heap)
    next_k = [0] * sessions

    t0 = time.perf_counter()
    sched_abs = [[t0 + t for t in sch] for sch in schedules]
    completions: List[List[float]] = [[] for _ in range(sessions)]
    errors: List[BaseException] = []

    def consume(idx: int) -> None:
        try:
            delay = slow.get(idx, 0.0)
            for _out in handles[idx].results(timeout=drain_timeout):
                completions[idx].append(time.perf_counter())
                if delay:
                    time.sleep(delay)
        except BaseException as e:  # surfaced after join
            errors.append(e)

    threads = [
        threading.Thread(target=consume, args=(idx,), daemon=True)
        for idx in range(sessions)
    ]
    for th in threads:
        th.start()

    # open-loop driver: release strictly by schedule, retry-don't-reschedule
    while heap:
        t_sched, idx, k = heap[0]
        now = time.perf_counter()
        wait = (t0 + t_sched) - now
        if wait > 0:
            time.sleep(min(wait, 0.005))
            continue
        heapq.heappop(heap)
        value = payload(handles[idx].sid, k)
        while not handles[idx].try_push(value):
            time.sleep(1e-4)  # schedule does NOT advance: queueing is charged
        next_k[idx] = k + 1
        if next_k[idx] < requests:
            heapq.heappush(heap, (schedules[idx][next_k[idx]], idx, next_k[idx]))

    for h in handles:
        h.close(drain_timeout=drain_timeout)
    for th in threads:
        th.join(timeout=drain_timeout)
    if errors:
        raise errors[0]
    duration = time.perf_counter() - t0

    latencies: List[float] = []
    per_session: Dict[int, dict] = {}
    for idx in range(sessions):
        done = completions[idx]
        if len(done) != requests:
            raise RuntimeError(
                f"session index {idx}: {len(done)} outputs for {requests} "
                "requests — run_open_loop needs a selectivity-1 pipeline"
            )
        lats = [done[k] - sched_abs[idx][k] for k in range(warmup, requests)]
        per_session[idx] = _summarize(lats)
        latencies.extend(lats)

    latencies.sort()
    total = sessions * requests
    if warmup:
        # steady-state window: opens once *every* session is past its
        # warmup prefix, closes at the last completion — and only the
        # completions inside it count, so uneven per-session progress
        # cannot inflate the rate (completions before the window opened
        # must not be divided by the window they didn't land in)
        win_start = max(completions[idx][warmup - 1] for idx in range(sessions))
        win_end = max(completions[idx][-1] for idx in range(sessions))
        window = win_end - win_start
        measured = sum(
            1 for done in completions for t in done if t > win_start
        )
    else:
        window = duration
        measured = total
    return LatencyReport(
        requests=total,
        completed=sum(len(c) for c in completions),
        duration_s=duration,
        offered_rate=arrivals.rate * sessions,
        achieved_rate=(measured / window) if window > 0 else float("nan"),
        p50=percentile(latencies, 50.0),
        p99=percentile(latencies, 99.0),
        p999=percentile(latencies, 99.9),
        mean=sum(latencies) / len(latencies),
        max=latencies[-1],
        per_session=per_session,
    )
