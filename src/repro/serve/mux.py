"""Session multiplexer: many ordered sessions on one planned runtime.

A real service runs thousands of concurrent ordered streams; forking one
:class:`~repro.core.api.Engine` runtime per client would burn a worker
fleet per session.  :class:`SessionMux` admits many :class:`MuxSession`\\ s
onto **one** planned runtime by

1. **tagging** every tuple ``(sid, value)`` at ingress and rewriting the
   operator graph so each operator works per-session:

   - *stateless* ops map the payload and re-tag their outputs;
   - *stateful* ops become **partitioned ops keyed by session id** — each
     session gets its own isolated state *and* previously-serial operators
     now scale across sessions (the multiplexer's parallelism dividend);
   - *partitioned* ops are re-keyed by ``(sid, key)`` so key spaces of
     different sessions never collide;

2. **demuxing** the runtime's totally-ordered egress back into per-session
   result queues — the global egress order is ingress order, so each
   session's subsequence is exactly its own outputs in its own order;

3. scheduling ingress with **deficit round-robin fairness** (per-session
   weights) over bounded per-session ingress queues, with **admission
   control**: ``max_sessions`` at ``open()``, queue-depth shedding with a
   structured :class:`AdmissionError`, and per-session backpressure — a
   slow consumer stops being *admitted* into the runtime instead of
   stalling the shared egress;

4. **graceful churn**: ``MuxSession.close()`` drains exactly that
   session's in-flight tuples (a pickle-safe flush token rides the ordered
   stream behind them) while other sessions keep streaming — composing
   with the process backend's crash recovery, which replays tagged tuples
   and tokens idempotently.

One daemon pump thread owns the inner :class:`~repro.core.api.Session`
(whose methods are not re-entrant) and drives it exclusively through the
non-blocking ``try_push``/``poll``/``service`` surface; client threads
only touch their own session's deques, so no locks are shared with the
runtime.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Iterable, Iterator, Optional

from repro.core.api import Engine, SessionStarvation, _normalize_graph
from repro.core.operators import OpSpec, PARTITIONED, STATEFUL, STATELESS

__all__ = [
    "AdmissionError",
    "MuxConfig",
    "MuxSession",
    "SessionMux",
    "tag_graph",
]


class AdmissionError(RuntimeError):
    """Structured admission rejection from the serving tier.

    ``reason`` is machine-readable: ``"max_sessions"`` (open() beyond the
    session cap), ``"ingress_full"`` (queue-depth shedding on a saturated
    session), or ``"mux_closed"``.  ``snapshot`` carries the per-session
    backlog stats at rejection time so shedding is diagnosable."""

    def __init__(self, message: str, *, reason: str, sid: Optional[int] = None,
                 limit: Optional[int] = None, snapshot: Optional[dict] = None):
        self.reason = reason
        self.sid = sid
        self.limit = limit
        self.snapshot = dict(snapshot or {})
        super().__init__(message)


@dataclass(frozen=True)
class MuxConfig:
    """Serving-tier knobs (the runtime's own knobs live in EngineConfig).

    ``max_sessions`` bounds concurrently open sessions; ``ingress_depth``
    bounds each session's parent-side ingress queue (``push`` blocks, then
    sheds with :class:`AdmissionError` after ``push_timeout``);
    ``result_budget`` is the undelivered-output count past which a slow
    consumer's *ingress* stops being scheduled (its results stay available
    — shared egress never blocks on one reader); ``quantum`` is the
    deficit-round-robin base quantum (tuples per scheduling round for
    weight 1.0); ``state_partitions`` is the partition count given to
    stateful operators converted to session-keyed partitioned form;
    ``load_signal_interval`` is how often (seconds) the pump exports a
    :meth:`SessionMux.load_signals` snapshot to the inner runtime via
    ``Session.offer_load`` — the feed for traffic-reactive elastic
    replanning (docs/serving.md)."""

    max_sessions: int = 64
    ingress_depth: int = 1024
    result_budget: int = 4096
    quantum: int = 16
    state_partitions: int = 8
    push_timeout: float = 30.0
    load_signal_interval: float = 0.25

    def validate(self) -> "MuxConfig":
        """Range-check every knob; returns self for chaining."""
        if self.max_sessions < 1:
            raise ValueError("max_sessions must be >= 1")
        if self.ingress_depth < 1:
            raise ValueError("ingress_depth must be >= 1")
        if self.result_budget < 1:
            raise ValueError("result_budget must be >= 1")
        if self.quantum < 1:
            raise ValueError("quantum must be >= 1")
        if self.state_partitions < 1:
            raise ValueError("state_partitions must be >= 1")
        if self.load_signal_interval <= 0:
            raise ValueError("load_signal_interval must be > 0")
        return self


class _FlushToken:
    """Pickle-safe drain marker for one session.

    Pushed behind a closing session's last tuple; every rewritten operator
    passes it through unchanged, and the totally-ordered egress guarantees
    that when it surfaces, all of that session's earlier outputs already
    did.  Crash replay may deliver it twice — demux treats a duplicate
    token as idempotent."""

    __slots__ = ("sid",)

    def __init__(self, sid: int):
        self.sid = sid

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_FlushToken(sid={self.sid})"


# ---------------------------------------------------------------- tagging
# Wrappers are module-level classes (not closures) so tagged graphs survive
# fork-style pickling on the process backend.
class _TagStateless:
    __slots__ = ("fn",)

    def __init__(self, fn):
        self.fn = fn

    def __call__(self, tagged):
        if isinstance(tagged, _FlushToken):
            return [tagged]
        sid, value = tagged
        return [(sid, out) for out in self.fn(value)]


class _TagStateful:
    """Stateful op converted to partitioned-by-sid: state is per session."""

    __slots__ = ("fn",)

    def __init__(self, fn):
        self.fn = fn

    def __call__(self, state, key, tagged):
        if isinstance(tagged, _FlushToken):
            return state, [tagged]
        sid, value = tagged
        state, outs = self.fn(state, value)
        return state, [(sid, out) for out in outs]


class _TagPartitioned:
    __slots__ = ("fn",)

    def __init__(self, fn):
        self.fn = fn

    def __call__(self, state, key, tagged):
        if isinstance(tagged, _FlushToken):
            return state, [tagged]
        sid, value = tagged
        state, outs = self.fn(state, key[1], value)
        return state, [(sid, out) for out in outs]


class _TagKey:
    """Key extractor for re-keyed partitioned ops: ``(sid, orig_key)``."""

    __slots__ = ("key_fn",)

    def __init__(self, key_fn):
        self.key_fn = key_fn

    def __call__(self, tagged):
        if isinstance(tagged, _FlushToken):
            return (tagged.sid, None)
        sid, value = tagged
        return (sid, self.key_fn(value))


class _SidKey:
    __slots__ = ()

    def __call__(self, tagged):
        if isinstance(tagged, _FlushToken):
            return tagged.sid
        return tagged[0]


class _HashMod:
    __slots__ = ("n",)

    def __init__(self, n: int):
        self.n = n

    def __call__(self, key) -> int:
        return hash(key) % self.n


def tag_graph(graph, edges=None, *, state_partitions: int = 8):
    """Rewrite an operator graph to flow ``(sid, value)`` tagged tuples.

    Returns ``(nodes, edges)`` ready for ``engine.plan``/``engine.open``.
    Stateful operators come back *partitioned by session id* (isolated
    per-session state, parallel across sessions); partitioned operators are
    re-keyed by ``(sid, key)``.  Operator semantics are per-session: an
    aggregation that used to fold one global stream now folds each
    session's stream independently — exactly what multiplexed serving
    means."""
    nodes, edge_list, _chain = _normalize_graph(graph, edges)
    tagged: Dict[str, OpSpec] = {}
    for name, spec in nodes.items():
        if spec.kind == STATELESS:
            tagged[name] = OpSpec(
                name=spec.name, kind=STATELESS, fn=_TagStateless(spec.fn),
                cost_us=spec.cost_us, selectivity=spec.selectivity,
            )
        elif spec.kind == STATEFUL:
            tagged[name] = OpSpec(
                name=spec.name, kind=PARTITIONED, fn=_TagStateful(spec.fn),
                key_fn=_SidKey(), num_partitions=state_partitions,
                partitioner=_HashMod(state_partitions),
                init_state=spec.init_state,
                cost_us=spec.cost_us, selectivity=spec.selectivity,
            )
        else:  # PARTITIONED
            tagged[name] = OpSpec(
                name=spec.name, kind=PARTITIONED,
                fn=_TagPartitioned(spec.fn),
                key_fn=_TagKey(spec.key_fn),
                num_partitions=spec.num_partitions,
                partitioner=_HashMod(spec.num_partitions),
                init_state=spec.init_state,
                cost_us=spec.cost_us, selectivity=spec.selectivity,
            )
    return tagged, list(edge_list)


# ------------------------------------------------------------------ session
class MuxSession:
    """One client's ordered stream over the shared runtime.

    ``push(values)`` feeds this session (blocking on its own bounded
    ingress queue, shedding with :class:`AdmissionError` past
    ``push_timeout``); ``try_push(value)`` is the non-blocking form;
    ``results()`` iterates exactly this session's outputs in push order;
    ``close()`` seals the session and waits until its in-flight tuples
    drained — other sessions stream on.  Deques cross the pump-thread
    boundary (atomic append/popleft); no locks are shared with the
    runtime."""

    def __init__(self, mux: "SessionMux", sid: int, weight: float):
        self._mux = mux
        self.sid = sid
        self.weight = weight
        self._ingress: collections.deque = collections.deque()
        self._results: collections.deque = collections.deque()
        self.pushed = 0       # accepted into the ingress queue
        self.admitted = 0     # handed to the runtime (pump thread)
        self.egressed = 0     # delivered into the result queue (pump thread)
        self.consumed = 0     # taken by the client
        self._closing = False   # no more pushes; token queued behind ingress
        self._drained = threading.Event()  # flush token egressed
        self._deficit = 0.0

    # ---- client surface ---------------------------------------------------
    def try_push(self, value: Any) -> bool:
        """Non-blocking push into this session's ingress queue."""
        mux = self._mux
        if self._closing or mux._closed:
            raise RuntimeError(f"session {self.sid} is closed")
        mux._raise_pump_error()
        if len(self._ingress) >= mux.config.ingress_depth:
            return False
        self._ingress.append(value)
        self.pushed += 1
        return True

    def push(self, values: Iterable[Any],
             timeout: Optional[float] = None) -> int:
        """Push an iterable in order; blocks per tuple while this session's
        ingress queue is full, shedding with :class:`AdmissionError` after
        ``timeout`` (default ``MuxConfig.push_timeout``) seconds without
        space.  Returns how many tuples were accepted."""
        limit = self._mux.config.push_timeout if timeout is None else timeout
        n = 0
        for value in values:
            deadline = time.perf_counter() + limit
            while not self.try_push(value):
                if time.perf_counter() > deadline:
                    raise AdmissionError(
                        f"session {self.sid}: ingress queue full for "
                        f"{limit}s ({len(self._ingress)} queued) — shedding",
                        reason="ingress_full", sid=self.sid,
                        limit=self._mux.config.ingress_depth,
                        snapshot=self._mux.stats(),
                    )
                time.sleep(1e-4)
            n += 1
        return n

    def poll(self, max_items: Optional[int] = None) -> list:
        """Non-blocking read of this session's ready outputs (in order)."""
        self._mux._raise_pump_error()
        out = []
        limit = len(self._results) if max_items is None else max_items
        for _ in range(limit):
            try:
                out.append(self._results.popleft())
            except IndexError:
                break
        self.consumed += len(out)
        return out

    def results(self, max_items: Optional[int] = None,
                timeout: Optional[float] = None) -> Iterator[Any]:
        """Iterate this session's ordered outputs as they materialize.

        Ends when the session is closed and fully drained.  ``timeout``
        bounds *continuous* starvation (clock resets on every arrival);
        expiry raises :class:`~repro.core.api.SessionStarvation` whose
        snapshot carries per-session backlog stats for the whole mux."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        yielded = 0
        while max_items is None or yielded < max_items:
            batch = self.poll(
                None if max_items is None else max_items - yielded
            )
            if batch:
                if timeout is not None:
                    deadline = time.perf_counter() + timeout
                for value in batch:
                    yielded += 1
                    yield value
                continue
            if self._drained.is_set() and not self._results:
                return
            if deadline is not None and time.perf_counter() > deadline:
                snap = self._mux.stats()
                raise SessionStarvation(
                    f"mux session {self.sid} starved: no output for "
                    f"{timeout}s (pushed={self.pushed}, "
                    f"egressed={self.egressed}); snapshot: {snap}",
                    snapshot=snap,
                )
            time.sleep(1e-4)

    def backlog(self) -> dict:
        """This session's live backlog counters (pump-visible state)."""
        return {
            "pushed": self.pushed,
            "admitted": self.admitted,
            "egressed": self.egressed,
            "consumed": self.consumed,
            "ingress_queued": len(self._ingress),
            "undelivered": len(self._results),
            "weight": self.weight,
            "closing": self._closing,
            "drained": self._drained.is_set(),
        }

    def close(self, drain_timeout: float = 60.0) -> dict:
        """Seal this session and wait for its in-flight tuples to drain
        (flush token round-trips the ordered stream); other sessions are
        untouched.  Returns the final backlog counters."""
        if not self._closing:
            self._closing = True  # pump queues the token once ingress drains
        deadline = time.perf_counter() + drain_timeout
        while not self._drained.wait(timeout=0.05):
            self._mux._raise_pump_error()
            if self._mux._closed:
                break
            if time.perf_counter() > deadline:
                raise TimeoutError(
                    f"session {self.sid} failed to drain in {drain_timeout}s:"
                    f" {self.backlog()}"
                )
        return self.backlog()

    # ---- context manager ---------------------------------------------------
    def __enter__(self) -> "MuxSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._drained.is_set():
            try:
                self.close()
            except Exception:
                if exc_type is None:
                    raise


# -------------------------------------------------------------------- mux
class SessionMux:
    """Admit many ordered sessions onto one planned runtime.

    ::

        engine = Engine(EngineConfig(backend="process", num_workers=4))
        mux = SessionMux(engine, graph, config=MuxConfig(max_sessions=128))
        with mux:
            a, b = mux.open(), mux.open(weight=2.0)
            a.push(stream_a); b.push(stream_b)
            for out in a.results(): ...
            a.close(); b.close()

    The constructor rewrites the graph with :func:`tag_graph`, opens one
    inner :class:`~repro.core.api.Session` over it, and starts the pump
    thread that owns that session."""

    def __init__(self, engine: Engine, graph, edges=None, *,
                 config: Optional[MuxConfig] = None):
        self.config = (config or MuxConfig()).validate()
        self.engine = engine
        nodes, edge_list = tag_graph(
            graph, edges, state_partitions=self.config.state_partitions
        )
        self.plan = engine.plan((nodes, edge_list))
        self._inner = engine.open(self.plan)
        self._sessions: Dict[int, MuxSession] = {}
        self._retired: Dict[int, dict] = {}
        self._sid_iter = itertools.count()
        self._closed = False
        self._pump_error: Optional[BaseException] = None
        self._opened = 0
        self._undeliverable = 0
        self._admitted_total = 0  # monotonic; pump thread only
        self._pending_tokens: collections.deque = collections.deque()
        self.report = None
        self._pump = threading.Thread(
            target=self._pump_loop, name="mux-pump", daemon=True
        )
        self._pump.start()

    # ---- client surface ---------------------------------------------------
    def open(self, weight: float = 1.0) -> MuxSession:
        """Admit a new session (raises :class:`AdmissionError` at the
        ``max_sessions`` cap).  ``weight`` scales the session's fair-share
        quantum (2.0 = twice the ingress bandwidth under contention)."""
        self._raise_pump_error()
        if self._closed:
            raise AdmissionError("mux is closed", reason="mux_closed")
        if weight <= 0:
            raise ValueError("weight must be > 0")
        if len(self._sessions) >= self.config.max_sessions:
            raise AdmissionError(
                f"admission rejected: {len(self._sessions)} open sessions "
                f"(max_sessions={self.config.max_sessions})",
                reason="max_sessions", limit=self.config.max_sessions,
                snapshot=self.stats(),
            )
        sid = next(self._sid_iter)
        session = MuxSession(self, sid, weight)
        # publish fully-constructed: dict assignment is atomic and the pump
        # only iterates snapshots (list(...)) of this dict
        self._sessions[sid] = session
        self._opened += 1
        return session

    def stats(self) -> dict:
        """Per-session backlog stats plus inner-runtime counters."""
        inner: dict = {}
        if self._closed and self.report is not None:
            inner = {"closed": True}
        sessions = {
            sid: s.backlog() for sid, s in list(self._sessions.items())
        }
        return {
            "sessions": sessions,
            "retired": dict(self._retired),
            "open_sessions": len(sessions),
            "opened_total": self._opened,
            "undeliverable": self._undeliverable,
            "max_sessions": self.config.max_sessions,
            "traffic": self.load_signals(),
            "inner": inner,
        }

    def load_signals(self) -> dict:
        """Aggregate serving-tier load snapshot for elastic replanning.

        Keys: ``ts`` (perf_counter), ``sessions`` (open count),
        ``admitted_total`` (monotonic tuples admitted into the runtime),
        ``ingress_queued`` (tuples parked in DRR ingress queues — admission
        pressure the runtime is not absorbing), ``backpressured`` (sessions
        paused on a full result buffer), ``undeliverable``.  The pump feeds
        this to ``Session.offer_load`` every ``load_signal_interval``
        seconds; the process backend's :class:`~repro.core.TrafficMonitor`
        turns it into grow/shrink proposals."""
        cfg = self.config
        sessions = list(self._sessions.values())
        return {
            "ts": time.perf_counter(),
            "sessions": len(sessions),
            "admitted_total": self._admitted_total,
            "ingress_queued": sum(len(s._ingress) for s in sessions),
            "backpressured": sum(
                1 for s in sessions if len(s._results) >= cfg.result_budget
            ),
            "undeliverable": self._undeliverable,
        }

    def close(self, drain_timeout: float = 60.0):
        """Close every session, drain, stop the pump, close the inner
        session; returns the runtime's final report (idempotent)."""
        if self._closed:
            return self.report
        for s in list(self._sessions.values()):
            s._closing = True
        deadline = time.perf_counter() + drain_timeout
        while any(
            not s._drained.is_set() for s in list(self._sessions.values())
        ):
            self._raise_pump_error()
            if time.perf_counter() > deadline:
                self._closed = True  # stop the pump before raising
                raise TimeoutError(
                    f"mux failed to drain in {drain_timeout}s: {self.stats()}"
                )
            time.sleep(1e-3)
        self._closed = True
        self._pump.join(timeout=drain_timeout)
        self._raise_pump_error()
        self.report = self._inner.close(drain_timeout=drain_timeout)
        return self.report

    def __enter__(self) -> "SessionMux":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:
            self._closed = True
            self._inner._abort()

    # ---- pump thread ------------------------------------------------------
    def _raise_pump_error(self) -> None:
        if self._pump_error is not None:
            raise RuntimeError(
                f"mux pump failed: {self._pump_error!r}"
            ) from self._pump_error

    def _pump_loop(self) -> None:
        try:
            idle_spin = 0
            # duck-typed: the inner session exports load signals to the
            # supervisor when it can (process backend); fakes/thread
            # sessions without the hook are simply not fed
            offer = getattr(self._inner, "offer_load", None)
            crank = getattr(self._inner, "service_once", None)
            signal_at = 0.0
            while not self._closed:
                if offer is not None:
                    now = time.perf_counter()
                    if now >= signal_at:
                        signal_at = now + self.config.load_signal_interval
                        offer(self.load_signals())
                moved = self._pump_ingress()
                moved |= self._pump_egress()
                if crank is not None:
                    # crank the backend every turn: the process backend's
                    # single-threaded supervisor must not ration its
                    # progress on try_push/poll side effects while the
                    # pump is busy moving tuples (paced traffic would
                    # otherwise run far below flood capacity)
                    moved |= crank()
                if moved:
                    idle_spin = 0
                else:
                    idle_spin += 1
                    self._inner.service()
                    if idle_spin > 4:
                        time.sleep(1e-4)
            # final egress sweep so close() sees every delivered output
            self._pump_egress()
        except BaseException as e:  # surfaced to every client call
            self._pump_error = e

    def _pump_ingress(self) -> bool:
        """One deficit-round-robin scheduling round over live sessions."""
        cfg = self.config
        moved = False
        for session in list(self._sessions.values()):
            if session._drained.is_set():
                continue
            # per-session backpressure: a slow consumer stops being
            # admitted into the runtime, not delivered from it
            if len(session._results) >= cfg.result_budget:
                continue
            # cap banked credit at two rounds (and never below one tuple,
            # or a tiny weight could starve its own session forever)
            session._deficit = min(
                session._deficit + cfg.quantum * session.weight,
                max(1.0, 2 * cfg.quantum * session.weight),
            )
            while session._deficit >= 1.0:
                try:
                    value = session._ingress.popleft()
                except IndexError:
                    if session._closing:
                        # ingress empty + closing: send the drain token
                        # exactly once, behind everything already admitted
                        if session.admitted == session.pushed:
                            self._pending_tokens.append(session.sid)
                            session.admitted += 1  # token slot: queue once
                        break
                    # idle turn: keep the banked credit (the accrual cap
                    # above already bounds it at two rounds) — a briefly
                    # paused high-weight session must not forfeit its
                    # earned share, exactly like a backpressured one
                    break
                if not self._inner.try_push((session.sid, value)):
                    session._ingress.appendleft(value)  # runtime is full
                    return moved
                session.admitted += 1
                self._admitted_total += 1
                session._deficit -= 1.0
                moved = True
        while self._pending_tokens:
            sid = self._pending_tokens[0]
            if not self._inner.try_push(_FlushToken(sid)):
                break
            self._pending_tokens.popleft()
            moved = True
        return moved

    def _pump_egress(self) -> bool:
        outs = self._inner.poll()
        if not outs:
            return False
        for item in outs:
            if isinstance(item, _FlushToken):
                session = self._sessions.get(item.sid)
                if session is not None and not session._drained.is_set():
                    session._drained.set()
                    self._retire(session)
                continue  # duplicate after crash replay: idempotent
            sid, value = item
            session = self._sessions.get(sid)
            if session is None:
                # late output of a retired session (crash replay overlap):
                # ordered egress makes this impossible in a clean run, and
                # replay duplicates are not deliverable — count, don't leak
                self._undeliverable += 1
                continue
            session._results.append(value)
            session.egressed += 1
        return True

    def _retire(self, session: MuxSession) -> None:
        self._retired[session.sid] = {
            "pushed": session.pushed,
            "egressed": session.egressed,
        }
        self._sessions.pop(session.sid, None)
