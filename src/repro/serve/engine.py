"""Ordered serving engine: continuous batching + ordered egress.

This is the paper's workload embodied (DESIGN.md §2): requests arrive with
serial numbers; decode completes out of order (variable generation lengths);
egress must preserve arrival order. The engine is a two-operator pipeline

    prefill (partitioned stateful, keyed by slot)  ->  decode (partitioned)
            -> ordered egress via NonBlockingReorderBuffer

with a CT-style dynamic choice between running a prefill or a decode step
each iteration — the paper's "pipelined flow beats single-operator
saturation" finding shows up as interleave > drain-all-prefills-first.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reorder import NonBlockingReorderBuffer, ParkingReorderBuffer
from repro.core.serial import SerialAssigner
from repro.models import transformer
from repro.models.common import ModelConfig


@functools.lru_cache(maxsize=None)
def _compiled_fns(cfg: ModelConfig, max_len: int):
    """Shared jitted (prefill, decode) pair, keyed by the only inputs the
    traces close over.  Engines are cheap to construct (tests build one per
    case); without this cache every instance re-traces and re-compiles both
    functions, which dominates wall time and trips suite watchdogs on
    loaded hosts."""
    prefill1 = jax.jit(
        lambda p, t: transformer.prefill(cfg, p, t, max_len=max_len)
    )

    def _decode_fn(p, tok, cache, pos):
        logits, cache = transformer.decode_step(cfg, p, tok, cache, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return prefill1, jax.jit(_decode_fn)


@dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    serial: int = 0
    submitted_at: float = 0.0


@dataclass
class Completion:
    serial: int
    tokens: np.ndarray
    latency_s: float = 0.0


class OrderedServingEngine:
    """Continuous-batching jax model server with ordered completions.

    Requests share ``max_slots`` decode slots (admitted in serial order);
    completions egress through a serial-number reorder ring, so callers see
    results in submission order regardless of per-request decode length —
    the model-serving embodiment of the paper's ordered-egress problem."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_slots: int = 4,
        max_len: int = 96,
        schedule: str = "interleave",  # or "prefill_first" (micro-batch style)
        eos_token: int = -1,
        reorder_size: int = 256,
    ):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.schedule = schedule
        self.eos = eos_token

        self._serials = SerialAssigner()
        self.pending: list[Request] = []
        self.completions: list[Completion] = []
        # Parking wrapper: a slow head-of-line request can hold ``next`` back
        # while more than reorder_size later requests complete. The engine is
        # single threaded, so spinning in send_blocking would livelock —
        # out-of-window completions park host-side and drain as the ring
        # window advances.
        self._reorder = ParkingReorderBuffer(
            NonBlockingReorderBuffer(self._emit, size=reorder_size)
        )

        # slot state (host-side bookkeeping; device-side cache batch = slots)
        self.slot_serial = [-1] * max_slots
        self.slot_generated: list[list[int]] = [[] for _ in range(max_slots)]
        self.slot_budget = [0] * max_slots
        self.slot_t0 = [0.0] * max_slots
        self.position = np.zeros((max_slots,), np.int32)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            transformer.abstract_cache(cfg, max_slots, max_len),
        )
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.active = np.zeros((max_slots,), bool)

        self._prefill1, self._decode = _compiled_fns(cfg, max_len)
        self.stats = {"prefills": 0, "decode_steps": 0, "emitted": 0}

    # ------------------------------------------------------------------ api
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        """Enqueue a prompt; returns its serial (completion order)."""
        serial = self._serials.next()
        self.pending.append(
            Request(np.asarray(prompt, np.int32), max_new_tokens, serial, time.perf_counter())
        )
        return serial

    def _emit(self, completion: Completion) -> None:
        self.completions.append(completion)
        self.stats["emitted"] += 1

    # ------------------------------------------------------------- internals
    def _free_slot(self) -> Optional[int]:
        for b in range(self.max_slots):
            if not self.active[b]:
                return b
        return None

    def _do_prefill(self) -> None:
        req = self.pending.pop(0)
        b = self._free_slot()
        assert b is not None
        logits, cache1 = self._prefill1(self.params, req.prompt[None, :])
        first = int(jnp.argmax(logits[0]))
        # install the request's KV into slot b (prefill->decode hand-off)
        self.cache = jax.tree.map(
            lambda c, c1: c.at[:, b].set(c1[:, 0]), self.cache, cache1
        )
        self.tokens = self.tokens.at[b].set(first)
        self.position[b] = len(req.prompt)
        self.slot_serial[b] = req.serial
        self.slot_generated[b] = [first]
        self.slot_budget[b] = req.max_new_tokens - 1
        self.slot_t0[b] = req.submitted_at
        self.active[b] = True
        self.stats["prefills"] += 1

    def _do_decode(self) -> None:
        # ``self.position`` is a host buffer mutated in place below (and by
        # ``_do_prefill``).  ``jnp.asarray`` zero-copies 64-byte-aligned numpy
        # arrays on CPU, so handing it over directly lets the in-place update
        # race the asynchronously dispatched decode — the kernel can read a
        # *later* position, silently corrupting the attention mask.  A fresh
        # copy per call is never mutated and stays alive via the jax array.
        next_tok, self.cache = self._decode(
            self.params, self.tokens, self.cache, jnp.asarray(self.position.copy())
        )
        self.tokens = next_tok
        self.position += self.active.astype(np.int32)
        self.stats["decode_steps"] += 1
        toks = np.asarray(next_tok).reshape(-1)
        for b in range(self.max_slots):
            if not self.active[b]:
                continue
            self.slot_generated[b].append(int(toks[b]))
            self.slot_budget[b] -= 1
            done = (
                self.slot_budget[b] <= 0
                or int(toks[b]) == self.eos
                or self.position[b] >= self.max_len - 1
            )
            if done:
                comp = Completion(
                    self.slot_serial[b],
                    np.asarray(self.slot_generated[b], np.int32),
                    time.perf_counter() - self.slot_t0[b],
                )
                # ordered egress: the reorder buffer holds it until all
                # earlier-arrived requests have been emitted; out-of-window
                # completions park (never spin) and drain on later sends
                self._reorder.send(comp.serial, comp)
                self.active[b] = False
                self.slot_serial[b] = -1

    # ------------------------------------------------------------------ run
    def step(self) -> bool:
        """One scheduler decision. Returns False when fully idle."""
        can_prefill = self.pending and self._free_slot() is not None
        can_decode = self.active.any()
        if not can_prefill and not can_decode:
            return False
        if self.schedule == "prefill_first":
            if can_prefill:
                self._do_prefill()
            else:
                self._do_decode()
        else:  # interleave: keep the decode pipeline flowing (CT-style)
            if can_decode and (self.stats["decode_steps"] == 0 or not can_prefill):
                self._do_decode()
            elif can_prefill and self.active.sum() < self.max_slots:
                self._do_prefill()
            else:
                self._do_decode()
        return True

    def run_to_completion(self, max_steps: int = 100_000) -> list[Completion]:
        """Step until every submitted request completed; returns the
        completions drained so far, in serial order."""
        steps = 0
        while self.step():
            steps += 1
            if steps > max_steps:
                raise RuntimeError("engine did not converge")
        return self.completions
