"""Core ordered-stream-processing library (the paper's contribution).

Host tier (faithful reproduction, threads + atomics):
  serial, reorder, hybrid, operators, pipeline, scheduler, runtime, simulate

Device tier (TPU-native vectorized adaptation, JAX + Pallas):
  vectorized
"""
from .serial import AtomicFlag, AtomicLong, SerialAssigner
from .reorder import (
    LockBasedReorderBuffer,
    NonBlockingReorderBuffer,
    ParkingReorderBuffer,
    ReorderBuffer,
    make_reorder_buffer,
)
from .hybrid import (
    HybridQueueWorklist,
    PartitionedQueueWorklist,
    SharedQueueWorklist,
    make_worklist,
)
from .operators import OpSpec, OperatorNode, OpStats, PARTITIONED, STATEFUL, STATELESS
from .pipeline import (
    CompiledPipeline,
    GraphPipeline,
    Merge,
    Split,
    compile_graph,
    compile_pipeline,
)
from .costmodel import (
    CostModel,
    OccupancyMonitor,
    TrafficMonitor,
    TrafficSnapshot,
    proportional_allocation,
    resolve_workers,
)
from .scheduler import HEURISTICS, Scheduler
from .runtime import RunReport, StreamRuntime, run_graph, run_pipeline
from .procrun import ProcessRuntime, UnstagedGraphWarning
from .shm import ShmReorderRing, ShmSpscRing
from .faults import (
    DeadLetter,
    FaultOptions,
    FaultPlan,
    FaultSpec,
    InjectedFault,
)
from .api import (
    ConfigError,
    Engine,
    EngineConfig,
    JobHandle,
    JobResult,
    PhysicalPlan,
    PlannedOp,
    PlannedStage,
    PlanVerificationError,
    ProcessOptions,
    Session,
    SessionStarvation,
    ThreadOptions,
)

__all__ = [
    "ConfigError",
    "PlanVerificationError",
    "Engine",
    "EngineConfig",
    "JobHandle",
    "JobResult",
    "PhysicalPlan",
    "PlannedOp",
    "PlannedStage",
    "ProcessOptions",
    "Session",
    "SessionStarvation",
    "ThreadOptions",
    "DeadLetter",
    "FaultOptions",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "AtomicFlag",
    "AtomicLong",
    "SerialAssigner",
    "LockBasedReorderBuffer",
    "NonBlockingReorderBuffer",
    "ParkingReorderBuffer",
    "ReorderBuffer",
    "make_reorder_buffer",
    "HybridQueueWorklist",
    "PartitionedQueueWorklist",
    "SharedQueueWorklist",
    "make_worklist",
    "OpSpec",
    "OperatorNode",
    "OpStats",
    "PARTITIONED",
    "STATEFUL",
    "STATELESS",
    "CompiledPipeline",
    "GraphPipeline",
    "Split",
    "Merge",
    "compile_graph",
    "compile_pipeline",
    "CostModel",
    "OccupancyMonitor",
    "TrafficMonitor",
    "TrafficSnapshot",
    "proportional_allocation",
    "resolve_workers",
    "HEURISTICS",
    "Scheduler",
    "RunReport",
    "StreamRuntime",
    "run_graph",
    "run_pipeline",
    "ProcessRuntime",
    "UnstagedGraphWarning",
    "ShmReorderRing",
    "ShmSpscRing",
]
