"""Deterministic fault injection for the process backend (chaos harness).

A :class:`FaultPlan` is a reproducible schedule of injected failures keyed by
``(stage, worker, serial)``.  Two delivery paths:

- **Supervisor-side** faults (``kill``, ``hang``, ``router_kill``) are process
  signals.  The parent samples each stage's drained-serial counter during its
  supervision tick and fires the signal once the counter crosses the spec's
  trigger serial — so a given plan kills at (approximately) the same stream
  position on every run, independent of wall-clock timing.
- **Child-side** faults (``op_error``, ``spill_delay``) ride the worker fork
  arguments: the worker raises :class:`InjectedFault` while processing the
  trigger serial, or sleeps before shipping a spill body.

``op_error`` composes with the per-op ``on_error`` policy
(:class:`FaultOptions`): ``raise`` aborts the job (the classic path),
``skip`` drops the offending tuple, ``dead_letter`` drops it AND quarantines
a :class:`DeadLetter` record surfaced in ``JobResult.dead_letters`` — so the
chaos battery can assert exact accounting of every injected failure.

Everything here is plain data (validated dataclasses): the runtime wiring
lives in :mod:`.procrun`, the config plumbing in :mod:`.api`.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

KILL = "kill"  # SIGKILL a worker once its stage drains past `serial`
HANG = "hang"  # SIGSTOP a worker (hung-not-dead: exercises stall detection)
ROUTER_KILL = "router_kill"  # SIGKILL the stage's exchange router
OP_ERROR = "op_error"  # raise InjectedFault inside the worker at `serial`
SPILL_DELAY = "spill_delay"  # sleep `delay`s before shipping a spill body

_KINDS = (KILL, HANG, ROUTER_KILL, OP_ERROR, SPILL_DELAY)
_CHILD_KINDS = (OP_ERROR, SPILL_DELAY)
ON_ERROR_POLICIES = ("raise", "skip", "dead_letter")


class InjectedFault(RuntimeError):
    """The exception an ``op_error`` fault raises inside a worker."""


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  ``serial`` is the trigger position in the
    stage's serial stream; ``worker`` is ignored for ``router_kill``;
    ``delay`` applies to ``spill_delay`` only."""

    kind: str
    stage: int = 0
    worker: int = 0
    serial: int = 1
    delay: float = 0.0

    def validate(self) -> None:
        """Raise ``ValueError`` if any field is out of range for its kind."""
        if self.kind not in _KINDS:
            raise ValueError(
                f"fault kind must be one of {_KINDS}, got {self.kind!r}"
            )
        if self.stage < 0 or self.worker < 0:
            raise ValueError("fault stage/worker must be >= 0")
        if self.serial < 1:
            raise ValueError("fault serial must be >= 1 (serials start at 1)")
        if self.kind == SPILL_DELAY and self.delay < 0:
            raise ValueError("spill_delay needs delay >= 0")


@dataclass
class FaultPlan:
    """A deterministic fault schedule: an explicit spec list, optionally
    derived from a seed (:meth:`generate`)."""

    specs: List[FaultSpec] = field(default_factory=list)
    seed: Optional[int] = None

    def validate(self) -> None:
        """Validate every spec in the schedule (see :meth:`FaultSpec.validate`)."""
        for spec in self.specs:
            spec.validate()

    @classmethod
    def generate(
        cls,
        seed: int,
        *,
        n_faults: int,
        stage_widths: Sequence[int],
        max_serial: int,
        kinds: Sequence[str] = (KILL,),
    ) -> "FaultPlan":
        """Derive a reproducible schedule from a seed: ``n_faults`` specs
        drawn uniformly over the given kinds, stages/workers (from
        ``stage_widths``), and serials in ``[1, max_serial]``."""
        rng = random.Random(seed)
        specs = []
        for _ in range(n_faults):
            kind = rng.choice(list(kinds))
            stage = rng.randrange(len(stage_widths))
            if kind == ROUTER_KILL:
                stage = max(stage, 1) if len(stage_widths) > 1 else 1
            spec = FaultSpec(
                kind=kind,
                stage=stage,
                worker=rng.randrange(max(stage_widths[min(stage, len(stage_widths) - 1)], 1))
                if stage < len(stage_widths) else 0,
                serial=rng.randrange(1, max(max_serial, 2)),
                delay=rng.uniform(0.0, 0.05) if kind == SPILL_DELAY else 0.0,
            )
            specs.append(spec)
        plan = cls(specs=specs, seed=seed)
        plan.validate()
        return plan

    # -- delivery-path splits (consumed by procrun) -------------------------
    def supervisor_specs(self) -> List[FaultSpec]:
        """Signal faults the parent fires off drained-serial counters."""
        return [s for s in self.specs if s.kind not in _CHILD_KINDS]

    def child_specs(self, stage: int, worker: int) -> Dict[str, Dict[int, FaultSpec]]:
        """Faults a specific worker injects on itself, keyed
        ``kind -> {trigger serial -> spec}`` (empty dicts elided)."""
        out: Dict[str, Dict[int, FaultSpec]] = {}
        for s in self.specs:
            if s.kind in _CHILD_KINDS and s.stage == stage and s.worker == worker:
                out.setdefault(s.kind, {})[s.serial] = s
        return out


@dataclass
class FaultOptions:
    """Fault-injection config carried by :class:`~.api.EngineConfig`.

    ``on_error`` is the worker-side policy for operator exceptions (injected
    or organic): a single policy string, or a per-op ``{op_name: policy}``
    mapping (ops not named fall back to ``raise``)."""

    plan: Optional[FaultPlan] = None
    on_error: Union[str, Dict[str, str]] = "raise"

    def validate(self) -> None:
        """Validate the plan (if any) and every ``on_error`` policy name."""
        if self.plan is not None:
            self.plan.validate()
        policies = (
            self.on_error.values()
            if isinstance(self.on_error, dict)
            else [self.on_error]
        )
        for p in policies:
            if p not in ON_ERROR_POLICIES:
                raise ValueError(
                    f"on_error policy must be one of {ON_ERROR_POLICIES}, "
                    f"got {p!r}"
                )

    def policy_for(self, op_name: str) -> str:
        """Resolve the effective ``on_error`` policy for one operator."""
        if isinstance(self.on_error, dict):
            return self.on_error.get(op_name, "raise")
        return self.on_error

    def to_dict(self) -> dict:
        """Plain-dict form (JSON-safe) for configs and logs; inverse of
        :meth:`from_dict`."""
        return {
            "plan": None if self.plan is None else {
                "seed": self.plan.seed,
                "specs": [vars(s).copy() for s in self.plan.specs],
            },
            "on_error": self.on_error
            if isinstance(self.on_error, str)
            else dict(self.on_error),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FaultOptions":
        """Rebuild options from :meth:`to_dict` output."""
        plan = None
        if d.get("plan"):
            plan = FaultPlan(
                specs=[FaultSpec(**s) for s in d["plan"].get("specs", ())],
                seed=d["plan"].get("seed"),
            )
        return cls(plan=plan, on_error=d.get("on_error", "raise"))


@dataclass(frozen=True)
class DeadLetter:
    """One quarantined tuple: the input that made an operator raise under
    the ``dead_letter`` policy, with enough context to replay or audit it."""

    stage: int
    worker: int
    serial: int
    op: str
    value: object
    error: str


def resolve_policies(on_error, ops) -> Tuple[str, ...]:
    """Flatten an ``on_error`` config into one policy per op in a stage's
    run (fork-argument form: workers index it positionally)."""
    if isinstance(on_error, str):
        return tuple(on_error for _ in ops)
    return tuple(on_error.get(op.name, "raise") for op in ops)
