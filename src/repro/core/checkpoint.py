"""Epoch checkpointing for stateful/keyed process stages (TStream-style
transactional state management grafted onto the serial protocol).

Protocol (the runtime wiring lives in :mod:`.procrun`):

1. Every ``checkpoint_interval`` serials a stage's *feeder* flushes its
   partial dispatch units and stamps a ``TAG_BARRIER`` record into every
   active ingress ring — the record's serial field is the epoch's boundary
   serial ``B`` (all serials ``< B`` precede it in every ring, per-ring FIFO)
   and its payload is the epoch number.
2. Each worker, on consuming the barrier, snapshots its worker-local state
   (exactly the elastic-handoff blob) and acks ``("ckpt", wid, epoch, B,
   blob)`` over its control pipe.  Nothing is published to the reorder ring
   for a barrier, so the serial stream is untouched.
3. The supervisor collects acks in this :class:`CheckpointStore`; an epoch
   *completes* when every active worker has acked, at which point it becomes
   the stage's restore point and the feeder is told to truncate its replay
   log below ``B`` (``("ckpt_done", epoch, B)``).
4. On a keyed/stateful worker crash the supervisor halts the feeder, kills
   the rest of the group, resets the ingress rings, re-forks the group
   preloaded with the epoch-``B`` snapshots, and has the feeder re-pump its
   replay log ``[B, …)`` — deterministic segments plus the reorder ring's
   per-serial idempotence make the recovered egress exact.

An elastic resize doubles as a *synthetic* checkpoint (:meth:`force`): the
quiesced handoff state at the resize boundary is already exactly a complete
epoch snapshot at the new width.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

_I8 = struct.Struct("<q")


def encode_barrier(epoch: int) -> bytes:
    """Barrier record payload: the 8-byte epoch number."""
    return _I8.pack(epoch)


def decode_barrier(data: bytes) -> int:
    return _I8.unpack(data)[0]


@dataclass
class Checkpoint:
    """A completed epoch: per-worker state blobs valid at ``boundary``
    (state after applying every serial ``< boundary``)."""

    epoch: int
    boundary: int
    blobs: Dict[int, Optional[bytes]] = field(default_factory=dict)


class CheckpointStore:
    """Supervisor-held snapshot store: pending per-epoch acks plus the
    latest *complete* checkpoint per stage (older epochs are dropped — the
    replay log only ever covers the latest boundary onward)."""

    def __init__(self) -> None:
        # pending acks keyed by BOUNDARY, not epoch: boundaries are globally
        # monotone per stage across feeder restarts (serial positions),
        # while epoch labels restart with a re-forked router's dispatcher.
        # Two barriers at the same boundary snapshot identical state
        # (deterministic replay), so merging their acks is sound.
        self._pending: Dict[int, Dict[int, Dict[int, Optional[bytes]]]] = {}
        self._epoch: Dict[Tuple[int, int], int] = {}  # (stage, B) -> label
        self._latest: Dict[int, Checkpoint] = {}
        self.completed = 0  # completed-epoch count (instrumentation)

    def ack(
        self, stage: int, wid: int, epoch: int, boundary: int,
        blob: Optional[bytes], width: int,
    ) -> Optional[Checkpoint]:
        """Record one worker's epoch ack; returns the finished
        :class:`Checkpoint` when this ack completes the epoch (every worker
        in ``range(width)`` acked), else None.  Replayed barriers re-ack
        idempotently; acks at or below the stage's latest complete boundary
        are ignored."""
        latest = self._latest.get(stage)
        if latest is not None and boundary <= latest.boundary:
            return None
        stage_pending = self._pending.setdefault(stage, {})
        acks = stage_pending.setdefault(boundary, {})
        acks[wid] = blob
        key = (stage, boundary)
        self._epoch[key] = max(self._epoch.get(key, 0), epoch)
        if set(acks) < set(range(width)):
            return None
        ckpt = Checkpoint(self._epoch[key], boundary, dict(acks))
        self._commit(stage, ckpt)
        return ckpt

    def force(self, stage: int, boundary: int, blobs: Dict[int, Optional[bytes]]) -> Checkpoint:
        """Install a synthetic checkpoint (elastic-resize quiesce: the
        handed-off state at the boundary IS a complete snapshot).  Epoch
        numbering continues from the stage's last complete epoch."""
        latest = self._latest.get(stage)
        epoch = (latest.epoch if latest else 0) + 1
        ckpt = Checkpoint(epoch, boundary, dict(blobs))
        self._commit(stage, ckpt)
        return ckpt

    def _commit(self, stage: int, ckpt: Checkpoint) -> None:
        self._latest[stage] = ckpt
        self.completed += 1
        stage_pending = self._pending.get(stage)
        if stage_pending:
            for b in [b for b in stage_pending if b <= ckpt.boundary]:
                del stage_pending[b]
        for key in [
            k for k in self._epoch if k[0] == stage and k[1] <= ckpt.boundary
        ]:
            del self._epoch[key]

    def latest(self, stage: int) -> Optional[Checkpoint]:
        """The stage's current restore point (None before the first
        complete epoch: recovery then replays from serial 1 with fresh
        state — the log is never truncated before a checkpoint exists)."""
        return self._latest.get(stage)

    def clear_pending(self, stage: int) -> None:
        """Drop in-flight (incomplete) epoch acks — a group restore or
        resize invalidates them (the replayed/new group re-acks)."""
        self._pending.pop(stage, None)
        for key in [k for k in self._epoch if k[0] == stage]:
            del self._epoch[key]
