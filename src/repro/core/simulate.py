"""Discrete-event simulator of the runtime (virtual time, 1-core container).

Reproduces the paper's *scaling* results (figs. 8-14) faithfully: the real
data-structure logic (worklists, hybrid/partitioned queues, reorder buffers,
scheduling heuristics) drives a W-worker virtual-time simulation where
per-tuple costs are declared. The thread runtime (runtime.py) validates
correctness on real threads; this engine measures concurrency behaviour the
1-core container cannot exhibit. DESIGN.md §7 records which figures use which.

Cost model (defaults match the paper's micro-benchmark scales):
- processing a tuple on operator o: cost_us (deterministic + optional jitter)
- reorder add: add_us; sending one output downstream: send_us
- lock-based scheme: add/drain require the op's lock -> arriving workers
  BLOCK until the holder finishes draining (fig. 3's pathology)
- non-blocking scheme: adds never wait; the drain is done by whoever grabs
  the try-lock flag, others continue immediately
- hybrid queue: delegated tuples are processed by the partition's active
  worker (extends its busy time); the delegating worker moves on (never
  blocks). partitioned-queue: static bucket ownership.
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Callable, Optional

from .scheduler import HEURISTICS


@dataclass
class SimOp:
    name: str
    kind: str  # stateless | stateful | partitioned
    cost_us: float
    selectivity: float = 1.0
    num_partitions: int = 1
    key_of: Optional[Callable[[int, random.Random], int]] = None  # (serial, rng)


@dataclass
class SimConfig:
    num_workers: int = 4
    heuristic: str = "ct"
    reorder_scheme: str = "non_blocking"  # or lock_based
    worklist_scheme: str = "hybrid"  # or partitioned
    time_slice_us: float = 2000.0
    # serialization costs (µs): lock hold = add+drain work; calibrated to the
    # paper's fig.12 regime where a 10µs op saturates a lock at ~16 workers
    add_us: float = 0.2
    send_us: float = 0.5
    reorder_size: int = 4096
    ct_window_us: float = 50_000.0
    qst_capacity: int = 4096
    jitter: float = 0.25  # ±12.5% processing-cost variation
    seed: int = 0
    marker_interval: int = 64


class _OpState:
    def __init__(self, op: SimOp, cfg: SimConfig):
        self.op = op
        self.cfg = cfg
        self.queue: list = []  # FIFO worklist [(serial, key)]
        self.qhead = 0
        self.next_serial = 1
        self.enqueued = 0
        # reorder buffer
        self.ro_next = 1
        self.ro_waiting: dict[int, int] = {}  # serial -> n_outputs
        self.lock_free_at = 0.0  # lock-based: time the op lock frees
        self.flag_busy = False  # non-blocking: drain flag
        # partitioned state
        self.part_queues: dict[int, list] = {}
        self.part_active: dict[int, bool] = {}
        self.part_delegated: dict[int, int] = {}
        self.part_pending = 0
        # stats for scheduler
        self.workers = 0
        self.busy_us = 0.0
        self.window_busy_us = 0.0
        self.consumed = 0
        self.produced = 0
        self.blocked_us = 0.0

    # -- worklist size
    def size(self) -> int:
        return len(self.queue) - self.qhead + getattr(self, "part_pending", 0)

    def push(self, serial: int, key) -> None:
        self.queue.append((serial, key))

    def pop(self):
        if self.qhead >= len(self.queue):
            return None
        item = self.queue[self.qhead]
        self.qhead += 1
        if self.qhead > 4096 and self.qhead * 2 > len(self.queue):
            del self.queue[: self.qhead]
            self.qhead = 0
        return item

    def max_dop(self) -> int:
        if self.op.kind == "stateful":
            return 1
        if self.op.kind == "partitioned":
            return self.op.num_partitions
        return 1 << 30

    def schedulable(self) -> bool:
        return self.workers < self.max_dop() and self.size() > 0

    def cost(self) -> float:
        return self.op.cost_us


class Simulator:
    def __init__(self, ops: list[SimOp], cfg: SimConfig):
        self.cfg = cfg
        self.ops = [_OpState(o, cfg) for o in ops]
        self.rng = random.Random(cfg.seed)
        self.now = 0.0
        self.events: list = []  # (time, seq, fn)
        self._seq = itertools.count()
        self.egress = 0
        self.ingress = 0
        self.marker_begin: dict[tuple[int, int], float] = {}
        self.latencies: list[float] = []
        self.window_start = 0.0
        self.worker_busy = [0.0] * cfg.num_workers
        self._sel_acc = [0.0] * len(ops)

    # ------------------------------------------------------------- plumbing
    def at(self, t: float, fn) -> None:
        heapq.heappush(self.events, (t, next(self._seq), fn))

    def _n_outputs(self, i: int) -> int:
        s = self.ops[i].op.selectivity
        base = int(s)
        self._sel_acc[i] += s - base
        if self._sel_acc[i] >= 1.0:
            self._sel_acc[i] -= 1.0
            base += 1
        return base

    # -------------------------------------------------------------- enqueue
    def feed(self, i: int, key=None, marker: bool = False) -> int:
        """Enqueue one tuple into op i's worklist; returns its serial."""
        st = self.ops[i]
        serial = st.next_serial
        st.next_serial += 1
        if st.op.kind == "partitioned":
            k = key if key is not None else 0
            p = k % st.op.num_partitions
            st.part_queues.setdefault(p, []).append((serial, k))
            if self.cfg.worklist_scheme == "partitioned":
                st.part_pending = getattr(st, "part_pending", 0) + 1
            else:
                st.push(serial, ("__master__", p))
        else:
            st.push(serial, key)
        return serial

    # ------------------------------------------------------------ scheduler
    def _cum_sel(self) -> list[float]:
        cs, acc = [], 1.0
        for st in self.ops:
            acc *= max(st.op.selectivity, 1e-9)
            cs.append(acc)
        return cs

    def pick_op(self) -> Optional[int]:
        cand = [i for i, st in enumerate(self.ops) if st.schedulable()]
        if not cand:
            return None
        h = self.cfg.heuristic
        if h == "lp":
            return cand[-1]
        if h == "qst":
            cs = self._cum_sel()
            total = sum(cs)
            for i in cand:
                if i + 1 >= len(self.ops):
                    return i
                thr = max(self.cfg.qst_capacity * cs[i] / total, 1.0)
                if self.ops[i + 1].size() < thr:
                    return i
            return cand[0]
        if h == "et":
            return max(
                cand,
                key=lambda i: self.ops[i].size()
                * self.ops[i].cost()
                / (self.ops[i].workers + 1),
            )
        # ct
        if self.now - self.window_start > self.cfg.ct_window_us:
            for st in self.ops:
                st.window_busy_us = 0.0
            self.window_start = self.now
        cs = self._cum_sel()
        return min(
            cand,
            key=lambda i: (
                self.ops[i].window_busy_us
                + self.ops[i].workers * self.cfg.time_slice_us
            )
            / (self.ops[i].cost() * cs[i]),
        )

    # --------------------------------------------------------------- worker
    def worker_ask(self, w: int) -> None:
        i = self.pick_op()
        if i is None:
            self.at(self.now + 20.0, lambda: self.worker_ask(w))  # idle poll
            return
        st = self.ops[i]
        st.workers += 1
        budget = max(1, int(self.cfg.time_slice_us / st.cost()))
        self.work_loop(w, i, budget)

    def work_loop(self, w: int, i: int, budget: int) -> None:
        st = self.ops[i]
        if budget <= 0:
            st.workers -= 1
            self.worker_ask(w)
            return
        if st.op.kind == "partitioned" and self.cfg.worklist_scheme == "partitioned":
            # Volcano-style static ownership: worker w owns buckets p%W==w
            for p in range(w % self.cfg.num_workers, st.op.num_partitions, self.cfg.num_workers):
                q = st.part_queues.get(p)
                if q:
                    tup = q.pop(0)
                    st.part_pending -= 1
                    self.process(w, i, tup[0], p, budget)
                    return
            # own buckets empty (others may not be): idle-poll, NOT recurse
            st.workers -= 1
            self.at(self.now + 20.0, lambda: self.worker_ask(w))
            return
        item = st.pop()
        if item is None:
            st.workers -= 1
            self.worker_ask(w)
            return
        serial, key = item
        if st.op.kind == "partitioned":
            _tag, p = key
            # hybrid queue (fig. 7): delegation instead of blocking
            if st.part_active.get(p):
                st.part_delegated[p] = st.part_delegated.get(p, 0) + 1
                self.at(self.now + 0.05, lambda: self.work_loop(w, i, budget))
                return
            st.part_active[p] = True
            tup = st.part_queues[p].pop(0)
            self.process(w, i, tup[0], p, budget)
        else:
            self.process(w, i, serial, None, budget)

    def process(self, w: int, i: int, serial: int, p, budget: int, extra=None) -> None:
        st = self.ops[i]
        cost = st.cost()
        if self.cfg.jitter:
            cost *= 1.0 + self.cfg.jitter * (self.rng.random() - 0.5)
        if (i, serial) not in self.marker_begin and serial % self.cfg.marker_interval == 0 and i == 0:
            self.marker_begin[(0, serial)] = self.now
        done = self.now + cost
        self.worker_busy[w] += cost
        st.busy_us += cost
        st.window_busy_us += cost
        st.consumed += 1
        self.at(done, lambda: self.finish(w, i, serial, p, budget))

    def finish(self, w: int, i: int, serial: int, p, budget: int) -> None:
        st = self.ops[i]
        n_out = self._n_outputs(i)
        st.produced += n_out
        if st.op.kind == "stateful":
            self.emit(i, serial, n_out)
            self.after_send(w, i, serial, p, budget, 0.0)
            return
        # reorder buffer
        if self.cfg.reorder_scheme == "lock_based":
            start = max(self.now, st.lock_free_at)
            blocked = start - self.now
            st.blocked_us += blocked
            self.worker_busy[w] += blocked
            st.ro_waiting[serial] = n_out
            drained = self._drain(i)
            hold = self.cfg.add_us + drained * self.cfg.send_us
            st.lock_free_at = start + hold
            self.worker_busy[w] += hold
            st.busy_us += hold + blocked
            st.window_busy_us += hold + blocked
            self.after_send(w, i, serial, p, budget, blocked + hold)
        else:
            st.ro_waiting[serial] = n_out
            extra = self.cfg.add_us
            if not st.flag_busy:
                st.flag_busy = True
                drained = self._drain(i)
                extra += drained * self.cfg.send_us
                st.flag_busy = False
            self.worker_busy[w] += extra
            st.busy_us += extra
            st.window_busy_us += extra
            self.after_send(w, i, serial, p, budget, extra)

    def _drain(self, i: int) -> int:
        """Send the contiguous ready prefix downstream; returns #outputs."""
        st = self.ops[i]
        drained = 0
        while st.ro_next in st.ro_waiting:
            n_out = st.ro_waiting.pop(st.ro_next)
            self.emit(i, st.ro_next, n_out)
            st.ro_next += 1
            drained += n_out
        return drained

    def emit(self, i: int, serial: int, n_out: int) -> None:
        begin = self.marker_begin.pop((i, serial), None)
        if i + 1 < len(self.ops):
            nxt = self.ops[i + 1]
            for _ in range(n_out):
                s2 = self.feed(i + 1, key=self.rng.randrange(1 << 30))
                if begin is not None:
                    self.marker_begin[(i + 1, s2)] = begin
                    begin = None
            if begin is not None and n_out == 0:
                self.latencies.append(self.now - begin)
        else:
            self.egress += n_out
            if begin is not None:
                self.latencies.append(self.now - begin)

    def after_send(self, w: int, i: int, serial: int, p, budget: int, delay: float) -> None:
        st = self.ops[i]

        def cont():
            if st.op.kind == "partitioned" and self.cfg.worklist_scheme == "hybrid":
                # drain delegated tuples for partition p before releasing it
                if st.part_delegated.get(p, 0) > 0:
                    st.part_delegated[p] -= 1
                    tup = st.part_queues[p].pop(0)
                    self.process(w, i, tup[0], p, budget - 1)
                    return
                st.part_active[p] = False
            self.work_loop(w, i, budget - 1)

        self.at(self.now + delay, cont)

    # ------------------------------------------------------------------ run
    def run(
        self,
        n_tuples: int,
        key_sampler: Optional[Callable[[random.Random], int]] = None,
        arrival_rate_us: float = 0.0,
    ) -> dict:
        """Feed n_tuples into op 0 (all at t=0, or at a fixed rate), run to
        completion, return metrics."""
        if arrival_rate_us <= 0:
            for t in range(n_tuples):
                k = key_sampler(self.rng) if key_sampler else t
                self.feed(0, key=k)
            self.ingress = n_tuples
        else:
            def arrive(t_idx=0):
                if t_idx >= n_tuples:
                    return
                k = key_sampler(self.rng) if key_sampler else t_idx
                self.feed(0, key=k)
                self.ingress += 1
                self.at(self.now + arrival_rate_us, lambda: arrive(t_idx + 1))
            self.at(0.0, arrive)

        for w in range(self.cfg.num_workers):
            self.at(0.0, lambda w=w: self.worker_ask(w))

        idle_polls = 0
        while self.events:
            t, _, fn = heapq.heappop(self.events)
            self.now = t
            before = len(self.events)
            fn()
            # termination: only idle polls remain and all queues empty
            if all(st.size() == 0 and st.workers == 0 for st in self.ops):
                remaining_real = [
                    e for e in self.events if e[0] > self.now + 1e9
                ]
                drained = all(
                    not st.ro_waiting and not any(st.part_delegated.values())
                    for st in self.ops
                )
                if drained:
                    break

        makespan = self.now
        lats = sorted(self.latencies)
        lo, hi = int(len(lats) * 0.2), max(int(len(lats) * 0.8), 1)
        mid = lats[lo:hi] or lats or [0.0]
        return {
            "makespan_us": makespan,
            "throughput_per_s": self.ingress / makespan * 1e6 if makespan else 0.0,
            "mean_latency_us": sum(mid) / len(mid),
            "p99_latency_us": lats[int(0.99 * (len(lats) - 1))] if lats else 0.0,
            "worker_busy_frac": (
                sum(self.worker_busy) / (self.cfg.num_workers * makespan)
                if makespan
                else 0.0
            ),
            "blocked_us": sum(st.blocked_us for st in self.ops),
            "egress": self.egress,
        }


def simulate(
    ops: list[SimOp],
    n_tuples: int,
    cfg: Optional[SimConfig] = None,
    key_sampler=None,
    **cfg_kw,
) -> dict:
    cfg = cfg or SimConfig(**cfg_kw)
    return Simulator(ops, cfg).run(n_tuples, key_sampler=key_sampler)
