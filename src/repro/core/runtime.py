"""Threaded stream runtime (paper §2.2): worker threads + central scheduler.

Workers loop: query scheduler -> work a time slice on the chosen operator ->
update stats -> repeat. Ingress can be driven externally (``pipeline.push``)
or by a source callable pumping tuples at a target rate.

With ``heuristic="adaptive"`` the runtime additionally starts an adaptive
controller thread that periodically calls :meth:`Scheduler.adapt` — it
re-estimates per-operator cost/selectivity from live stats and resizes each
node's effective parallelism cap M_i to its load share, dynamically mapping
the computation's exposed parallelism onto the machine's (paper §2/§6).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

from .costmodel import resolve_workers
from .pipeline import CompiledPipeline, GraphPipeline
from .scheduler import Scheduler


@dataclass
class RunReport:
    tuples_in: int
    tuples_out: int
    wall_time: float
    throughput: float  # ingress tuples fully processed per second
    mean_latency: float  # mean processing latency of 20-80pct markers (s)
    p99_latency: float
    worker_busy_frac: float
    # Egress tuples over the active processing window (first push -> last
    # egress).  ``throughput`` divides ingress count by *total* wall time,
    # which understates the sustained rate when drain dominates short runs.
    egress_throughput: float = 0.0

    def __str__(self):
        return (
            f"in={self.tuples_in} out={self.tuples_out} wall={self.wall_time:.3f}s "
            f"thru={self.throughput:,.0f}/s egress={self.egress_throughput:,.0f}/s "
            f"lat(mean)={self.mean_latency*1e3:.3f}ms "
            f"lat(p99)={self.p99_latency*1e3:.3f}ms busy={self.worker_busy_frac:.2f}"
        )


class StreamRuntime:
    """Threaded execution backend: ``num_workers`` worker threads pulling
    (operator, budget) assignments from a central :class:`~.scheduler
    .Scheduler` to drive a compiled :class:`~.pipeline.GraphPipeline`;
    ``heuristic="adaptive"`` adds the controller thread that periodically
    remaps per-operator parallelism caps (paper §2.2/§6)."""

    def __init__(
        self,
        pipeline: GraphPipeline,
        num_workers=4,  # int, or "auto" for one worker per core
        heuristic: str = "ct",
        **sched_kw,
    ):
        num_workers = resolve_workers(num_workers)
        self.pipeline = pipeline
        self.num_workers = num_workers
        sched_kw.setdefault("edges", getattr(pipeline, "sched_edges", None))
        self.scheduler = Scheduler(
            pipeline.nodes, heuristic, num_workers=num_workers, **sched_kw
        )
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._controller: Optional[threading.Thread] = None
        # lock-free: per-worker slot; only worker w writes _busy[w]
        self._busy = [0.0] * num_workers
        # First operator-fn exception seen by any worker.  A raising op kills
        # its worker thread and strands the in-flight tuple, so the pipeline
        # can never drain; recording it lets run()/Session raise a clear
        # error instead of hanging until the drain deadline.
        # lock-free: single racing store per worker; last-exception-wins is acceptable (any recorded error aborts the run)
        self.worker_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ workers
    _IDLE_MIN = 1e-5  # first miss: 10 µs
    _IDLE_MAX = 1e-3  # backoff cap / park interval: 1 ms

    def _worker_loop(self, wid: int) -> None:
        idle = self._IDLE_MIN
        while not self._stop.is_set():
            assignment = self.scheduler.acquire()
            if assignment is None:
                if self.scheduler.idle_hint():
                    # graph drained: park at the cap instead of spinning up
                    time.sleep(self._IDLE_MAX)
                else:
                    time.sleep(idle)
                    idle = min(idle * 2, self._IDLE_MAX)
                continue
            idle = self._IDLE_MIN
            node, budget = assignment
            t0 = time.perf_counter()
            try:
                node.work(wid, budget)
            except BaseException as exc:  # noqa: BLE001 — recorded, not lost
                self.worker_error = exc
                return  # this worker is done; drivers observe worker_error
            finally:
                self.scheduler.release(node)
                self._busy[wid] += time.perf_counter() - t0

    def _controller_loop(self) -> None:
        """Adaptive controller (heuristic="adaptive"): periodically re-estimate
        operator cost/selectivity and resize per-node parallelism caps."""
        while not self._stop.is_set():
            self.scheduler.adapt()
            self._stop.wait(self.scheduler.adapt_interval)

    def start(self) -> None:
        """Start the worker threads (and the adaptive controller, if any)."""
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._worker_loop, args=(w,), daemon=True)
            for w in range(self.num_workers)
        ]
        for t in self._threads:
            t.start()
        if self.scheduler.heuristic == "adaptive":
            self._controller = threading.Thread(
                target=self._controller_loop, daemon=True
            )
            self._controller.start()

    def stop(self) -> None:
        """Signal and join every worker thread (idempotent)."""
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        if self._controller is not None:
            self._controller.join(timeout=5.0)
            self._controller = None

    # ------------------------------------------------------------------ drive
    def run(
        self,
        source: Iterable,
        *,
        drain: bool = True,
        drain_timeout: float = 60.0,
    ) -> RunReport:
        """Pump every tuple from ``source`` through the pipeline and report."""
        n_in = 0
        t0 = time.perf_counter()
        self.start()
        try:
            for value in source:
                self.pipeline.push(value)
                n_in += 1
            self.pipeline.flush()  # release any partial ingress micro-batch
            if drain:
                deadline = time.perf_counter() + drain_timeout
                while not self.pipeline.drained():
                    if self.worker_error is not None:
                        raise RuntimeError(
                            f"worker failed: {self.worker_error!r}"
                        ) from self.worker_error
                    if time.perf_counter() > deadline:
                        raise TimeoutError("pipeline failed to drain")
                    time.sleep(1e-4)
        finally:
            self.stop()
        return self.make_report(n_in, time.perf_counter() - t0)

    def make_report(self, n_in: int, wall: float) -> RunReport:
        """Summarize a finished (stopped, drained) run over ``wall`` seconds
        and ``n_in`` ingress tuples.  Factored out of :meth:`run` so the
        streaming :class:`~.api.Session` surface can report on a
        push-driven window with the exact same conventions."""
        lats = self.pipeline.processing_latencies()
        lats_sorted = sorted(lats)
        mean_lat = sum(lats) / len(lats) if lats else 0.0
        p99 = lats_sorted[int(0.99 * (len(lats_sorted) - 1))] if lats_sorted else 0.0
        busy = sum(self._busy) / (self.num_workers * wall) if wall > 0 else 0.0
        n_out = self.pipeline.egress_count
        window = self.pipeline.processing_window() or wall
        # A 0/1-tuple egress has no meaningful first-push→last-egress window
        # (it would divide by ~0 and report an absurd rate): report 0.0.
        return RunReport(
            tuples_in=n_in,
            tuples_out=n_out,
            wall_time=wall,
            throughput=n_in / wall if wall > 0 else 0.0,
            egress_throughput=n_out / window if (window > 0 and n_out > 1) else 0.0,
            mean_latency=mean_lat,
            p99_latency=p99,
            worker_busy_frac=busy,
        )


def _deprecated_one_shot(name: str) -> None:
    import warnings

    warnings.warn(
        f"{name}() is deprecated; use repro.core.Engine — "
        "engine = Engine(EngineConfig(...)); plan = engine.plan(...); "
        "engine.run(plan, source) (or engine.open(plan) for streaming)",
        DeprecationWarning,
        stacklevel=3,
    )


def run_pipeline(specs, source: Iterable, **kw):
    """Deprecated one-shot: compile an operator chain, run to drain, report.

    Thin shim over the :class:`~.api.Engine` path — ``kw`` is parsed by
    :meth:`~.api.EngineConfig.from_kwargs` (unknown or conflicting options
    raise :class:`~.api.ConfigError` instead of being silently swallowed)
    and the run goes through ``Engine.run``.  Returns ``(handle, report)``
    where ``handle`` is a :class:`~.api.JobResult`-backed proxy exposing the
    documented result surface (``outputs``, ``egress_count``, ``markers``)
    identically for both backends, plus pass-through access to the
    underlying executed pipeline/runtime.  New code should call
    :class:`~.api.Engine` directly (``engine.plan`` → ``engine.run`` /
    ``engine.open``).
    """
    from .api import Engine, EngineConfig

    _deprecated_one_shot("run_pipeline")
    engine = Engine(EngineConfig.from_kwargs(**kw))
    result = engine.run(list(specs), source)
    return result.handle(), result.report


def run_graph(nodes, edges, source: Iterable, **kw):
    """Deprecated one-shot for DAG pipelines: compile, run to drain, report.

    Thin shim over the :class:`~.api.Engine` path (see :func:`run_pipeline`
    for the shim contract); ``backend="process"`` cuts the graph's linear
    prefix into process stages exactly as before, and routing nodes left in
    the parent tail still emit :class:`~.procrun.UnstagedGraphWarning`.
    """
    from .api import Engine, EngineConfig

    _deprecated_one_shot("run_graph")
    engine = Engine(EngineConfig.from_kwargs(**kw))
    result = engine.run((dict(nodes), list(edges)), source)
    return result.handle(), result.report
