"""Threaded stream runtime (paper §2.2): worker threads + central scheduler.

Workers loop: query scheduler -> work a time slice on the chosen operator ->
update stats -> repeat. Ingress can be driven externally (``pipeline.push``)
or by a source callable pumping tuples at a target rate.

With ``heuristic="adaptive"`` the runtime additionally starts an adaptive
controller thread that periodically calls :meth:`Scheduler.adapt` — it
re-estimates per-operator cost/selectivity from live stats and resizes each
node's effective parallelism cap M_i to its load share, dynamically mapping
the computation's exposed parallelism onto the machine's (paper §2/§6).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

from .costmodel import resolve_workers
from .pipeline import CompiledPipeline, GraphPipeline
from .scheduler import Scheduler


@dataclass
class RunReport:
    tuples_in: int
    tuples_out: int
    wall_time: float
    throughput: float  # ingress tuples fully processed per second
    mean_latency: float  # mean processing latency of 20-80pct markers (s)
    p99_latency: float
    worker_busy_frac: float
    # Egress tuples over the active processing window (first push -> last
    # egress).  ``throughput`` divides ingress count by *total* wall time,
    # which understates the sustained rate when drain dominates short runs.
    egress_throughput: float = 0.0

    def __str__(self):
        return (
            f"in={self.tuples_in} out={self.tuples_out} wall={self.wall_time:.3f}s "
            f"thru={self.throughput:,.0f}/s egress={self.egress_throughput:,.0f}/s "
            f"lat(mean)={self.mean_latency*1e3:.3f}ms "
            f"lat(p99)={self.p99_latency*1e3:.3f}ms busy={self.worker_busy_frac:.2f}"
        )


class StreamRuntime:
    def __init__(
        self,
        pipeline: GraphPipeline,
        num_workers=4,  # int, or "auto" for one worker per core
        heuristic: str = "ct",
        **sched_kw,
    ):
        num_workers = resolve_workers(num_workers)
        self.pipeline = pipeline
        self.num_workers = num_workers
        sched_kw.setdefault("edges", getattr(pipeline, "sched_edges", None))
        self.scheduler = Scheduler(
            pipeline.nodes, heuristic, num_workers=num_workers, **sched_kw
        )
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._controller: Optional[threading.Thread] = None
        self._busy = [0.0] * num_workers

    # ------------------------------------------------------------------ workers
    _IDLE_MIN = 1e-5  # first miss: 10 µs
    _IDLE_MAX = 1e-3  # backoff cap / park interval: 1 ms

    def _worker_loop(self, wid: int) -> None:
        idle = self._IDLE_MIN
        while not self._stop.is_set():
            assignment = self.scheduler.acquire()
            if assignment is None:
                if self.scheduler.idle_hint():
                    # graph drained: park at the cap instead of spinning up
                    time.sleep(self._IDLE_MAX)
                else:
                    time.sleep(idle)
                    idle = min(idle * 2, self._IDLE_MAX)
                continue
            idle = self._IDLE_MIN
            node, budget = assignment
            t0 = time.perf_counter()
            try:
                node.work(wid, budget)
            finally:
                self.scheduler.release(node)
            self._busy[wid] += time.perf_counter() - t0

    def _controller_loop(self) -> None:
        """Adaptive controller (heuristic="adaptive"): periodically re-estimate
        operator cost/selectivity and resize per-node parallelism caps."""
        while not self._stop.is_set():
            self.scheduler.adapt()
            self._stop.wait(self.scheduler.adapt_interval)

    def start(self) -> None:
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._worker_loop, args=(w,), daemon=True)
            for w in range(self.num_workers)
        ]
        for t in self._threads:
            t.start()
        if self.scheduler.heuristic == "adaptive":
            self._controller = threading.Thread(
                target=self._controller_loop, daemon=True
            )
            self._controller.start()

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        if self._controller is not None:
            self._controller.join(timeout=5.0)
            self._controller = None

    # ------------------------------------------------------------------ drive
    def run(
        self,
        source: Iterable,
        *,
        drain: bool = True,
        drain_timeout: float = 60.0,
    ) -> RunReport:
        """Pump every tuple from ``source`` through the pipeline and report."""
        n_in = 0
        t0 = time.perf_counter()
        self.start()
        try:
            for value in source:
                self.pipeline.push(value)
                n_in += 1
            self.pipeline.flush()  # release any partial ingress micro-batch
            if drain:
                deadline = time.perf_counter() + drain_timeout
                while not self.pipeline.drained():
                    if time.perf_counter() > deadline:
                        raise TimeoutError("pipeline failed to drain")
                    time.sleep(1e-4)
        finally:
            self.stop()
        wall = time.perf_counter() - t0
        lats = self.pipeline.processing_latencies()
        lats_sorted = sorted(lats)
        mean_lat = sum(lats) / len(lats) if lats else 0.0
        p99 = lats_sorted[int(0.99 * (len(lats_sorted) - 1))] if lats_sorted else 0.0
        busy = sum(self._busy) / (self.num_workers * wall) if wall > 0 else 0.0
        n_out = self.pipeline.egress_count
        window = self.pipeline.processing_window() or wall
        # A 0/1-tuple egress has no meaningful first-push→last-egress window
        # (it would divide by ~0 and report an absurd rate): report 0.0.
        return RunReport(
            tuples_in=n_in,
            tuples_out=n_out,
            wall_time=wall,
            throughput=n_in / wall if wall > 0 else 0.0,
            egress_throughput=n_out / window if (window > 0 and n_out > 1) else 0.0,
            mean_latency=mean_lat,
            p99_latency=p99,
            worker_busy_frac=busy,
        )


def run_pipeline(
    specs,
    source: Iterable,
    *,
    num_workers=4,  # int, or "auto" for cost-model-driven allocation
    heuristic: str = "ct",
    reorder_scheme: str = "non_blocking",
    worklist_scheme: str = "hybrid",
    collect_outputs: bool = False,
    marker_interval: int = 64,
    backend: str = "thread",
    batch_size: int = 1,
    reorder_size: int = 1024,
    cost_priors=None,  # {op name: cost_us} overriding declared priors
    **kw,
) -> tuple[CompiledPipeline, RunReport]:
    """Convenience one-shot: compile, run to drain, report.

    ``backend="process"`` runs the chain on :class:`~.procrun.ProcessRuntime`
    (staged OS-process worker groups + shared-memory exchange rings; same
    ordered semantics).  The returned "pipeline" is then the runtime itself,
    which exposes the same result surface (``outputs``, ``egress_count``,
    ``markers``).  ``batch_size > 1`` enables the threaded path's
    micro-batched tuple flow and doubles as the process backend's dispatch
    unit size (``io_batch``) when the latter is not given.

    ``num_workers="auto"`` sizes parallelism from the cost model
    (:mod:`.costmodel`): the process backend divides a ``worker_budget``
    (default cores + 1, via ``**kw``) across its stages in proportion to
    predicted load — from ``cost_priors`` or a short calibration pass — and
    elastically replans live when observed occupancy drifts; the thread
    backend resolves it to one worker per core and feeds ``cost_priors`` to
    the scheduler.  Process-only knobs ride ``**kw``: ``stages`` (max process
    stages; ``1`` = ingress-only plan), ``io_batch``, ``max_inflight``,
    ``worker_budget``, ``elastic``, ``replan_interval``, ring geometry.
    """
    if backend == "process":
        from .procrun import _chain_nodes

        return run_graph(
            *_chain_nodes(list(specs)),
            source,
            num_workers=num_workers,
            heuristic=heuristic,
            reorder_scheme=reorder_scheme,
            worklist_scheme=worklist_scheme,
            collect_outputs=collect_outputs,
            marker_interval=marker_interval,
            backend=backend,
            batch_size=batch_size,
            reorder_size=reorder_size,
            cost_priors=cost_priors,
            **kw,
        )
    if backend != "thread":
        raise ValueError(f"unknown backend {backend!r} (thread | process)")
    num_workers = resolve_workers(num_workers)
    pipe = CompiledPipeline(
        specs,
        reorder_scheme=reorder_scheme,
        worklist_scheme=worklist_scheme,
        num_workers=num_workers,
        collect_outputs=collect_outputs,
        marker_interval=marker_interval,
        batch_size=batch_size,
        reorder_size=reorder_size,
    )
    rt = StreamRuntime(
        pipe, num_workers=num_workers, heuristic=heuristic,
        cost_priors=cost_priors, **kw,
    )
    report = rt.run(source)
    return pipe, report


def run_graph(
    nodes,
    edges,
    source: Iterable,
    *,
    num_workers=4,  # int, or "auto" for cost-model-driven allocation
    heuristic: str = "ct",
    reorder_scheme: str = "non_blocking",
    worklist_scheme: str = "hybrid",
    collect_outputs: bool = False,
    marker_interval: int = 64,
    backend: str = "thread",
    batch_size: int = 1,
    reorder_size: int = 1024,
    cost_priors=None,  # {op name: cost_us} overriding declared priors
    **kw,
) -> tuple[GraphPipeline, RunReport]:
    """Convenience one-shot for DAG pipelines: compile, run to drain, report.

    ``backend="process"`` cuts the graph's linear prefix into process stages
    at partitioned/stateful boundaries (shared-memory exchange edges between
    worker groups) and executes any uncuttable remainder in the parent in
    serial order (see :mod:`.procrun`; a :class:`~.procrun.UnstagedGraphWarning`
    is emitted when routing nodes land in that tail); semantics are
    unchanged.  ``stages=1`` (via ``**kw``) restores the ingress-only plan;
    ``num_workers="auto"`` enables cost-model worker allocation + elastic
    replanning (see :func:`run_pipeline`).
    """
    if backend == "process":
        from .procrun import ProcessRuntime

        rt = ProcessRuntime(
            nodes,
            edges,
            num_workers=num_workers,
            collect_outputs=collect_outputs,
            marker_interval=marker_interval,
            batch_size=batch_size,
            reorder_scheme=reorder_scheme,
            worklist_scheme=worklist_scheme,
            reorder_size=reorder_size,
            cost_priors=cost_priors,
            **kw,
        )
        report = rt.run(source)
        return rt, report
    if backend != "thread":
        raise ValueError(f"unknown backend {backend!r} (thread | process)")
    num_workers = resolve_workers(num_workers)
    pipe = GraphPipeline(
        nodes,
        edges,
        reorder_scheme=reorder_scheme,
        worklist_scheme=worklist_scheme,
        num_workers=num_workers,
        collect_outputs=collect_outputs,
        marker_interval=marker_interval,
        batch_size=batch_size,
        reorder_size=reorder_size,
    )
    rt = StreamRuntime(
        pipe, num_workers=num_workers, heuristic=heuristic,
        cost_priors=cost_priors, **kw,
    )
    report = rt.run(source)
    return pipe, report
