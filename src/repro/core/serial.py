"""Atomic primitives and serial-number assignment (paper §3).

Python cannot express lock-free CAS loops, but under the GIL a small lock-guarded
counter has the same linearizable semantics as the paper's ``atomic_long``; the
try-lock flag is expressed with ``Lock.acquire(blocking=False)`` which *is*
test_and_set. These are the only primitives the paper's data structures need.
"""
from __future__ import annotations

import threading


class AtomicLong:
    """Linearizable counter with load / fetch_add / fetch_sub."""

    __slots__ = ("_value", "_lock")

    def __init__(self, value: int = 0):
        self._value = value
        self._lock = threading.Lock()

    def load(self) -> int:
        """Read the current value (linearizable)."""
        # int reads are atomic under the GIL; take the lock anyway so the
        # semantics do not depend on CPython implementation details.
        with self._lock:
            return self._value

    def fetch_add(self, delta: int = 1) -> int:
        """Atomically add ``delta``; returns the PREVIOUS value."""
        with self._lock:
            old = self._value
            self._value += delta
            return old

    def fetch_sub(self, delta: int = 1) -> int:
        """Atomically subtract ``delta``; returns the PREVIOUS value."""
        return self.fetch_add(-delta)

    def store(self, value: int) -> None:
        """Atomically overwrite the value."""
        with self._lock:
            self._value = value

    def exchange(self, value: int) -> int:
        """Atomically set to ``value`` and return the previous value."""
        with self._lock:
            old = self._value
            self._value = value
            return old


class AtomicFlag:
    """test_and_set / clear, as used by the non-blocking reorder buffer."""

    __slots__ = ("_lock",)

    def __init__(self):
        self._lock = threading.Lock()

    def test_and_set(self) -> bool:
        """Returns True if the flag was ALREADY set (i.e. acquisition failed),
        mirroring C++ ``atomic_flag::test_and_set`` semantics."""
        return not self._lock.acquire(blocking=False)

    def clear(self) -> None:
        """Release the flag so the next ``test_and_set`` succeeds."""
        self._lock.release()


class SerialAssigner:
    """Monotone serial numbers starting at 1 (paper: 'starting from 1')."""

    __slots__ = ("_counter",)

    def __init__(self, start: int = 1):
        self._counter = AtomicLong(start)

    def next(self) -> int:
        """Claim and return the next serial number."""
        return self._counter.fetch_add(1)

    def peek(self) -> int:
        """The serial the next :meth:`next` call would return (no claim)."""
        return self._counter.load()
