"""Shared-memory transport for the process-parallel backend (paper §3, across
address spaces).

Two lock-free structures layered on ``multiprocessing.shared_memory``:

- :class:`ShmSpscRing` — bounded single-producer/single-consumer ring of
  fixed-width slots carrying ``(serial, tag, payload)`` records.  Large
  payloads span consecutive slots (the producer publishes the whole span with
  one tail advance, so the consumer never observes a partial record).  The
  head (consumer cursor) and tail (producer cursor) are each written by
  exactly one process, so no cross-process atomic RMW is needed — the only
  primitive required is an aligned 8-byte store, which a single ``memcpy``
  into the mapping provides.

- :class:`ShmReorderRing` — the cross-process mirror of
  :class:`~.reorder.NonBlockingReorderBuffer` (paper fig. 4): a bounded ring
  indexed by ``serial mod size`` with a shared ``next`` counter.  Any worker
  process may publish a slot (each serial is owned by exactly one worker);
  the single drainer (the parent) consumes the contiguous ready prefix and
  is the only writer of ``next``.  A slot is published by storing its
  sequence number *last*, so a crashed worker can never expose a torn
  payload — the slot simply stays unpublished and the serial is replayed.

Payload codec: fixed-width slots want fixed-width encodings, so ints and
floats travel as raw 8-byte values; everything else falls back to pickle
(the slow path).  Reorder-ring bundles whose pickle exceeds the slot payload
are diverted to a per-worker pipe and the slot carries only a spill tag,
keeping the ring itself fixed-width.
"""
from __future__ import annotations

import pickle
import struct
from multiprocessing import shared_memory
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------- value codec
TAG_INT = 0  # 8-byte signed little-endian
TAG_FLOAT = 1  # 8-byte IEEE double
TAG_PICKLE = 2  # pickle bytes (slow path)
TAG_EMPTY = 3  # empty output bundle (hole-punch: serial completed, 0 tuples)
TAG_ONE_INT = 4  # bundle of exactly one int
TAG_ONE_FLOAT = 5  # bundle of exactly one float
TAG_SPILL = 6  # bundle too large for the slot; body travels via pipe

_I8 = struct.Struct("<q")
_F8 = struct.Struct("<d")


def encode_value(obj: Any) -> Tuple[int, bytes]:
    """Encode one tuple value for an ingress ring slot."""
    if type(obj) is int and -(1 << 63) <= obj < (1 << 63):
        return TAG_INT, _I8.pack(obj)
    if type(obj) is float:
        return TAG_FLOAT, _F8.pack(obj)
    return TAG_PICKLE, pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


def decode_value(tag: int, data: bytes) -> Any:
    if tag == TAG_INT:
        return _I8.unpack(data)[0]
    if tag == TAG_FLOAT:
        return _F8.unpack(data)[0]
    return pickle.loads(data)


def encode_bundle(outs: list) -> Tuple[int, bytes]:
    """Encode a flat-map result bundle (list of outputs) for a reorder slot."""
    if not outs:
        return TAG_EMPTY, b""
    if len(outs) == 1:
        v = outs[0]
        if type(v) is int and -(1 << 63) <= v < (1 << 63):
            return TAG_ONE_INT, _I8.pack(v)
        if type(v) is float:
            return TAG_ONE_FLOAT, _F8.pack(v)
    return TAG_PICKLE, pickle.dumps(outs, protocol=pickle.HIGHEST_PROTOCOL)


def decode_bundle(tag: int, data: bytes) -> list:
    if tag == TAG_EMPTY:
        return []
    if tag == TAG_ONE_INT:
        return [_I8.unpack(data)[0]]
    if tag == TAG_ONE_FLOAT:
        return [_F8.unpack(data)[0]]
    return pickle.loads(data)


def _align(n: int, a: int = 64) -> int:
    return (n + a - 1) // a * a


# ------------------------------------------------------------------ SPSC ring
class ShmSpscRing:
    """Bounded SPSC ring of fixed-width slots over a shared-memory segment.

    Record layout (first slot of a span):
      [total_len:4][tag:1][serial:8][payload...]
    continuation slots carry raw payload bytes.  ``tail``/``head`` count
    *slots*; a record occupies ``ceil((13+len)/slot_bytes)`` slots and is
    published by a single tail store after every byte is written.
    """

    _HDR = 64  # tail:8 @0 (producer-owned), head:8 @8 (consumer-owned),
    # closed:8 @16 (producer-owned)
    _REC = struct.Struct("<IBq")  # total_len, tag, serial

    def __init__(self, name_prefix: str, slots: int = 4096, slot_bytes: int = 512):
        if slots < 4:
            raise ValueError("ring needs >= 4 slots")
        self.slots = slots
        self.slot_bytes = _align(slot_bytes)
        size = self._HDR + self.slots * self.slot_bytes
        self._shm = shared_memory.SharedMemory(
            create=True, size=size, name=f"{name_prefix}_spsc"
        )
        self._buf = self._shm.buf
        self._buf[: self._HDR] = bytes(self._HDR)
        self._tail = 0  # producer-side mirror
        self._head = 0  # consumer-side mirror
        self.name = self._shm.name

    # max payload bytes of a single record
    @property
    def capacity_bytes(self) -> int:
        return (self.slots - 1) * self.slot_bytes - self._REC.size

    # -- counters (aligned 8-byte single-writer stores) ---------------------
    def _load(self, off: int) -> int:
        return _I8.unpack_from(self._buf, off)[0]

    def _store(self, off: int, v: int) -> None:
        _I8.pack_into(self._buf, off, v)

    # -- producer -----------------------------------------------------------
    def put(self, serial: int, tag: int, data: bytes) -> bool:
        """Append one record; returns False if the ring lacks space."""
        total = self._REC.size + len(data)
        nslots = max(1, -(-total // self.slot_bytes))
        if nslots >= self.slots:
            raise ValueError(
                f"record of {len(data)}B exceeds ring capacity "
                f"({self.capacity_bytes}B); raise slot_bytes/slots"
            )
        head = self._load(8)
        if self._tail - head + nslots > self.slots:
            return False
        first = (self._tail % self.slots) * self.slot_bytes + self._HDR
        self._REC.pack_into(self._buf, first, len(data), tag, serial)
        wrote = min(len(data), self.slot_bytes - self._REC.size)
        self._buf[first + self._REC.size : first + self._REC.size + wrote] = (
            data[:wrote]
        )
        pos = wrote
        for k in range(1, nslots):
            off = ((self._tail + k) % self.slots) * self.slot_bytes + self._HDR
            chunk = data[pos : pos + self.slot_bytes]
            self._buf[off : off + len(chunk)] = chunk
            pos += len(chunk)
        self._tail += nslots
        self._store(0, self._tail)  # publish the whole span
        return True

    def close_ring(self) -> None:
        """Producer-side EOF: consumers drain whatever is left, then stop."""
        self._store(16, 1)

    # -- consumer -----------------------------------------------------------
    def get(self) -> Optional[Tuple[int, int, bytes]]:
        """Pop one record -> (serial, tag, payload), or None when empty."""
        tail = self._load(0)
        if self._head >= tail:
            return None
        first = (self._head % self.slots) * self.slot_bytes + self._HDR
        total, tag, serial = self._REC.unpack_from(self._buf, first)
        nslots = max(1, -(-(self._REC.size + total) // self.slot_bytes))
        take = min(total, self.slot_bytes - self._REC.size)
        data = bytes(self._buf[first + self._REC.size : first + self._REC.size + take])
        if nslots > 1:
            parts = [data]
            pos = take
            for k in range(1, nslots):
                off = ((self._head + k) % self.slots) * self.slot_bytes + self._HDR
                chunk_len = min(total - pos, self.slot_bytes)
                parts.append(bytes(self._buf[off : off + chunk_len]))
                pos += chunk_len
            data = b"".join(parts)
        self._head += nslots
        self._store(8, self._head)
        return serial, tag, data

    def closed(self) -> bool:
        return self._load(16) != 0

    def __len__(self) -> int:  # records are >=1 slot; used as emptiness hint
        return max(self._load(0) - self._load(8), 0)

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        self._buf = None
        self._shm.close()

    def unlink(self) -> None:
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass


# --------------------------------------------------------------- reorder ring
class ShmReorderRing:
    """Cross-process serial-number reorder ring (fig. 4 semantics, MPSC).

    Slot layout: [seq:8][begin:8 double][len:4][tag:1][payload...].  Workers
    publish serial ``t`` into slot ``t % size`` under the entry condition
    ``next <= t < next + size`` (``next`` read from the shared header); the
    sequence field is stored last, which is the publish.  The parent drains
    the contiguous prefix and is the sole writer of ``next``.
    """

    _HDR = 64  # next:8 @0 (drainer-owned)
    _SLOT_HDR = struct.Struct("<qdIB")  # seq, begin, len, tag

    PUBLISHED = 0
    FULL = 1
    STALE = 2  # serial already drained (replay after crash) — drop

    def __init__(self, name_prefix: str, size: int = 4096, payload_bytes: int = 512):
        self.size = size
        self.payload_bytes = payload_bytes
        self.slot_bytes = _align(self._SLOT_HDR.size + payload_bytes)
        self._shm = shared_memory.SharedMemory(
            create=True,
            size=self._HDR + size * self.slot_bytes,
            name=f"{name_prefix}_reorder",
        )
        self._buf = self._shm.buf
        self._buf[: self._HDR] = bytes(self._HDR)
        # seq fields must start != any valid serial (serials start at 1)
        for j in range(size):
            _I8.pack_into(self._buf, self._HDR + j * self.slot_bytes, 0)
        _I8.pack_into(self._buf, 0, 1)  # next = 1
        self._next = 1  # drainer-side mirror
        self.name = self._shm.name

    # -- worker side --------------------------------------------------------
    def shared_next(self) -> int:
        return _I8.unpack_from(self._buf, 0)[0]

    def try_publish(self, t: int, tag: int, data: bytes, begin: float) -> int:
        n = self.shared_next()
        if t < n:
            return self.STALE
        if t >= n + self.size:
            return self.FULL
        if len(data) > self.payload_bytes:
            raise ValueError("bundle exceeds slot payload; caller must spill")
        off = self._HDR + (t % self.size) * self.slot_bytes
        body = off + self._SLOT_HDR.size
        self._buf[body : body + len(data)] = data
        # header written in two steps so seq (the publish) is stored last
        struct.pack_into("<dIB", self._buf, off + 8, begin, len(data), tag)
        _I8.pack_into(self._buf, off, t)
        return self.PUBLISHED

    # -- drainer side -------------------------------------------------------
    def poll(self) -> Optional[Tuple[int, int, float, bytes]]:
        """Consume the next in-order slot -> (serial, tag, begin, payload)."""
        off = self._HDR + (self._next % self.size) * self.slot_bytes
        seq, begin, length, tag = self._SLOT_HDR.unpack_from(self._buf, off)
        if seq != self._next:
            return None
        body = off + self._SLOT_HDR.size
        data = bytes(self._buf[body : body + length])
        t = self._next
        self._next += 1
        _I8.pack_into(self._buf, 0, self._next)  # widen the window
        return t, tag, begin, data

    @property
    def next_serial(self) -> int:
        return self._next

    def published(self, t: int) -> bool:
        """Drainer-side: is serial ``t`` already drained or sitting published
        in its slot?  Used by crash recovery to avoid replaying serials whose
        result survived the worker — replays must have exactly one publisher,
        or a slow duplicate could clobber the slot after it is reused by
        serial ``t + size``."""
        if t < self._next:
            return True
        off = self._HDR + (t % self.size) * self.slot_bytes
        return _I8.unpack_from(self._buf, off)[0] == t

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        self._buf = None
        self._shm.close()

    def unlink(self) -> None:
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass
