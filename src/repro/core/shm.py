"""Shared-memory transport for the process-parallel backend (paper §3, across
address spaces).

Three lock-free structures layered on ``multiprocessing.shared_memory``, plus
the value codec they share.  Together they carry the staged process pipeline
(:mod:`.procrun`): every stage owns one :class:`ExchangeRing` — N per-worker
ingress rings in, one serial-number reorder ring out.

Ring wire format
----------------

- :class:`ShmSpscRing` — bounded single-producer/single-consumer ring of
  fixed-width slots.  A record's first slot is ``[total_len:4][tag:1]
  [serial:8][payload...]``; large payloads span consecutive slots
  (continuation slots are raw payload bytes) and the producer publishes the
  whole span with one tail store, so the consumer never observes a partial
  record.  The head (consumer cursor, offset 8) and tail (producer cursor,
  offset 0) are each written by exactly one process, so no cross-process
  atomic RMW is needed — the only primitive required is an aligned 8-byte
  store.  Offset 16 is the producer-owned ``closed`` flag (EOF: drain what is
  left, then stop); offset 24 is the supervisor-owned ``handoff`` flag
  (elastic resize: the exiting consumer first sends its worker-local state
  back over its pipe).  Consumption is split into :meth:`ShmSpscRing.peek` /
  :meth:`ShmSpscRing.advance` so a consumer can *read* a record, act on it,
  and only then commit the head — the basis of crash replay (below).

- :class:`ShmReorderRing` — the cross-process mirror of
  :class:`~.reorder.NonBlockingReorderBuffer` (paper fig. 4): a bounded ring
  indexed by ``serial mod size`` with a shared ``next`` counter (header
  offset 0, drainer-owned; offset 8 is a supervisor-owned ``stop`` flag that
  tells publishers/drainers to abandon ship at teardown).  Slot layout is
  ``[seq:8][len:4][span:4][tag:1][payload...]``.  Any worker
  process may publish a slot (each serial is owned by exactly one worker);
  the single drainer consumes the contiguous ready prefix and is the only
  writer of ``next``.

Serial-number protocol
----------------------

Serials are assigned by the stage's *feeder* (the parent for stage 0, an
exchange router for interior stages) in stream order, one per tuple, starting
at 1.  A micro-batch dispatched as one unit covers either a *contiguous* run
of serials (round-robin routing: the SPSC record's serial field is the span
head) or an *explicit* serial list (keyed routing interleaves serials across
workers — the per-tuple serials ride inside the payload, which is what lets
``batch_size`` and keyed stages compose).  Workers publish results back under
those same serials: one ``span``-sized slot for a contiguous unit, one
single-serial slot per tuple for a keyed unit, so the drainer's contiguous
sweep restores the exact cross-worker interleave order.  A slot is published
by storing its sequence number *last*; the publish entry condition is
``next <= t < next + size`` (``t < next`` reports ``STALE``, beyond the
window reports ``FULL`` and the worker retries).  ``TAG_EOF`` is published by
the feeder itself at ``last_serial + 1`` once every unit is dispatched — the
ring's contiguity guarantee means the drainer sees it only after every real
result, which is the staged pipeline's end-of-stream marker.

Crash / replay invariants
-------------------------

A worker *peeks* its next unit, processes it, publishes the result, and only
then advances the ring head.  Both cursor stores are single aligned 8-byte
writes and the sequence field is stored last, so a worker killed at any point
leaves every shared structure consistent: a replacement process forked onto
the same rings (after :meth:`ShmSpscRing.sync_consumer`) re-reads at most one
uncommitted unit and re-publishes it.  Duplicate publishes are safe because
segment functions are required to be deterministic — a republish either
overwrites the identical payload (serial still in window) or fails the entry
condition with ``STALE`` (already drained) and is dropped.

Payload codec: dispatch units and multi-tuple result bundles travel as
pickle; single-int/float result bundles take a raw 8-byte fast path
(``TAG_ONE_INT``/``TAG_ONE_FLOAT``) and bundles of homogeneous small
int/float tuples take a raw struct path (``TAG_TUPS`` — a 4-byte header,
per-column type codes, then 8 bytes per cell).  Columnar micro-batches
(:mod:`repro.columnar`) ride whole blocks through ``TAG_COLBLOCK`` span
slots — NumPy column vectors written directly into the ring via the same
span-publish path, with pickle reserved for the ragged marker sidecar.
Reorder-ring bundles whose encoding exceeds the slot payload are diverted
to a pipe side channel and the slot carries only a spill tag, keeping the
ring itself fixed-width.
"""
from __future__ import annotations

import pickle
import struct
from multiprocessing import shared_memory
from typing import Optional, Tuple

# ---------------------------------------------------------------- value codec
TAG_PICKLE = 2  # pickle bytes (slow path)
TAG_EMPTY = 3  # empty output bundle (hole-punch: serial completed, 0 tuples)
TAG_ONE_INT = 4  # bundle of exactly one int
TAG_ONE_FLOAT = 5  # bundle of exactly one float
TAG_SPILL = 6  # bundle too large for the slot; body travels via pipe
TAG_MBUNDLE = 7  # single-serial bundle + latency marker: pickle((outs, marker))
TAG_BUNDLES = 8  # span result: pickle((bundles, out_marks, dropped_marks))
TAG_EOF = 9  # end-of-stream marker published by the feeder at last_serial+1
TAG_UNIT = 10  # contiguous dispatch unit: pickle((values, marks)); serial=head
TAG_KUNIT = 11  # keyed dispatch unit: pickle((serials, values, marks))
TAG_KBUNDLES = 12  # batched keyed results: pickle([(serial, tag, data), ...])
# published as ONE slot at the unit's first serial; the drainer scatters the
# non-head serials into a local stash (see ShmReorderRing.poll), which is
# what keeps a keyed stage's reorder traffic per-unit instead of per-tuple
TAG_BARRIER = 13  # epoch checkpoint barrier riding an ingress ring: the
# serial field is the epoch's boundary serial B (state after every serial
# < B), the payload is the 8-byte epoch number.  Workers snapshot and ack
# over their pipe; nothing is published to the reorder ring for a barrier.
TAG_COLBLOCK = 14  # columnar micro-batch (repro.columnar wire format): a
# whole fixed-width ColumnBlock in one span slot — as a dispatch unit it
# replaces TAG_UNIT (serial = block head, span rides the record), as a
# result it replaces TAG_BUNDLES (span = block rows, one serial per row).
# The payload is decoded by repro.columnar.codec; core.shm only moves it.
TAG_TUPS = 15  # bundle of homogeneous fixed-width numeric tuples:
# [n:2][k:1][col type codes: k bytes] then n*k raw 8-byte cells row-major
# (code 0 = int64, 1 = float64) — the widened raw fast path for results
# that are small tuples of ints/floats instead of bare scalars.

_I8 = struct.Struct("<q")
_F8 = struct.Struct("<d")
_TUP_HDR = struct.Struct("<HB")  # rows:2, cols:1 (then `cols` code bytes)
_TUP_MAX_COLS = 16
_TUP_MAX_ROWS = 0xFFFF
_I64_MIN, _I64_MAX = -(1 << 63), (1 << 63) - 1


def _try_encode_tuples(outs: list) -> Optional[bytes]:
    """Raw struct encoding for a bundle of homogeneous numeric tuples, or
    None when any row breaks the shape/type contract (pickle fallback).
    Column types are fixed by the first row; bools are excluded (a bool is
    an int subclass but must round-trip as bool)."""
    first = outs[0]
    k = len(first)
    if not 1 <= k <= _TUP_MAX_COLS or len(outs) > _TUP_MAX_ROWS:
        return None
    codes = bytearray()
    for v in first:
        if type(v) is int:
            codes.append(0)
        elif type(v) is float:
            codes.append(1)
        else:
            return None
    buf = bytearray(_TUP_HDR.pack(len(outs), k))
    buf += codes
    pack_i, pack_f = _I8.pack, _F8.pack
    for row in outs:
        if type(row) is not tuple or len(row) != k:
            return None
        for code, v in zip(codes, row):
            if code == 0:
                if type(v) is not int or not _I64_MIN <= v <= _I64_MAX:
                    return None
                buf += pack_i(v)
            else:
                if type(v) is not float:
                    return None
                buf += pack_f(v)
    return bytes(buf)


def _decode_tuples(data: bytes) -> list:
    n, k = _TUP_HDR.unpack_from(data, 0)
    codes = data[_TUP_HDR.size:_TUP_HDR.size + k]
    off = _TUP_HDR.size + k
    unpack_i, unpack_f = _I8.unpack_from, _F8.unpack_from
    out = []
    for _ in range(n):
        row = []
        for code in codes:
            row.append(
                unpack_i(data, off)[0] if code == 0 else unpack_f(data, off)[0]
            )
            off += 8
        out.append(tuple(row))
    return out


def encode_bundle(outs: list) -> Tuple[int, bytes]:
    """Encode a flat-map result bundle (list of outputs) for a reorder slot."""
    if not outs:
        return TAG_EMPTY, b""
    if len(outs) == 1:
        v = outs[0]
        if type(v) is int and -(1 << 63) <= v < (1 << 63):
            return TAG_ONE_INT, _I8.pack(v)
        if type(v) is float:
            return TAG_ONE_FLOAT, _F8.pack(v)
    if type(outs[0]) is tuple:
        raw = _try_encode_tuples(outs)
        if raw is not None:
            return TAG_TUPS, raw
    return TAG_PICKLE, pickle.dumps(outs, protocol=pickle.HIGHEST_PROTOCOL)


def decode_bundle(tag: int, data: bytes) -> list:
    if tag == TAG_EMPTY:
        return []
    if tag == TAG_ONE_INT:
        return [_I8.unpack(data)[0]]
    if tag == TAG_ONE_FLOAT:
        return [_F8.unpack(data)[0]]
    if tag == TAG_TUPS:
        return _decode_tuples(data)
    return pickle.loads(data)


def _align(n: int, a: int = 64) -> int:
    return (n + a - 1) // a * a


# ------------------------------------------------------------------ SPSC ring
class ShmSpscRing:
    """Bounded SPSC ring of fixed-width slots over a shared-memory segment.

    Record layout (first slot of a span):
      [total_len:4][tag:1][serial:8][payload...]
    continuation slots carry raw payload bytes.  ``tail``/``head`` count
    *slots*; a record occupies ``ceil((13+len)/slot_bytes)`` slots and is
    published by a single tail store after every byte is written.

    Consumption is two-phase: :meth:`peek` reads the record at the head
    without committing, :meth:`advance` commits it.  A consumer that dies
    between the two leaves the record in place for its replacement (see the
    module docstring's crash/replay invariants); :meth:`get` is the
    peek+advance convenience for consumers that do not need replay.
    """

    _HDR = 64  # tail:8 @0 (producer-owned), head:8 @8 (consumer-owned),
    # closed:8 @16 (producer-owned), handoff:8 @24 (supervisor-owned),
    # heartbeat:8 @32 (consumer-owned monotone liveness counter)
    _REC = struct.Struct("<IBq")  # total_len, tag, serial

    def __init__(self, name_prefix: str, slots: int = 4096, slot_bytes: int = 512):
        if slots < 4:
            raise ValueError("ring needs >= 4 slots")
        self.slots = slots
        self.slot_bytes = _align(slot_bytes)
        size = self._HDR + self.slots * self.slot_bytes
        self._shm = shared_memory.SharedMemory(
            create=True, size=size, name=f"{name_prefix}_spsc"
        )
        self._buf = self._shm.buf
        self._buf[: self._HDR] = bytes(self._HDR)
        self._tail = 0  # producer-side mirror
        self._head = 0  # consumer-side mirror
        self._beat = 0  # consumer-side heartbeat mirror
        self.name = self._shm.name

    @property
    def capacity_bytes(self) -> int:
        """Max payload bytes a single record can carry (span limit)."""
        return (self.slots - 1) * self.slot_bytes - self._REC.size

    # -- counters (aligned 8-byte single-writer stores) ---------------------
    def _load(self, off: int) -> int:
        return _I8.unpack_from(self._buf, off)[0]

    def _store(self, off: int, v: int) -> None:
        _I8.pack_into(self._buf, off, v)

    # -- producer -----------------------------------------------------------
    def sync_producer(self) -> None:
        """Reload the producer cursor from shared memory.

        A replacement producer process (router crash re-fork) inherits the
        supervisor's stale tail mirror — usually 0, since the parent never
        puts into interior rings; writing with it would rewind the shared
        tail and orphan every queued record.  Re-read the authoritative
        value before the first :meth:`put`."""
        self._tail = self._load(0)

    def put(self, serial: int, tag: int, data: bytes) -> bool:
        """Append one record; returns False if the ring lacks space."""
        total = self._REC.size + len(data)
        nslots = max(1, -(-total // self.slot_bytes))
        if nslots >= self.slots:
            raise ValueError(
                f"record of {len(data)}B exceeds ring capacity "
                f"({self.capacity_bytes}B); raise slot_bytes/slots"
            )
        head = self._load(8)
        if self._tail - head + nslots > self.slots:
            return False
        first = (self._tail % self.slots) * self.slot_bytes + self._HDR
        self._REC.pack_into(self._buf, first, len(data), tag, serial)
        wrote = min(len(data), self.slot_bytes - self._REC.size)
        self._buf[first + self._REC.size : first + self._REC.size + wrote] = (
            data[:wrote]
        )
        pos = wrote
        for k in range(1, nslots):
            off = ((self._tail + k) % self.slots) * self.slot_bytes + self._HDR
            chunk = data[pos : pos + self.slot_bytes]
            self._buf[off : off + len(chunk)] = chunk
            pos += len(chunk)
        self._tail += nslots
        self._store(0, self._tail)  # publish the whole span
        return True

    def close_ring(self) -> None:
        """Producer-side EOF: consumers drain whatever is left, then stop."""
        self._store(16, 1)

    # -- supervisor (elastic replanning) ------------------------------------
    def request_handoff(self) -> None:
        """Ask the consumer to send its worker-local state back over its pipe
        before exiting (elastic resize: the group is re-forked at a new width
        and keyed state must migrate).  Set BEFORE :meth:`close_ring` so the
        exiting worker observes it."""
        self._store(24, 1)

    def handoff_requested(self) -> bool:
        """Whether the supervisor flagged an elastic state handoff."""
        return self._load(24) != 0

    def reopen_ring(self) -> None:
        """Clear the EOF/handoff flags so a quiesced ring (head == tail) can
        serve a freshly forked replacement group after an elastic resize."""
        self._store(16, 0)
        self._store(24, 0)

    def reset_to_tail(self) -> None:
        """Supervisor-side group-restore reset: discard every queued record
        by moving the consumer cursor to the producer cursor.  Only legal
        once the consumer process is dead (the supervisor briefly becomes the
        sole writer of the head); the feeder then re-pumps the discarded
        window from its replay log and a freshly forked consumer resumes via
        :meth:`sync_consumer`."""
        self._store(8, self._load(0))

    # -- progress counters (any process) ------------------------------------
    def consumed_slots(self) -> int:
        """Slots the consumer has committed — a monotone per-worker progress
        counter the supervisor samples for the cost model."""
        return self._load(8)

    def queued_slots(self) -> int:
        """Slots currently queued (produced − consumed): the stage-occupancy
        signal behind elastic replanning."""
        return max(self._load(0) - self._load(8), 0)

    # -- liveness heartbeat (consumer writes, supervisor reads) -------------
    def beat(self) -> None:
        """Consumer-side liveness tick.  Monotone and written on every main
        loop pass (including idle naps and FULL publish spins), so a frozen
        counter means the consumer is hung or dead — the supervisor's stall
        detector SIGKILLs it and lets the crash path recover."""
        self._beat += 1
        self._store(32, self._beat)

    def heartbeat(self) -> int:
        """Current consumer heartbeat value (supervisor-side sample)."""
        return self._load(32)

    # -- consumer -----------------------------------------------------------
    def sync_consumer(self) -> None:
        """Reload the consumer cursor from shared memory.

        A replacement consumer process (crash re-fork) inherits the parent's
        stale head mirror; this re-reads the authoritative shared value so it
        resumes exactly at the first uncommitted record."""
        self._head = self._load(8)

    def peek(self) -> Optional[Tuple[int, int, bytes, int]]:
        """Read the head record WITHOUT committing it.

        Returns ``(serial, tag, payload, nslots)`` or None when empty; pass
        ``nslots`` to :meth:`advance` to commit after acting on the record.
        """
        tail = self._load(0)
        if self._head >= tail:
            return None
        first = (self._head % self.slots) * self.slot_bytes + self._HDR
        total, tag, serial = self._REC.unpack_from(self._buf, first)
        nslots = max(1, -(-(self._REC.size + total) // self.slot_bytes))
        take = min(total, self.slot_bytes - self._REC.size)
        data = bytes(self._buf[first + self._REC.size : first + self._REC.size + take])
        if nslots > 1:
            parts = [data]
            pos = take
            for k in range(1, nslots):
                off = ((self._head + k) % self.slots) * self.slot_bytes + self._HDR
                chunk_len = min(total - pos, self.slot_bytes)
                parts.append(bytes(self._buf[off : off + chunk_len]))
                pos += chunk_len
            data = b"".join(parts)
        return serial, tag, data, nslots

    def advance(self, nslots: int) -> None:
        """Commit the record last returned by :meth:`peek`."""
        self._head += nslots
        self._store(8, self._head)

    def get(self) -> Optional[Tuple[int, int, bytes]]:
        """Pop one record -> (serial, tag, payload), or None when empty."""
        rec = self.peek()
        if rec is None:
            return None
        serial, tag, data, nslots = rec
        self.advance(nslots)
        return serial, tag, data

    def closed(self) -> bool:
        """Producer-side EOF flag: drain what is left, then stop."""
        return self._load(16) != 0

    def __len__(self) -> int:  # records are >=1 slot; used as emptiness hint
        return max(self._load(0) - self._load(8), 0)

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Detach this process's mapping (does not free the segment)."""
        self._buf = None
        self._shm.close()

    def unlink(self) -> None:
        """Free the shared-memory segment (idempotent)."""
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass


# --------------------------------------------------------------- reorder ring
class ShmReorderRing:
    """Cross-process serial-number reorder ring (fig. 4 semantics, MPSC).

    Slot layout: [seq:8][len:4][span:4][tag:1][payload...].  Workers publish
    serial ``t`` into slot ``t % size`` under the entry condition
    ``next <= t < next + size`` (``next`` read from the shared
    header); the sequence field is stored last, which is the publish.  A
    ``span > 1`` slot carries the results of the contiguous serial run
    ``[t, t + span)`` in one publish (round-robin micro-batches); the drainer
    advances ``next`` past the whole run.  The drainer consumes the
    contiguous prefix and is the sole writer of ``next``.  Header offset 8 is
    a supervisor-owned ``stop`` flag: publishers spinning on a FULL window
    and idle drainers check it so teardown never strands a process.

    Drains come in two flavours.  :meth:`poll` is read-and-commit in one
    step (the parent's final-ring drain).  A *restartable* drainer (an
    exchange router) instead uses :meth:`read_ahead` — which moves only a
    local cursor, leaving the shared ``next`` (and therefore the publish
    window, whose slots double as the replay source) behind — and
    :meth:`commit`, which widens the window only after everything read has
    been durably handed downstream.  ``commit`` also double-buffers a
    *commit record* ``(read_pos, downstream_next_serial)`` in the header:
    two slots plus an index written last, so a drainer SIGKILLed mid-commit
    always leaves one complete pair for its replacement
    (:meth:`sync_drainer` / :meth:`commit_record`).
    """

    _HDR = 128  # next:8 @0 (drainer-owned), stop:8 @8 (supervisor-owned),
    # active group width:8 @16 (supervisor-owned metadata),
    # drainer heartbeat:8 @24, commit record slots A/B:16 @32/@48
    # (read_pos, downstream serial), active record index:8 @64 (0 = none)
    _SLOT_HDR = struct.Struct("<qIIB")  # seq, len, span, tag

    PUBLISHED = 0
    FULL = 1
    STALE = 2  # serial already drained (replay after crash) — drop

    def __init__(self, name_prefix: str, size: int = 4096, payload_bytes: int = 512):
        self.size = size
        self.payload_bytes = payload_bytes
        self.slot_bytes = _align(self._SLOT_HDR.size + payload_bytes)
        self._shm = shared_memory.SharedMemory(
            create=True,
            size=self._HDR + size * self.slot_bytes,
            name=f"{name_prefix}_reorder",
        )
        self._buf = self._shm.buf
        self._buf[: self._HDR] = bytes(self._HDR)
        # seq fields must start != any valid serial (serials start at 1)
        for j in range(size):
            _I8.pack_into(self._buf, self._HDR + j * self.slot_bytes, 0)
        _I8.pack_into(self._buf, 0, 1)  # next = 1
        self._next = 1  # drainer-side mirror (read cursor; see read_ahead)
        self._beat = 0  # drainer-side heartbeat mirror
        # drainer-local scatter stash for TAG_KBUNDLES slots: a keyed worker
        # publishes a whole unit's results (interleaved serials) as one slot
        # at the unit's first serial; the remaining (serial -> (tag, data))
        # entries wait here until the contiguous sweep reaches them.  Bounded
        # by the ring window (every stashed serial is < next + size).
        self._stash: dict = {}
        self.name = self._shm.name

    # -- worker side --------------------------------------------------------
    def shared_next(self) -> int:
        """The drainer's published ``next`` — readable from any process.

        Feeders use it to bound in-flight serials (dispatched − drained), the
        staged backend's per-stage backpressure."""
        return _I8.unpack_from(self._buf, 0)[0]

    def try_publish(self, t: int, tag: int, data: bytes, span: int = 1) -> int:
        """Publish serial ``t``'s result slot (covering ``span`` serials).
        Returns ``PUBLISHED``, ``FULL`` (window not there yet — retry), or
        ``STALE`` (already drained: crash replay — drop)."""
        n = self.shared_next()
        if t < n:
            return self.STALE
        if t >= n + self.size:
            return self.FULL
        if len(data) > self.payload_bytes:
            raise ValueError("bundle exceeds slot payload; caller must spill")
        off = self._HDR + (t % self.size) * self.slot_bytes
        body = off + self._SLOT_HDR.size
        self._buf[body : body + len(data)] = data
        # header written in two steps so seq (the publish) is stored last
        struct.pack_into("<IIB", self._buf, off + 8, len(data), span, tag)
        _I8.pack_into(self._buf, off, t)
        return self.PUBLISHED

    # -- drainer side -------------------------------------------------------
    def read_ahead(self) -> Optional[Tuple[int, int, bytes, int]]:
        """Consume the next in-order slot -> (serial, tag, payload, span)
        advancing only the drainer-LOCAL cursor — the shared ``next`` (and
        with it the publish window) moves at :meth:`commit` time.  A
        ``TAG_KBUNDLES`` slot is unpacked transparently: the head serial's
        entry is returned now, the rest scatter into the drainer-local stash
        and are returned when the sweep reaches their serials."""
        t = self._next
        hit = self._stash.pop(t, None)
        if hit is None:
            off = self._HDR + (t % self.size) * self.slot_bytes
            seq, length, span, tag = self._SLOT_HDR.unpack_from(self._buf, off)
            if seq != t:
                return None
            body = off + self._SLOT_HDR.size
            data = bytes(self._buf[body : body + length])
            if tag == TAG_KBUNDLES:
                head = None
                for s, etag, edata in pickle.loads(data):
                    if s == t:
                        head = (etag, edata)
                    else:
                        self._stash[s] = (etag, edata)
                tag, data = head
                span = 1
        else:
            tag, data = hit
            span = 1
        self._next += max(span, 1)
        return t, tag, data, span

    def poll(self) -> Optional[Tuple[int, int, bytes, int]]:
        """Read-and-commit drain (the parent's final ring): every
        :meth:`read_ahead` is immediately committed, so the publish window
        tracks the read cursor exactly — the pre-recovery semantics."""
        got = self.read_ahead()
        if got is not None:
            _I8.pack_into(self._buf, 0, self._next)  # widen the window
        return got

    def commit(self, downstream_serial: int) -> None:
        """Publish drain progress: widen the shared window to the local read
        cursor and record ``(read_pos, downstream_serial)`` — the pair a
        replacement drainer resumes from.  The caller guarantees everything
        read so far is durably pumped downstream (its out-queues, partial
        accumulators, and scatter stash are all empty), so slots below the
        cursor may be recycled.  The record is double-buffered with the
        index stored last: a SIGKILL mid-commit leaves the previous complete
        pair active."""
        idx = _I8.unpack_from(self._buf, 64)[0]
        new = 2 if idx == 1 else 1
        base = 32 if new == 1 else 48
        _I8.pack_into(self._buf, base, self._next)
        _I8.pack_into(self._buf, base + 8, downstream_serial)
        _I8.pack_into(self._buf, 64, new)
        _I8.pack_into(self._buf, 0, self._next)  # widen the window last

    def commit_record(self) -> Optional[Tuple[int, int]]:
        """The active ``(read_pos, downstream_serial)`` commit pair, or None
        if this ring's drainer has never committed."""
        idx = _I8.unpack_from(self._buf, 64)[0]
        if idx == 0:
            return None
        base = 32 if idx == 1 else 48
        return (
            _I8.unpack_from(self._buf, base)[0],
            _I8.unpack_from(self._buf, base + 8)[0],
        )

    def sync_drainer(self) -> int:
        """Restarted-drainer resume: reload the read cursor from the commit
        record (falling back to the shared ``next``), clear the local stash,
        and return the downstream serial to resume dispatch at.  Also
        re-publishes the window at the committed position — a predecessor
        killed between writing the record and widening the window left the
        two an index apart, and the record is the later, authoritative one."""
        rec = self.commit_record()
        if rec is None:
            self._next = _I8.unpack_from(self._buf, 0)[0]
            serial = 1
        else:
            self._next, serial = rec
            _I8.pack_into(self._buf, 0, self._next)
        self._stash = {}
        return serial

    def has_stashed(self) -> bool:
        """Whether KBUNDLES scatter entries are still awaiting their serials
        (a commit while stashed would let their source slot be recycled)."""
        return bool(self._stash)

    def read_pos(self) -> int:
        """Drainer-local read cursor (may run ahead of the shared window)."""
        return self._next

    # -- drainer heartbeat (drainer writes, supervisor reads) ---------------
    def beat_drainer(self) -> None:
        """Drainer-side liveness tick (see :meth:`ShmSpscRing.beat`)."""
        self._beat += 1
        _I8.pack_into(self._buf, 24, self._beat)

    def drainer_heartbeat(self) -> int:
        """Current drainer heartbeat value (supervisor-side sample)."""
        return _I8.unpack_from(self._buf, 24)[0]

    @property
    def next_serial(self) -> int:
        """Drainer-side mirror of the next serial to consume."""
        return self._next

    def published(self, t: int) -> bool:
        """Any-process-side: is serial ``t`` already drained or sitting
        published in its slot?  A crash-replacement worker checks this before
        re-publishing its replayed unit — a serial whose result survived the
        dead worker must have exactly one publisher, or the duplicate could
        clobber the slot concurrently with its reuse by ``t + size`` once the
        drain sweeps past ``t``.  (If the slot is *unpublished*, republish is
        race-free: the drain cannot pass ``t``, so ``t + size`` fails the
        entry condition until the republish lands.)"""
        if t < self.shared_next():
            return True
        off = self._HDR + (t % self.size) * self.slot_bytes
        return _I8.unpack_from(self._buf, off)[0] == t

    # -- teardown flag ------------------------------------------------------
    def request_stop(self) -> None:
        """Supervisor-side: tell publishers/drainers to abandon the stream."""
        _I8.pack_into(self._buf, 8, 1)

    def stopped(self) -> bool:
        """Teardown flag: publishers/drainers must abandon the stream."""
        return _I8.unpack_from(self._buf, 8)[0] != 0

    # -- group-width metadata (supervisor-owned, any process may read) ------
    def set_active_width(self, w: int) -> None:
        """Publish the stage's live worker-group width (elastic resizes
        rewrite it; routers/monitors read it for introspection)."""
        _I8.pack_into(self._buf, 16, w)

    def active_width(self) -> int:
        """The stage's live worker-group width (supervisor-published)."""
        return _I8.unpack_from(self._buf, 16)[0]

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Detach this process's mapping (does not free the segment)."""
        self._buf = None
        self._shm.close()

    def unlink(self) -> None:
        """Free the shared-memory segment (idempotent)."""
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass


# -------------------------------------------------------------- exchange edge
class ExchangeRing:
    """M-producer → N-consumer hand-off backing one process stage.

    The stage's *feeder* (parent or exchange router — the single upstream
    drainer, so M producers are already serialized by the upstream reorder
    ring) seals stream-ordered tuples into dispatch units and puts them into
    the N per-worker ingress SPSC rings (keyed routing for partitioned
    stages; round-robin otherwise).  The stage's N workers publish per-serial
    results into the single ``reorder`` ring, whose contiguous drain restores
    stream order for the next hop.  Pure structure: routing/sealing policy
    lives in :mod:`.procrun`.

    ``consumers`` is the *maximum* group width: elastic replanning
    (:mod:`.costmodel`) may run fewer live workers than rings.  The live
    width rides the reorder-ring header (:meth:`set_active_width`) and the
    per-ring cursors double as the cost model's progress/occupancy counters
    (:meth:`progress`, :meth:`backlog_slots`).
    """

    def __init__(
        self,
        name_prefix: str,
        consumers: int,
        *,
        ring_slots: int = 2048,
        slot_bytes: int = 1024,
        reorder_size: int = 1024,
        reorder_payload: int = 4096,
    ):
        if consumers < 1:
            raise ValueError("exchange needs at least one consumer")
        self.consumers = consumers
        self.rings = [
            ShmSpscRing(f"{name_prefix}_c{j}", slots=ring_slots, slot_bytes=slot_bytes)
            for j in range(consumers)
        ]
        self.reorder = ShmReorderRing(
            name_prefix, size=reorder_size, payload_bytes=reorder_payload
        )
        self.reorder.set_active_width(consumers)

    # -- group-width metadata ----------------------------------------------
    def set_active_width(self, w: int) -> None:
        self.reorder.set_active_width(w)

    def active_width(self) -> int:
        return self.reorder.active_width()

    # -- sampling counters (supervisor-side cost model) ---------------------
    def progress(self) -> Tuple[int, list]:
        """(drained serials, per-worker consumed-slot counters) — the publish
        counters :class:`~.costmodel.OccupancyMonitor` samples."""
        return (
            max(self.reorder.shared_next() - 1, 0),
            [r.consumed_slots() for r in self.rings],
        )

    def backlog_slots(self) -> int:
        """Queued ingress slots across the group (stage occupancy proxy)."""
        return sum(r.queued_slots() for r in self.rings)

    def close_ingress(self) -> None:
        """Producer-side EOF on every ingress ring (workers drain, then exit)."""
        for r in self.rings:
            r.close_ring()

    def request_handoff(self) -> None:
        """Elastic resize: flag every ring so exiting workers send state."""
        for r in self.rings:
            r.request_handoff()

    def reopen_ingress(self) -> None:
        """Clear EOF/handoff flags after a quiesced resize (see
        :meth:`ShmSpscRing.reopen_ring`)."""
        for r in self.rings:
            r.reopen_ring()

    def reset_ingress(self) -> None:
        """Group-restore: discard every queued ingress record (the feeder
        re-pumps them from its replay log).  Only legal with the consumer
        group dead — see :meth:`ShmSpscRing.reset_to_tail`."""
        for r in self.rings:
            r.reset_to_tail()

    def sync_feeder(self) -> None:
        """Restarted-feeder resume: reload every ingress ring's producer
        cursor (see :meth:`ShmSpscRing.sync_producer`)."""
        for r in self.rings:
            r.sync_producer()

    def heartbeats(self) -> list:
        """Per-worker consumer heartbeat samples (stall detection)."""
        return [r.heartbeat() for r in self.rings]

    def request_stop(self) -> None:
        self.reorder.request_stop()

    def close(self) -> None:
        for r in self.rings:
            r.close()
        self.reorder.close()

    def unlink(self) -> None:
        for r in self.rings:
            r.unlink()
        self.reorder.unlink()
