"""Engine / Plan / Session: the compile → plan → execute public surface.

The paper's core claim is that an adaptive runtime should *map exposed
parallelism onto the machine* — which makes the execution **plan** (stage
cuts, worker widths, ring geometry, predicted load) a first-class artifact,
not a constructor side-effect.  Following BriskStream's design (PAPERS.md),
this module separates the three phases the legacy one-shots fused:

1. **Configure** — :class:`EngineConfig`, a typed, validated config tree
   (:class:`ThreadOptions` / :class:`ProcessOptions` sub-configs).  Every
   knob that used to ride an unvalidated ``**kw`` grab-bag is a declared
   field; :meth:`EngineConfig.from_kwargs` parses the legacy flat keyword
   surface and rejects unknown or conflicting options with a structured
   :class:`ConfigError` (including a did-you-mean hint for typos).

2. **Plan** — ``engine.plan(graph_or_specs)`` returns a backend-agnostic
   :class:`PhysicalPlan`: per-operator predicted cost/flow/load, the process
   backend's stage cuts with cost-model worker widths and exchange-ring
   geometry, and the unstaged parent-tail remainder (the
   :class:`~.procrun.UnstagedGraphWarning` note).  Plans render as text
   (:meth:`PhysicalPlan.explain`), round-trip through plain dicts
   (:meth:`PhysicalPlan.to_dict` / :meth:`PhysicalPlan.from_dict`) for
   caching and test assertions, and can be re-bound to operator callables
   with :meth:`PhysicalPlan.bind`.

3. **Execute** — two surfaces over the same plan:

   - ``engine.run(plan, source)`` drains a finite source and returns a
     uniform :class:`JobResult` (ordered ``outputs``, the
     :class:`~.runtime.RunReport`, and the plan *actually executed* after
     any elastic replans) regardless of backend.
   - ``engine.open(plan)`` returns a streaming :class:`Session`:
     ``push(tuples)`` feeds the pipeline incrementally (the process backend
     feeds the stage-0 exchange live instead of requiring a finite iterable
     up front), ``results()`` iterates ordered egress as it materializes,
     ``stats()`` samples live occupancy, ``close()`` drains and reports.

The deprecated one-shots (:func:`~.runtime.run_pipeline` /
:func:`~.runtime.run_graph`) are thin shims over this path and return a
:class:`JobResult`-backed :class:`JobHandle` so their historical result
surface (``outputs`` / ``egress_count`` / ``markers``) stays identical
across backends.
"""
from __future__ import annotations

import difflib
import time
from dataclasses import asdict, dataclass, field, fields
from typing import Any, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple, Union

from .costmodel import graph_flows, resolve_workers
from .faults import FaultOptions
from .operators import DEVICE, OpSpec, PARTITIONED, STATEFUL
from .pipeline import CompiledPipeline, GraphPipeline
from .procrun import ProcessRuntime, _chain_nodes
from .runtime import RunReport, StreamRuntime
from .scheduler import HEURISTICS

_REORDER_SCHEMES = ("non_blocking", "lock_based")
_WORKLIST_SCHEMES = ("hybrid", "partitioned", "shared")


# ------------------------------------------------------------------- errors
class ConfigError(ValueError):
    """Structured configuration error raised by the Engine surface.

    Carries the offending ``key`` (when one option is to blame) and an
    optional ``suggestion`` (a did-you-mean hint for typos); the formatted
    message includes both.  Subclasses :class:`ValueError` so legacy callers
    catching ``ValueError`` keep working.
    """

    def __init__(self, message: str, *, key: Optional[str] = None,
                 suggestion: Optional[str] = None):
        self.key = key
        self.suggestion = suggestion
        if suggestion:
            message = f"{message} (did you mean {suggestion!r}?)"
        super().__init__(message)


class PlanVerificationError(ConfigError):
    """A :class:`PhysicalPlan` failed the plan-time ordering-safety catalog
    (:mod:`repro.analysis.plancheck`).  Carries the structured ``violations``
    (:class:`~repro.analysis.plancheck.PlanViolation` rows, each with a
    ``PV4xx`` rule id) so callers can branch on specific rules instead of
    parsing the message."""

    def __init__(self, violations):
        self.violations = list(violations)
        lines = "; ".join(v.render() for v in self.violations)
        super().__init__(
            f"plan fails ordering-safety verification: {lines}"
        )


class SessionStarvation(TimeoutError):
    """``Session.results(timeout=...)`` starved past its deadline: no output
    materialized for ``timeout`` continuous seconds while the session was
    still open.  Carries a live ``snapshot`` dict (per-stage widths, backlog
    slots, heartbeat counters, restart/replan counts — whatever the backend's
    ``stats()`` exposes) captured at expiry, so a hang is diagnosable from
    the exception alone; the snapshot is also rendered into the message."""

    def __init__(self, message: str, snapshot: Optional[dict] = None):
        self.snapshot = dict(snapshot or {})
        super().__init__(message)


def _check(cond: bool, message: str, key: Optional[str] = None) -> None:
    if not cond:
        raise ConfigError(message, key=key)


# ------------------------------------------------------------------ configs
@dataclass
class ThreadOptions:
    """Thread-backend options: the central scheduler's dials (paper §6).

    ``heuristic`` picks the scheduling policy (``qst``/``lp``/``et``/``ct``/
    ``adaptive``); ``time_slice`` is the constant worker slice; ``capacity``
    and ``window`` parameterize the QST and CT heuristics; the adaptive
    controller re-estimates costs every ``adapt_interval`` seconds.
    """

    heuristic: str = "ct"
    time_slice: float = 0.002
    capacity: int = 4096
    window: float = 0.05
    adapt_interval: float = 0.02

    def validate(self) -> None:
        """Raise :class:`ConfigError` on any out-of-range field."""
        _check(self.heuristic in HEURISTICS,
               f"unknown heuristic {self.heuristic!r}; pick from {HEURISTICS}",
               key="heuristic")
        _check(self.time_slice > 0, "time_slice must be > 0", key="time_slice")
        _check(self.capacity >= 1, "capacity must be >= 1", key="capacity")
        _check(self.window > 0, "window must be > 0", key="window")
        _check(self.adapt_interval > 0, "adapt_interval must be > 0",
               key="adapt_interval")


@dataclass
class ProcessOptions:
    """Process-backend options: stage planning, exchange-ring geometry, and
    elastic replanning (see :mod:`.procrun` / :mod:`.shm`).

    ``stages`` caps the planner (``None`` = cut as deep as the graph allows,
    ``1`` = the ingress-only plan); ``io_batch`` is the dispatch-unit size
    (defaults to ``batch_size`` when that is > 1, else 32); ``max_inflight``
    bounds in-flight serials (latency throttle); ``ring_slots`` /
    ``slot_bytes`` / ``reorder_payload`` size the shared-memory rings;
    ``worker_budget`` is the total the ``"auto"`` allocator divides (default
    cores + 1); ``elastic`` forces replanning on/off (``None`` = on exactly
    when ``num_workers="auto"``); the ``replan_*`` trio tunes the occupancy
    monitor; ``parent_idle_cap`` caps the supervisor's idle nap.

    Traffic-reactive elasticity dials (see docs/serving.md): the
    ``traffic_*`` group tunes the :class:`~repro.core.TrafficMonitor` that
    turns serving-tier load signals (``SessionMux.load_signals`` snapshots
    arriving via ``Session.offer_load``) into grow/shrink proposals —
    ``traffic_elastic`` arms it (``None`` = on exactly when the runtime is
    elastic; ``True`` additionally forces ``elastic`` on),
    ``traffic_interval`` is the policy evaluation period,
    ``traffic_grow_util`` / ``traffic_shrink_util`` are the hysteresis
    thresholds on predicted stage utilization (shrink must sit strictly
    below grow), ``traffic_patience`` the consecutive qualifying samples
    required, and ``traffic_cooldown`` the post-resize quiet period.
    ``resize_latency_budget`` is the p99 guard: seconds a replan may stall
    the feeder before the supervisor aborts it pre-quiesce (and a
    traffic-triggered resize that completes over budget is undone);
    ``None`` disables the guard.

    Fault-tolerance dials (see ``docs/fault-tolerance.md``):
    ``checkpoint_interval`` is the epoch length in serials for keyed/stateful
    state snapshots (0 disables — those stages then abort the job on a worker
    crash, the pre-checkpoint behavior); ``stall_timeout`` arms the
    hung-process detector (seconds a worker/router heartbeat may freeze
    before it is SIGKILLed into the crash-recovery path; ``None`` = off;
    must exceed the worst single-unit operator time); ``spill_timeout`` is
    the oversized-bundle relay deadline.

    Columnar / device-offload dials (see ``docs/columnar.md``):
    ``columnar`` arms the zero-copy batch path — dispatchers seal numeric
    micro-batches as ``TAG_COLBLOCK`` column blocks instead of pickled
    units (non-conforming batches fall back to pickle per unit);
    ``device_batch`` is the rows-per-dispatch target of ``device``-kind
    stages (clamped up to ``io_batch``); ``device_workers`` is the pinned
    width of every device stage (device widths never resize — batching
    state lives per worker); ``device_inflight`` bounds asynchronous
    dispatches in flight (2 = double-buffering: the newest dispatch
    overlaps host ingest and the oldest batch's compute);
    ``device_backend`` picks the kernel backend (``auto`` = jax when
    importable, else the pure-NumPy reference).
    """

    stages: Optional[int] = None
    io_batch: Optional[int] = None
    max_inflight: Optional[int] = None
    ring_slots: int = 2048
    slot_bytes: int = 1024
    reorder_payload: int = 4096
    restart_on_crash: bool = True
    worker_budget: Optional[int] = None
    elastic: Optional[bool] = None
    calibrate_tuples: int = 64
    replan_interval: float = 0.25
    replan_threshold: float = 0.55
    replan_patience: int = 3
    traffic_elastic: Optional[bool] = None
    traffic_interval: float = 0.5
    traffic_grow_util: float = 0.85
    traffic_shrink_util: float = 0.30
    traffic_patience: int = 2
    traffic_cooldown: float = 2.0
    resize_latency_budget: Optional[float] = None
    parent_idle_cap: float = 5e-4
    columnar: bool = False
    device_batch: int = 256
    device_workers: int = 1
    device_inflight: int = 2
    device_backend: str = "auto"
    checkpoint_interval: int = 1024
    stall_timeout: Optional[float] = None
    spill_timeout: float = 10.0

    def validate(self) -> None:
        """Raise :class:`ConfigError` on any out-of-range field."""
        _check(self.stages is None or self.stages >= 1,
               "stages must be None or >= 1", key="stages")
        _check(self.io_batch is None or self.io_batch >= 1,
               "io_batch must be None or >= 1", key="io_batch")
        _check(self.max_inflight is None or self.max_inflight >= 1,
               "max_inflight must be None or >= 1", key="max_inflight")
        _check(self.ring_slots >= 4, "ring_slots must be >= 4", key="ring_slots")
        _check(self.slot_bytes >= 64, "slot_bytes must be >= 64",
               key="slot_bytes")
        _check(self.reorder_payload >= 16, "reorder_payload must be >= 16",
               key="reorder_payload")
        _check(self.worker_budget is None or self.worker_budget >= 1,
               "worker_budget must be None or >= 1", key="worker_budget")
        _check(self.calibrate_tuples >= 0, "calibrate_tuples must be >= 0",
               key="calibrate_tuples")
        _check(self.replan_interval > 0, "replan_interval must be > 0",
               key="replan_interval")
        _check(0 < self.replan_threshold <= 1,
               "replan_threshold must be in (0, 1]", key="replan_threshold")
        _check(self.replan_patience >= 1, "replan_patience must be >= 1",
               key="replan_patience")
        _check(
            self.traffic_elastic is not True or self.elastic is not False,
            "traffic_elastic=True requires elastic replanning "
            "(elastic must not be False)",
            key="traffic_elastic",
        )
        _check(self.traffic_interval > 0, "traffic_interval must be > 0",
               key="traffic_interval")
        _check(self.traffic_grow_util > 0, "traffic_grow_util must be > 0",
               key="traffic_grow_util")
        _check(
            0 < self.traffic_shrink_util < self.traffic_grow_util,
            "traffic_shrink_util must be in (0, traffic_grow_util) — the "
            "hysteresis band must be non-empty",
            key="traffic_shrink_util",
        )
        _check(self.traffic_patience >= 1, "traffic_patience must be >= 1",
               key="traffic_patience")
        _check(self.traffic_cooldown >= 0, "traffic_cooldown must be >= 0",
               key="traffic_cooldown")
        _check(
            self.resize_latency_budget is None
            or self.resize_latency_budget > 0,
            "resize_latency_budget must be None (guard off) or > 0",
            key="resize_latency_budget",
        )
        _check(self.parent_idle_cap > 0, "parent_idle_cap must be > 0",
               key="parent_idle_cap")
        _check(isinstance(self.columnar, bool),
               "columnar must be a bool", key="columnar")
        _check(
            isinstance(self.device_batch, int) and self.device_batch >= 1,
            "device_batch must be an int >= 1", key="device_batch",
        )
        _check(
            isinstance(self.device_workers, int) and self.device_workers >= 1,
            "device_workers must be an int >= 1", key="device_workers",
        )
        _check(
            isinstance(self.device_inflight, int)
            and self.device_inflight >= 1,
            "device_inflight must be an int >= 1", key="device_inflight",
        )
        _check(
            self.device_backend in ("auto", "jax", "numpy"),
            "device_backend must be one of auto|jax|numpy",
            key="device_backend",
        )
        _check(
            isinstance(self.checkpoint_interval, int)
            and self.checkpoint_interval >= 0,
            "checkpoint_interval must be an int >= 0 (0 disables epochs)",
            key="checkpoint_interval",
        )
        _check(self.stall_timeout is None or self.stall_timeout > 0,
               "stall_timeout must be None (off) or > 0", key="stall_timeout")
        _check(self.spill_timeout > 0, "spill_timeout must be > 0",
               key="spill_timeout")


_COMMON_KEYS = (
    "backend", "num_workers", "batch_size", "marker_interval",
    "collect_outputs", "reorder_scheme", "worklist_scheme", "reorder_size",
    "cost_priors",
)
_THREAD_KEYS = tuple(f.name for f in fields(ThreadOptions))
_PROCESS_KEYS = tuple(f.name for f in fields(ProcessOptions))
_ALL_KEYS = _COMMON_KEYS + _THREAD_KEYS + _PROCESS_KEYS


@dataclass
class EngineConfig:
    """Validated engine configuration: backend selection plus every knob the
    runtimes accept, as declared fields instead of a ``**kw`` grab-bag.

    Common fields configure both backends (``num_workers`` takes an int or
    ``"auto"`` for cost-model allocation; ``batch_size`` is the micro-batch
    unit; ``cost_priors`` maps op names to per-tuple µs overriding declared
    priors).  Backend-specific dials live in the ``thread`` /
    ``process`` sub-configs — both are always present, so one config can
    A/B the two backends by flipping ``backend`` alone.  Build directly, or
    from the legacy flat keyword surface via :meth:`from_kwargs` (which
    rejects unknown/conflicting keys with :class:`ConfigError`).
    """

    backend: str = "thread"
    num_workers: Union[int, str] = 4
    batch_size: int = 1
    marker_interval: int = 64
    collect_outputs: bool = False
    reorder_scheme: str = "non_blocking"
    worklist_scheme: str = "hybrid"
    reorder_size: int = 1024
    cost_priors: Optional[Dict[str, float]] = None
    thread: ThreadOptions = field(default_factory=ThreadOptions)
    process: ProcessOptions = field(default_factory=ProcessOptions)
    #: fault-injection schedule + per-op on_error policy (process backend;
    #: see core/faults.py and docs/fault-tolerance.md)
    faults: FaultOptions = field(default_factory=FaultOptions)

    # ------------------------------------------------------------- parsing
    @classmethod
    def from_kwargs(cls, **kw) -> "EngineConfig":
        """Build a config from the legacy flat keyword surface.

        Routes each key to the right (sub-)config field.  Unknown keys raise
        :class:`ConfigError` with a did-you-mean hint; process-only keys
        combined with ``backend="thread"`` raise a conflict error (they were
        silently meaningless before this surface existed).  Thread-scheduler
        keys are accepted alongside ``backend="process"`` — the config
        carries both sub-configs precisely so one object can drive either
        backend — but only the selected backend reads its own section.
        """
        backend = kw.get("backend", "thread")
        common: Dict[str, Any] = {}
        thread_kw: Dict[str, Any] = {}
        process_kw: Dict[str, Any] = {}
        subs: Dict[str, Any] = {}
        for key, value in kw.items():
            if key in ("thread", "process", "faults"):  # whole sub-configs
                subs[key] = value
            elif key in _COMMON_KEYS:
                common[key] = value
            elif key in _THREAD_KEYS:
                thread_kw[key] = value
            elif key in _PROCESS_KEYS:
                if backend == "thread":
                    raise ConfigError(
                        f"option {key!r} is process-backend-only but "
                        "backend='thread' is selected; pass "
                        "backend='process' or drop it",
                        key=key,
                    )
                process_kw[key] = value
            else:
                hits = difflib.get_close_matches(key, _ALL_KEYS, n=1)
                raise ConfigError(
                    f"unknown option {key!r}",
                    key=key,
                    suggestion=hits[0] if hits else None,
                )
        for name, flat in (("thread", thread_kw), ("process", process_kw)):
            if name in subs and flat:
                raise ConfigError(
                    f"pass {name} options either flat or as a {name}= "
                    "sub-config, not both",
                    key=sorted(flat)[0],
                )
        thread = subs.get("thread", None)
        process = subs.get("process", None)
        faults = subs.get("faults", None)
        cfg = cls(
            thread=thread if thread is not None else ThreadOptions(**thread_kw),
            process=(
                process if process is not None else ProcessOptions(**process_kw)
            ),
            faults=faults if faults is not None else FaultOptions(),
            **common,
        )
        cfg.validate()
        return cfg

    # ---------------------------------------------------------- validation
    def validate(self) -> "EngineConfig":
        """Validate every field (including sub-configs); returns ``self`` so
        construction sites can chain.  Raises :class:`ConfigError`."""
        if isinstance(self.thread, dict):  # convenience: accept plain dicts
            self.thread = ThreadOptions(**self.thread)
        if isinstance(self.process, dict):
            self.process = ProcessOptions(**self.process)
        if isinstance(self.faults, dict):
            self.faults = FaultOptions.from_dict(self.faults)
        _check(isinstance(self.faults, FaultOptions),
               f"faults must be a FaultOptions, got "
               f"{type(self.faults).__name__}", key="faults")
        try:
            self.faults.validate()
        except ValueError as exc:
            raise ConfigError(str(exc), key="faults") from None
        _check(isinstance(self.thread, ThreadOptions),
               f"thread must be a ThreadOptions, got "
               f"{type(self.thread).__name__}", key="thread")
        _check(isinstance(self.process, ProcessOptions),
               f"process must be a ProcessOptions, got "
               f"{type(self.process).__name__}", key="process")
        _check(self.backend in ("thread", "process"),
               f"unknown backend {self.backend!r} (thread | process)",
               key="backend")
        if self.num_workers != "auto":
            _check(
                isinstance(self.num_workers, int) and self.num_workers >= 1,
                "num_workers must be a positive int or 'auto', got "
                f"{self.num_workers!r}",
                key="num_workers",
            )
        _check(isinstance(self.batch_size, int) and self.batch_size >= 1,
               "batch_size must be an int >= 1", key="batch_size")
        _check(isinstance(self.marker_interval, int) and self.marker_interval >= 0,
               "marker_interval must be an int >= 0", key="marker_interval")
        _check(self.reorder_scheme in _REORDER_SCHEMES,
               f"unknown reorder_scheme {self.reorder_scheme!r}; "
               f"pick from {_REORDER_SCHEMES}", key="reorder_scheme")
        _check(self.worklist_scheme in _WORKLIST_SCHEMES,
               f"unknown worklist_scheme {self.worklist_scheme!r}; "
               f"pick from {_WORKLIST_SCHEMES}", key="worklist_scheme")
        _check(isinstance(self.reorder_size, int) and self.reorder_size >= 2,
               "reorder_size must be an int >= 2", key="reorder_size")
        if self.cost_priors is not None:
            _check(
                isinstance(self.cost_priors, dict)
                and all(
                    isinstance(k, str) and isinstance(v, (int, float))
                    for k, v in self.cost_priors.items()
                ),
                "cost_priors must map op names to per-tuple µs numbers",
                key="cost_priors",
            )
        self.thread.validate()
        self.process.validate()
        return self

    # --------------------------------------------------------- round-trip
    def to_dict(self) -> dict:
        """Plain-dict form (JSON-able); inverse of :meth:`from_dict`."""
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "EngineConfig":
        """Rebuild a config from :meth:`to_dict` output (validated)."""
        d = dict(d)
        thread = ThreadOptions(**d.pop("thread", {}))
        process = ProcessOptions(**d.pop("process", {}))
        faults = FaultOptions.from_dict(d.pop("faults", None) or {})
        return cls(
            thread=thread, process=process, faults=faults, **d
        ).validate()


# ------------------------------------------------------------------- plans
@dataclass
class PlannedOp:
    """One operator's predicted profile inside a :class:`PhysicalPlan`:
    relative input ``flow`` (tuples per source tuple), per-tuple ``cost_us``,
    declared ``selectivity``, the ``load_share`` fraction of total predicted
    work, and the intrinsic parallelism cap ``max_dop`` (``None`` =
    unbounded — stateless operators).  ``schema_width`` is the declared
    columnar field count of ``device``-kind operators (``None``
    otherwise)."""

    name: str
    kind: str
    cost_us: float
    selectivity: float
    flow: float
    load_share: float
    max_dop: Optional[int] = None
    schema_width: Optional[int] = None


@dataclass
class PlannedStage:
    """One process-backend stage cut inside a :class:`PhysicalPlan`: the
    operator run it executes, its allocated worker-group width (``workers``,
    from the cost model under ``num_workers="auto"``), the elastic headroom
    (``max_workers``), the predicted per-tuple ``cost_us`` / relative
    ``flow`` / ``load_share`` driving the allocation, and whether the stage
    participates in epoch checkpointing (``checkpointed`` — keyed, stateful,
    and device stages with a non-zero ``checkpoint_interval`` and crash
    restarts on)."""

    index: int
    kind: str
    ops: List[str]
    workers: int
    max_workers: int
    cost_us: float
    flow: float
    load_share: float
    checkpointed: bool = False


class PhysicalPlan:
    """Backend-agnostic execution plan: the inspectable artifact between
    ``engine.plan(...)`` and ``engine.run(...)`` / ``engine.open(...)``.

    Carries the per-operator predicted profile (``ops``), the routing-node
    names (``routing``), and — for the process backend — the stage cuts with
    cost-model worker widths (``stages``), the exchange-ring geometry
    (``ring``), and the unstaged parent-tail node names (``unstaged``).
    ``explain()`` renders a stable text form (golden-testable);
    ``to_dict()`` / ``from_dict()`` round-trip the plan through plain dicts
    so it can be cached or asserted on.  A plan deserialized from a dict is
    *unbound* (operator callables cannot be serialized); re-attach the graph
    with :meth:`bind` before executing it.
    """

    def __init__(
        self,
        *,
        backend: str,
        config: EngineConfig,
        ops: Sequence[PlannedOp],
        routing: Sequence[str] = (),
        stages: Sequence[PlannedStage] = (),
        unstaged: Sequence[str] = (),
        ring: Optional[Dict[str, int]] = None,
        worker_budget: Optional[int] = None,
        graph: Optional[Tuple[dict, list]] = None,
    ):
        self.backend = backend
        self.config = config
        self.ops = list(ops)
        self.routing = list(routing)
        self.stages = list(stages)
        self.unstaged = list(unstaged)
        self.ring = dict(ring) if ring else None
        self.worker_budget = worker_budget
        self._graph = graph  # (nodes, edges) with live callables; not serialized

    # ------------------------------------------------------------- binding
    @property
    def bound(self) -> bool:
        """Whether the plan still references live operator callables."""
        return self._graph is not None

    @property
    def graph(self) -> Tuple[dict, list]:
        """The bound ``(nodes, edges)`` graph; raises if the plan came from
        :meth:`from_dict` and was never :meth:`bind`-ed."""
        if self._graph is None:
            raise ConfigError(
                "plan is unbound (deserialized from a dict); call "
                "plan.bind(graph_or_specs) to re-attach operator callables"
            )
        return self._graph

    def bind(self, graph, edges=None) -> "PhysicalPlan":
        """Re-attach operator callables to a deserialized plan.  Accepts the
        same graph forms as :meth:`Engine.plan`; node names and kinds must
        match the plan's recorded operator rows.  Returns ``self``."""
        nodes, edge_list, _specs = _normalize_graph(graph, edges)
        got = [
            (spec.name, spec.kind) for _n, spec in _topo_ops(nodes, edge_list)
        ]
        want = [(op.name, op.kind) for op in self.ops]
        if got != want:
            raise ConfigError(
                f"graph ops {got} do not match the plan's {want}"
            )
        self._graph = (nodes, edge_list)
        return self

    # ---------------------------------------------------------- rendering
    def explain(self) -> str:
        """Deterministic text rendering of the plan.  Stable across hosts
        when the config pins every machine-derived input — in particular
        pass an explicit ``worker_budget`` (and an int ``num_workers``)
        for golden tests: the ``"auto"`` defaults read the host's core
        count, which would leak into the budget line and the widths."""
        c = self.config
        lines = [f"PhysicalPlan backend={self.backend}"]
        if self.backend == "process":
            lines.append(
                f"  workers: num_workers={c.num_workers} "
                f"budget={self.worker_budget}"
            )
        else:
            lines.append(
                f"  workers: num_workers={c.num_workers} "
                f"heuristic={c.thread.heuristic}"
            )
        lines.append(
            f"  batching: batch_size={c.batch_size} "
            f"marker_interval={c.marker_interval}"
        )
        lines.append(
            f"  ordering: reorder={c.reorder_scheme}/{c.reorder_size} "
            f"worklist={c.worklist_scheme}"
        )
        lines.append("  ops:")
        lines.append(
            "    name                 kind          cost_us    flow   sel"
            "    load%"
        )
        for op in self.ops:
            lines.append(
                f"    {op.name:<20} {op.kind:<12} {op.cost_us:>8.1f} "
                f"{op.flow:>7.2f} {op.selectivity:>5.2f} "
                f"{op.load_share * 100:>7.1f}%"
            )
        if self.routing:
            lines.append(f"  routing nodes: {', '.join(self.routing)}")
        if self.backend == "process":
            lines.append("  stages:")
            for s in self.stages:
                ops = ", ".join(s.ops) or "<identity>"
                lines.append(
                    f"    s{s.index} {s.kind:<9} x{s.workers} "
                    f"(max {s.max_workers})  cost={s.cost_us:.1f}us "
                    f"flow={s.flow:.2f} load={s.load_share * 100:.1f}%  "
                    f"ops=[{ops}]"
                )
            r = self.ring or {}
            lines.append(
                f"  exchange: io_batch={r.get('io_batch')} "
                f"max_inflight={r.get('max_inflight')} "
                f"ring_slots={r.get('ring_slots')} "
                f"slot_bytes={r.get('slot_bytes')} "
                f"reorder_size={r.get('reorder_size')} "
                f"reorder_payload={r.get('reorder_payload')}"
            )
            p = c.process
            dev_stages = [s for s in self.stages if s.kind == "device"]
            if r.get("columnar") or dev_stages:
                bits = [f"columnar={'on' if r.get('columnar') else 'off'}"]
                if dev_stages:
                    bits.append(
                        f"device_batch={r.get('device_batch')} "
                        f"device_workers={r.get('device_workers')} "
                        f"device_inflight={r.get('device_inflight')} "
                        f"backend={p.device_backend}"
                    )
                lines.append(f"  columnar: {' '.join(bits)}")
            ckpt = [
                f"s{s.index}" for s in self.stages
                if getattr(s, "checkpointed", False)
            ]
            if ckpt:
                lines.append(
                    f"  checkpoint: interval="
                    f"{r.get('checkpoint_interval') or p.checkpoint_interval} "
                    f"stages=[{', '.join(ckpt)}] "
                    f"stall_timeout={p.stall_timeout}"
                )
            else:
                why = (
                    "disabled"
                    if p.checkpoint_interval == 0 or not p.restart_on_crash
                    else "no keyed/stateful/device stage"
                )
                lines.append(f"  checkpoint: off ({why})")
            elastic_on = (
                p.elastic if p.elastic is not None
                else c.num_workers == "auto"
            ) or p.traffic_elastic is True
            traffic_on = (
                p.traffic_elastic if p.traffic_elastic is not None
                else elastic_on
            )
            if traffic_on:
                guard = (
                    "off" if p.resize_latency_budget is None
                    else f"{p.resize_latency_budget:g}s"
                )
                lines.append(
                    f"  elasticity: traffic=on "
                    f"interval={p.traffic_interval:g}s "
                    f"grow>{p.traffic_grow_util:g} "
                    f"shrink<{p.traffic_shrink_util:g} "
                    f"patience={p.traffic_patience} "
                    f"cooldown={p.traffic_cooldown:g}s guard={guard}"
                )
            else:
                why = "static widths" if not elastic_on else "disabled"
                lines.append(f"  elasticity: traffic=off ({why})")
            if self.unstaged:
                # execution warns only when routing nodes land in the tail
                # (a stages=N cap can strand plain ops there silently)
                warns = any(n in self.routing for n in self.unstaged)
                note = " (UnstagedGraphWarning)" if warns else ""
                lines.append(
                    f"  tail: {', '.join(self.unstaged)} run serially in "
                    f"the parent{note}"
                )
            else:
                lines.append("  tail: none (fully staged)")
        from repro.analysis.plancheck import CATALOG_VERSION  # lazy: no cycle

        violations = self.verify(raise_on_violation=False)
        if violations:
            rules = ", ".join(sorted({v.rule for v in violations}))
            lines.append(
                f"  ordering-safety: {len(violations)} violation(s) "
                f"[{rules}] (catalog v{CATALOG_VERSION})"
            )
        else:
            lines.append(
                f"  ordering-safety: verified OK (catalog v{CATALOG_VERSION})"
            )
        return "\n".join(lines)

    # ---------------------------------------------------------- round-trip
    def to_dict(self) -> dict:
        """Plain-dict (JSON-able) form of everything but the operator
        callables; inverse of :meth:`from_dict`."""
        return {
            "version": 1,
            "backend": self.backend,
            "config": self.config.to_dict(),
            "ops": [asdict(op) for op in self.ops],
            "routing": list(self.routing),
            "stages": [asdict(s) for s in self.stages],
            "unstaged": list(self.unstaged),
            "ring": dict(self.ring) if self.ring else None,
            "worker_budget": self.worker_budget,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PhysicalPlan":
        """Rebuild an (unbound) plan from :meth:`to_dict` output."""
        if d.get("version") != 1:
            raise ConfigError(f"unknown plan version {d.get('version')!r}")
        return cls(
            backend=d["backend"],
            config=EngineConfig.from_dict(d["config"]),
            ops=[PlannedOp(**op) for op in d["ops"]],
            routing=d.get("routing", ()),
            stages=[PlannedStage(**s) for s in d.get("stages", ())],
            unstaged=d.get("unstaged", ()),
            ring=d.get("ring"),
            worker_budget=d.get("worker_budget"),
        )

    # -------------------------------------------------------- verification
    def verify(self, *, raise_on_violation: bool = True):
        """Check the plan against the ordering-safety rule catalog
        (:mod:`repro.analysis.plancheck`, rules PV401–PV406): stage widths
        vs. operator kinds, reorder-ring geometry vs. publish span, elastic
        headroom.  Every plan :meth:`Engine.plan` builds passes by
        construction; a hand-built or deserialized-and-edited plan may not.

        Returns the violation list (empty = safe).  With
        ``raise_on_violation`` (the default) a non-empty list raises
        :class:`PlanVerificationError` instead, carrying the structured
        violations.
        """
        from repro.analysis.plancheck import verify_plan  # lazy: no cycle

        violations = verify_plan(self)
        if violations and raise_on_violation:
            raise PlanVerificationError(violations)
        return violations

    def stage_widths(self) -> List[int]:
        """Planned per-stage worker-group widths (process backend)."""
        return [s.workers for s in self.stages]

    def __repr__(self) -> str:
        return (
            f"<PhysicalPlan backend={self.backend} ops={len(self.ops)} "
            f"stages={len(self.stages)} bound={self.bound}>"
        )


# ------------------------------------------------------- graph normalization
def _normalize_graph(graph, edges=None):
    """Accept the ``Engine.plan`` graph forms and return
    ``(nodes, edges, chain_specs_or_None)``."""
    if edges is not None:
        return dict(graph), [tuple(e) for e in edges], None
    if (
        isinstance(graph, tuple) and len(graph) == 2
        and isinstance(graph[0], dict)
    ):  # (nodes, edges) — a 2-tuple of OpSpecs is a chain, not a graph pair
        nodes, edge_list = graph
        return dict(nodes), [tuple(e) for e in edge_list], None
    if isinstance(graph, dict):
        raise ConfigError(
            "a node dict needs its edge list: pass plan(nodes, edges) or "
            "plan((nodes, edges))"
        )
    specs = list(graph)
    if not specs:
        raise ConfigError("pipeline needs at least one operator")
    for s in specs:
        if not isinstance(s, OpSpec):
            raise ConfigError(
                f"expected OpSpec elements in the chain, got {type(s).__name__}"
            )
    nodes, edge_list = _chain_nodes(specs)
    return nodes, edge_list, specs


def _topo_ops(nodes, edges):
    """(name, spec) for every OpSpec node in topological order."""
    rows, _routing = graph_flows(nodes, edges, None)
    return [(name, spec) for name, spec, _flow, _cost in rows]


# ----------------------------------------------------------------- results
@dataclass
class JobResult:
    """Uniform result of ``engine.run``: ordered ``outputs`` (empty unless
    ``collect_outputs``), the :class:`~.runtime.RunReport`, the
    :class:`PhysicalPlan` actually executed (post elastic replans), latency
    ``markers``, the ``egress_count``, and the elastic/crash instrumentation
    counters (``recoveries`` counts completed crash recoveries — group
    restores and router re-forks; ``dead_letters`` holds the
    :class:`~.faults.DeadLetter` tuples quarantined under the
    ``on_error="dead_letter"`` policy).  ``handle()`` wraps it in the
    legacy-shaped proxy."""

    outputs: list
    report: RunReport
    plan: PhysicalPlan
    markers: list
    egress_count: int
    replans: int = 0
    restarts: int = 0
    recoveries: int = 0
    dead_letters: list = field(default_factory=list)
    target: Any = field(default=None, repr=False)  # executed pipeline/runtime

    def handle(self) -> "JobHandle":
        """The legacy result proxy (see :class:`JobHandle`)."""
        return JobHandle(self)


class JobHandle:
    """:class:`JobResult`-backed proxy with the legacy "pipeline" surface.

    The deprecated one-shots used to return a different object per backend
    (``CompiledPipeline`` / ``GraphPipeline`` vs ``ProcessRuntime``); this
    proxy exposes the documented result attributes — ``outputs``,
    ``egress_count``, ``markers`` — identically for both, plus ``result``
    (the full :class:`JobResult`) and attribute pass-through to the executed
    pipeline/runtime for backend-specific introspection
    (``num_stages``, ``stage_widths()``, ``cost_model``, ...).
    """

    def __init__(self, result: JobResult):
        self._result = result

    @property
    def result(self) -> JobResult:
        """The full :class:`JobResult` behind this proxy."""
        return self._result

    @property
    def outputs(self) -> list:
        """Ordered egress tuples (``collect_outputs=True`` runs only)."""
        return self._result.outputs

    @property
    def egress_count(self) -> int:
        """Total tuples egressed by the run."""
        return self._result.egress_count

    @property
    def markers(self) -> list:
        """Latency probe markers recorded during the run (paper §7)."""
        return self._result.markers

    def __getattr__(self, name: str):
        target = object.__getattribute__(self, "_result").target
        if target is None:
            raise AttributeError(name)
        return getattr(target, name)

    def __repr__(self) -> str:
        return f"<JobHandle {self._result.plan!r} out={self._result.egress_count}>"


# ----------------------------------------------------------------- session
class Session:
    """Streaming execution handle returned by ``engine.open(plan)``.

    Protocol: ``push(tuples)`` feeds the pipeline incrementally (blocking
    backpressure once the in-flight window fills), ``results()`` iterates
    ordered egress as it materializes, ``stats()`` samples live state, and
    ``close()`` seals the input, drains every in-flight tuple, tears the
    backend down, and returns the final :class:`~.runtime.RunReport` (also
    stored as ``session.report``).  Context-manager aware (``with
    engine.open(plan) as s: ...`` closes on exit, aborting on error).
    Sessions force ``collect_outputs`` on so egress is observable; one
    caller thread drives a session (its methods are not re-entrant).
    """

    backend = "?"

    def __init__(self):
        self.report: Optional[RunReport] = None
        self._pushed = 0
        self._cursor = 0  # absolute egress index of the next unread output
        self._trimmed = 0  # outputs already released from the backing list
        self._closed = False
        self._aborted = False  # error-path teardown: backend state is gone
        self._t0 = time.perf_counter()

    # -- surface ------------------------------------------------------------
    #: consumed-prefix length at which results() trims the backing output
    #: list — long-lived serving sessions must not hold every egressed tuple
    _TRIM_THRESHOLD = 4096

    def push(self, tuples: Iterable[Any]) -> int:
        """Feed an iterable of tuples into the live pipeline, in order;
        returns how many were pushed.  Blocks (backpressure) when the
        backend's in-flight window is full.  Raises ``RuntimeError`` once
        the session is closed (or when a worker failed)."""
        if self._closed:
            raise RuntimeError("session is closed")
        n = 0
        for value in tuples:
            self._push_one(value)
            n += 1
            self._pushed += 1  # counted per tuple: a mid-iterable failure
            # must not uncount tuples that already entered the pipeline
        return n

    def try_push(self, value: Any) -> bool:
        """Non-blocking single-tuple push: ``True`` if the tuple entered the
        pipeline, ``False`` if the backend's in-flight window is full right
        now (the caller may retry, service results, or shed load).  This is
        the ingress primitive multiplexers build fairness on — a blocked
        ``push()`` would hold *every* queued session hostage to global
        backpressure, ``try_push`` lets the caller keep draining egress
        while the window is full.  Raises like :meth:`push` once closed."""
        if self._closed:
            raise RuntimeError("session is closed")
        if not self._try_push_one(value):
            return False
        self._pushed += 1
        return True

    def poll(self, max_items: Optional[int] = None) -> list:
        """Non-blocking egress read: return (and consume) whatever ordered
        outputs have already materialized — possibly ``[]`` — without ever
        waiting.  Shares the exactly-once cursor with :meth:`results`; use
        one or the other per drain phase, not both concurrently.  Unlike
        ``results()`` this never services the backend, so a process-backend
        caller interleaving only ``try_push``/``poll`` should expect to see
        progress ride on its pushes."""
        if self._aborted:
            raise RuntimeError(
                "session was aborted (error-path teardown); "
                "results are unavailable"
            )
        consumed = self._cursor - self._trimmed
        if consumed >= self._TRIM_THRESHOLD:
            self._discard_consumed(consumed)
            self._trimmed = self._cursor
            consumed = 0
        batch = self._outputs_since(consumed)
        if max_items is not None:
            batch = batch[:max_items]
        self._cursor += len(batch)
        return batch

    def service(self) -> None:
        """One liveness crank for non-blocking drivers.

        Callers that interleave :meth:`try_push` / :meth:`poll` (instead of
        the blocking ``results()`` loop, which services internally) must
        call this when idle: it flushes partial ingress micro-batches and —
        on the process backend — cranks the single-threaded parent
        supervisor, without which nothing would ever egress."""
        self._idle_service(64)

    def results(self, max_items: Optional[int] = None,
                timeout: Optional[float] = None) -> Iterator[Any]:
        """Iterate ordered egress tuples as they materialize.

        Yields every output exactly once across all ``results()`` calls, in
        egress (= serial) order.  The iterator ends when the session is
        closed and fully drained; before that it waits for more output —
        bounded by ``timeout`` seconds of *continuous* starvation when given
        (the clock resets whenever an output arrives; on expiry it raises
        :class:`SessionStarvation` carrying a live per-stage backlog/
        heartbeat snapshot).  ``max_items`` bounds this call.  Consumed outputs
        are released from memory as the iterator advances, so an indefinite
        session stays bounded by its in-flight window, not its history.
        """
        deadline = None if timeout is None else time.perf_counter() + timeout
        yielded = 0
        starved = 0
        while max_items is None or yielded < max_items:
            if self._aborted:
                raise RuntimeError(
                    "session was aborted (error-path teardown); "
                    "results are unavailable"
                )
            consumed = self._cursor - self._trimmed
            if consumed >= self._TRIM_THRESHOLD:
                self._discard_consumed(consumed)
                self._trimmed = self._cursor
                consumed = 0
            batch = self._outputs_since(consumed)
            if batch:
                starved = 0
                if timeout is not None:  # starvation clock resets on arrival
                    deadline = time.perf_counter() + timeout
                for value in batch:
                    self._cursor += 1
                    yielded += 1
                    yield value
                    if max_items is not None and yielded >= max_items:
                        return
                continue
            if self._drained_after_close():
                return
            if deadline is not None and time.perf_counter() > deadline:
                snap = self._starvation_snapshot()
                raise SessionStarvation(
                    f"session.results() starved: no output for {timeout}s "
                    f"(pushed={self._pushed}, egressed so far="
                    f"{self._cursor}); live snapshot: {snap}",
                    snapshot=snap,
                )
            starved += 1
            self._idle_service(starved)

    def stats(self) -> dict:
        """Live counters: tuples pushed/egressed plus backend-specific
        occupancy (scheduler snapshot or stage widths/backlog)."""
        raise NotImplementedError

    def offer_load(self, signals: dict) -> None:
        """Feed a serving-tier load snapshot to the backend.

        ``signals`` is a :meth:`repro.serve.SessionMux.load_signals`-shaped
        dict (``ts``, ``sessions``, ``admitted_total``, ``ingress_queued``,
        ``backpressured``).  The process backend forwards it to the
        traffic-reactive elasticity policy
        (:class:`~repro.core.TrafficMonitor`); other backends ignore it.
        Must be called from the thread that owns the session."""

    def service_once(self) -> bool:
        """One *non-blocking* backend progress crank; ``True`` if it did work.

        Unlike :meth:`service` this never sleeps and never flushes partial
        micro-batches, so a pump loop may call it every iteration: on the
        process backend it advances the single-threaded parent supervisor
        (whose progress would otherwise ration on ``try_push``/``poll``
        side effects under steady paced traffic); on backends whose workers
        make progress on their own threads it is a no-op."""
        return False

    def close(self, drain_timeout: float = 60.0) -> RunReport:
        """Seal the input, drain every in-flight tuple, stop the backend,
        and return the final report (idempotent)."""
        raise NotImplementedError

    # -- plumbing (backend hooks) --------------------------------------------
    # _outputs_since/_discard_consumed index into the backing output list
    # RELATIVE to the already-trimmed prefix (the base class does the
    # absolute-cursor bookkeeping).
    def _push_one(self, value: Any) -> None:
        raise NotImplementedError

    def _try_push_one(self, value: Any) -> bool:
        raise NotImplementedError

    def _outputs_since(self, cursor: int) -> list:
        raise NotImplementedError

    def _discard_consumed(self, n: int) -> None:
        raise NotImplementedError

    def _drained_after_close(self) -> bool:
        raise NotImplementedError

    def _idle_service(self, starved: int) -> None:
        raise NotImplementedError

    def _starvation_snapshot(self) -> dict:
        """Live state attached to :class:`SessionStarvation`; backends with
        richer liveness signals (heartbeats, backlog) extend ``stats()``."""
        try:
            return self.stats()
        except Exception:  # diagnostics must not mask the starvation raise
            return {}

    def _abort(self) -> None:
        raise NotImplementedError

    # -- context manager -----------------------------------------------------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            if not self._closed:
                self.close()
        else:  # error path: tear down without insisting on a clean drain
            self._abort()


class _ThreadSession(Session):
    """Session over the threaded runtime: worker threads process pushes
    concurrently; reads snapshot the pipeline's ordered output list."""

    backend = "thread"

    def __init__(self, pipeline: GraphPipeline, runtime: StreamRuntime):
        super().__init__()
        self._pipe = pipeline
        self._rt = runtime
        # Input-side backpressure bound: worklists are unbounded deques, so
        # without a gate an over-fast producer grows them without limit —
        # the same indefinite-session leak the output-side trim closes.
        self._inflight_cap = max(
            2 * getattr(pipeline, "batch_size", 1) * 64,
            2048,
        )
        # the gate's worklist sweep costs O(n_ops) locks: amortize it over
        # _GATE_EVERY pushes (backlog bound becomes cap + _GATE_EVERY)
        self._gate_left = 0
        runtime.start()

    _GATE_EVERY = 64

    def _push_one(self, value: Any) -> None:
        pipe = self._pipe
        if self._gate_left <= 0:
            self._gate_left = self._GATE_EVERY
            while sum(n.worklist_size() for n in pipe.nodes) >= self._inflight_cap:
                if self._rt.worker_error is not None:
                    raise RuntimeError(
                        f"worker failed: {self._rt.worker_error!r}"
                    ) from self._rt.worker_error
                time.sleep(1e-4)  # workers drain concurrently; no deadlock
        self._gate_left -= 1
        pipe.push(value)

    def _try_push_one(self, value: Any) -> bool:
        # same amortized gate as _push_one, but a closed gate reports False
        # instead of spinning; the re-check happens on the next attempt
        if self._gate_left <= 0:
            if self._rt.worker_error is not None:
                raise RuntimeError(
                    f"worker failed: {self._rt.worker_error!r}"
                ) from self._rt.worker_error
            pipe = self._pipe
            if sum(n.worklist_size() for n in pipe.nodes) >= self._inflight_cap:
                return False
            self._gate_left = self._GATE_EVERY
        self._gate_left -= 1
        self._pipe.push(value)
        return True

    def _outputs_since(self, cursor: int) -> list:
        return self._pipe.outputs_since(cursor)

    def _discard_consumed(self, n: int) -> None:
        self._pipe.consume_outputs(n)

    def _drained_after_close(self) -> bool:
        return self._closed and self._pipe.drained()

    def _idle_service(self, starved: int) -> None:
        if self._rt.worker_error is not None:
            raise RuntimeError(
                f"worker failed: {self._rt.worker_error!r}"
            ) from self._rt.worker_error
        if starved % 64 == 0:
            # liveness under micro-batching: a partial ingress batch can hold
            # the very tuples a results() reader is waiting for
            self._pipe.flush()
        time.sleep(1e-4)

    def stats(self) -> dict:
        """Live thread-backend counters (see :meth:`Session.stats`)."""
        return {
            "backend": self.backend,
            "closed": self._closed,
            "pushed": self._pushed,
            "egressed": self._pipe.egress_count,
            "workers": self._rt.num_workers,
            "ops": self._rt.scheduler.snapshot(),
        }

    def close(self, drain_timeout: float = 60.0) -> RunReport:
        """Flush, drain, stop the worker threads, report (idempotent)."""
        if self._closed:
            if self.report is None:
                raise RuntimeError("session aborted before close()")
            return self.report
        self._closed = True
        self._pipe.flush()
        deadline = time.perf_counter() + drain_timeout
        while not self._pipe.drained():
            if self._rt.worker_error is not None:
                self._abort()
                raise RuntimeError(
                    f"worker failed: {self._rt.worker_error!r}"
                ) from self._rt.worker_error
            if time.perf_counter() > deadline:
                self._rt.stop()
                raise TimeoutError("session failed to drain")
            time.sleep(1e-4)
        self._rt.stop()
        self.report = self._rt.make_report(
            self._pushed, time.perf_counter() - self._t0
        )
        return self.report

    def _abort(self) -> None:
        self._closed = True
        self._aborted = True
        self._rt.stop()


class _ProcessSession(Session):
    """Session over :class:`~.procrun.ProcessRuntime`: pushes feed the
    stage-0 exchange incrementally (no finite iterable needed) and every
    call cranks the single-threaded parent supervisor."""

    backend = "process"

    def __init__(self, runtime: ProcessRuntime):
        super().__init__()
        self._rt = runtime
        runtime.start_stream()

    def _push_one(self, value: Any) -> None:
        self._rt.stream_push(value)

    def _try_push_one(self, value: Any) -> bool:
        return self._rt.stream_try_push(value)

    def _outputs_since(self, cursor: int) -> list:
        return self._rt.collected_outputs()[cursor:]

    def _discard_consumed(self, n: int) -> None:
        # parent-side list, mutated only from the caller's thread
        del self._rt.collected_outputs()[:n]

    def _drained_after_close(self) -> bool:
        return self._closed and (
            self.report is not None or self._rt.stream_drained()
        )

    def _idle_service(self, starved: int) -> None:
        # the parent is single-threaded: a starved reader must crank the
        # supervisor itself or nothing will ever egress
        if not self._rt._service_once():
            time.sleep(1e-4)

    def offer_load(self, signals: dict) -> None:
        """Forward serving-tier load signals to the supervisor's traffic
        monitor (see :meth:`Session.offer_load`)."""
        self._rt.observe_traffic(signals)

    def service_once(self) -> bool:
        """Bounded non-blocking supervisor sweep (see
        :meth:`Session.service_once`): cranks until a pass reports no
        progress (cap 64), so one call drains whatever the workers have
        ready instead of rationing one crank's worth per call — a fixed
        per-crank overhead (ring scans, unpickling, the serial tail) would
        otherwise cap paced throughput far below flood throughput."""
        rt = self._rt
        did = False
        for _ in range(64):
            if not rt._service_once():
                break
            did = True
        return did

    def stats(self) -> dict:
        """Live process-backend counters (see :meth:`Session.stats`)."""
        rt = self._rt
        return {
            "backend": self.backend,
            "closed": self._closed,
            "pushed": self._pushed,
            "egressed": rt.egress_count,
            "stage_widths": rt.stage_widths(),
            "backlog_slots": [x.backlog_slots() for x in rt._exchanges],
            "heartbeats": [x.heartbeats() for x in rt._exchanges],
            "replans": rt.replans,
            "restarts": rt.restarts,
            "recoveries": rt.recoveries,
            "dead_letters": len(rt.dead_letters),
            "grows": rt.grows,
            "shrinks": rt.shrinks,
            "resize_stalls": list(rt.resize_stalls),
            "resize_aborts": rt.resize_aborts,
            "resize_reverts": rt.resize_reverts,
        }

    def close(self, drain_timeout: float = 60.0) -> RunReport:
        """Seal input, drain through every stage, tear down the worker
        groups, report (idempotent)."""
        if self._closed:
            if self.report is None:
                raise RuntimeError("session aborted before close()")
            return self.report
        self._closed = True
        self.report = self._rt.finish_stream(drain_timeout)
        return self.report

    def _abort(self) -> None:
        self._closed = True
        self._aborted = True
        self._rt.stop()


# ------------------------------------------------------------------- engine
class Engine:
    """Execution engine owning backend selection: compile → plan → execute.

    Construct from an :class:`EngineConfig` (or legacy flat keywords, parsed
    strictly) and use:

    - :meth:`plan` — derive an inspectable :class:`PhysicalPlan` from a
      graph (no processes are forked, nothing runs);
    - :meth:`run` — execute a plan (or plan-on-the-fly from a graph) over a
      finite source, returning a :class:`JobResult`;
    - :meth:`open` — start a streaming :class:`Session` over the plan.

    ::

        engine = Engine(EngineConfig(backend="process", num_workers="auto"))
        plan = engine.plan(specs)
        print(plan.explain())
        result = engine.run(plan, source)
        with engine.open(plan) as s:
            s.push(batch)
            for out in s.results(max_items=10):
                ...
    """

    def __init__(self, config: Optional[EngineConfig] = None, **kw):
        if config is None:
            config = EngineConfig.from_kwargs(**kw)
        elif kw:
            raise ConfigError(
                "pass either an EngineConfig or flat keywords, not both"
            )
        if not isinstance(config, EngineConfig):
            raise ConfigError(
                f"config must be an EngineConfig, got {type(config).__name__}"
            )
        self.config = config.validate()

    # ----------------------------------------------------------------- plan
    def plan(self, graph, edges=None) -> PhysicalPlan:
        """Compile ``graph`` into a :class:`PhysicalPlan` without running it.

        ``graph`` is a chain (sequence of :class:`~.operators.OpSpec`), a
        ``(nodes, edges)`` tuple, or a node dict with ``edges`` passed
        separately.  For the process backend this cuts stages, prices them
        with the cost model (priors or explicit ``cost_priors`` — run-time
        calibration only refines plans made *at* run time), and records the
        exchange-ring geometry; ``plan.unstaged`` names every node left in
        the serial parent tail, and — exactly as execution would — planning
        emits :class:`~.procrun.UnstagedGraphWarning` when routing nodes
        (``Split``/``Merge``) are among them.
        """
        nodes, edge_list, _specs = _normalize_graph(graph, edges)
        cfg = self.config
        op_rows, routing = graph_flows(nodes, edge_list, cfg.cost_priors)
        ops = _planned_ops(op_rows)
        if cfg.backend == "thread":
            plan = PhysicalPlan(
                backend="thread", config=cfg, ops=ops, routing=routing,
                graph=(nodes, edge_list),
            )
        else:
            rt = self._make_process_runtime(nodes, edge_list)
            plan = self._describe_process(rt, ops, routing, (nodes, edge_list))
        # Engine-built plans hold by construction; verifying here keeps the
        # catalog honest (a planner bug surfaces at plan time, not run time).
        plan.verify()
        return plan

    # ------------------------------------------------------------------ run
    def run(self, plan_or_graph, source: Iterable, *, edges=None,
            drain_timeout: float = 60.0) -> JobResult:
        """Execute over a finite ``source`` and drain; returns
        :class:`JobResult`.

        Accepts a bound :class:`PhysicalPlan` (its stage widths are pinned —
        elastic replanning, when enabled, may still adjust them live) or any
        :meth:`plan` graph form (planned on the fly; ``num_workers="auto"``
        without priors then also runs the calibration pass).  The result's
        ``plan`` field describes what actually executed, including
        post-replan widths.
        """
        cfg = self.config
        plan, nodes, edge_list, chain_specs, pinned = self._resolve(
            plan_or_graph, edges
        )

        if cfg.backend == "thread":
            pipe, rt = self._build_thread(nodes, edge_list, chain_specs)
            report = rt.run(source, drain_timeout=drain_timeout)
            if plan is None:
                op_rows, routing = graph_flows(nodes, edge_list, cfg.cost_priors)
                plan = PhysicalPlan(
                    backend="thread", config=cfg, ops=_planned_ops(op_rows),
                    routing=routing, graph=(nodes, edge_list),
                )
            return JobResult(
                outputs=pipe.outputs, report=report, plan=plan,
                markers=list(pipe.markers), egress_count=pipe.egress_count,
                target=pipe,
            )

        rt = self._make_process_runtime(nodes, edge_list, stage_widths=pinned)
        report = rt.run(source, drain_timeout=drain_timeout)
        op_rows, routing = graph_flows(nodes, edge_list, cfg.cost_priors)
        executed = self._describe_process(
            rt, _planned_ops(op_rows), routing, (nodes, edge_list)
        )
        return JobResult(
            outputs=rt.outputs, report=report, plan=executed,
            markers=list(rt.markers), egress_count=rt.egress_count,
            replans=rt.replans, restarts=rt.restarts,
            recoveries=rt.recoveries, dead_letters=list(rt.dead_letters),
            target=rt,
        )

    # ----------------------------------------------------------------- open
    def open(self, plan_or_graph, edges=None) -> Session:
        """Open a streaming :class:`Session` over a plan or graph.

        The session forces ``collect_outputs`` on (its ``results()``
        iterator is the egress).  Process-backend sessions size
        ``workers="auto"`` from priors only — there is no source to
        calibrate on — and rely on elastic replanning to adapt live.
        """
        cfg = self.config
        _plan, nodes, edge_list, chain_specs, pinned = self._resolve(
            plan_or_graph, edges
        )
        if cfg.backend == "thread":
            pipe, rt = self._build_thread(
                nodes, edge_list, chain_specs, collect=True
            )
            return _ThreadSession(pipe, rt)
        rt = self._make_process_runtime(
            nodes, edge_list, stage_widths=pinned, collect=True
        )
        return _ProcessSession(rt)

    # ------------------------------------------------------------ internals
    def _resolve(self, plan_or_graph, edges):
        """Shared plan-vs-graph resolution for :meth:`run` / :meth:`open`:
        returns ``(plan_or_None, nodes, edges, chain_specs, pinned_widths)``,
        rejecting plans made for the other backend."""
        if isinstance(plan_or_graph, PhysicalPlan):
            plan = plan_or_graph
            if plan.backend != self.config.backend:
                raise ConfigError(
                    f"plan was made for backend={plan.backend!r} but this "
                    f"engine runs backend={self.config.backend!r}"
                )
            plan.verify()  # a hand-edited plan must not reach execution
            nodes, edge_list = plan.graph
            return plan, nodes, edge_list, None, (
                plan.stage_widths() if plan.stages else None
            )
        nodes, edge_list, chain_specs = _normalize_graph(plan_or_graph, edges)
        return None, nodes, edge_list, chain_specs, None

    def _build_thread(self, nodes, edges, chain_specs=None,
                      collect: Optional[bool] = None):
        cfg = self.config
        num_workers = resolve_workers(cfg.num_workers)
        collect_outputs = cfg.collect_outputs if collect is None else collect
        # chains keep their CompiledPipeline face (legacy `.specs` surface)
        if chain_specs is None and all(
            isinstance(s, OpSpec) for s in nodes.values()
        ):
            order = [name for name, _spec in _topo_ops(nodes, edges)]
            if list(edges) == list(zip(order, order[1:])):
                chain_specs = [nodes[n] for n in order]
        pipe_kw = dict(
            reorder_scheme=cfg.reorder_scheme,
            worklist_scheme=cfg.worklist_scheme,
            num_workers=num_workers,
            collect_outputs=collect_outputs,
            marker_interval=cfg.marker_interval,
            batch_size=cfg.batch_size,
            reorder_size=cfg.reorder_size,
        )
        if chain_specs is not None:
            pipe = CompiledPipeline(chain_specs, **pipe_kw)
        else:
            pipe = GraphPipeline(nodes, edges, **pipe_kw)
        t = cfg.thread
        rt = StreamRuntime(
            pipe,
            num_workers=num_workers,
            heuristic=t.heuristic,
            cost_priors=cfg.cost_priors,
            time_slice=t.time_slice,
            capacity=t.capacity,
            window=t.window,
            adapt_interval=t.adapt_interval,
        )
        return pipe, rt

    def _make_process_runtime(self, nodes, edges, stage_widths=None,
                              collect: Optional[bool] = None) -> ProcessRuntime:
        cfg = self.config
        p = cfg.process
        return ProcessRuntime(
            nodes,
            edges,
            num_workers=cfg.num_workers,
            marker_interval=cfg.marker_interval,
            collect_outputs=cfg.collect_outputs if collect is None else collect,
            io_batch=p.io_batch,
            batch_size=cfg.batch_size,
            stages=p.stages,
            ring_slots=p.ring_slots,
            slot_bytes=p.slot_bytes,
            reorder_size=cfg.reorder_size,
            reorder_payload=p.reorder_payload,
            max_inflight=p.max_inflight,
            restart_on_crash=p.restart_on_crash,
            reorder_scheme=cfg.reorder_scheme,
            worklist_scheme=cfg.worklist_scheme,
            worker_budget=p.worker_budget,
            cost_priors=cfg.cost_priors,
            elastic=p.elastic,
            calibrate_tuples=p.calibrate_tuples,
            replan_interval=p.replan_interval,
            replan_threshold=p.replan_threshold,
            replan_patience=p.replan_patience,
            traffic_elastic=p.traffic_elastic,
            traffic_interval=p.traffic_interval,
            traffic_grow_util=p.traffic_grow_util,
            traffic_shrink_util=p.traffic_shrink_util,
            traffic_patience=p.traffic_patience,
            traffic_cooldown=p.traffic_cooldown,
            resize_latency_budget=p.resize_latency_budget,
            parent_idle_cap=p.parent_idle_cap,
            columnar=p.columnar,
            device_batch=p.device_batch,
            device_workers=p.device_workers,
            device_inflight=p.device_inflight,
            device_backend=p.device_backend,
            checkpoint_interval=p.checkpoint_interval,
            stall_timeout=p.stall_timeout,
            spill_timeout=p.spill_timeout,
            fault_plan=cfg.faults.plan,
            on_error=cfg.faults.on_error,
            stage_widths=stage_widths,
        )

    def _describe_process(self, rt: ProcessRuntime, ops, routing,
                          graph) -> PhysicalPlan:
        profiles = rt.cost_model.profiles
        total = sum(p.load for p in profiles) or 1.0
        stages = [
            PlannedStage(
                index=plan.index,
                kind=plan.kind,
                ops=[op.name for op in plan.ops],
                workers=plan.workers,
                max_workers=max(plan.max_workers, plan.workers),
                cost_us=round(prof.cost_us, 3),
                flow=round(prof.flow, 4),
                load_share=round(prof.load / total, 4),
                checkpointed=rt._ckpt_enabled(plan.index),
            )
            for plan, prof in zip(rt.stage_plans, profiles)
        ]
        ring = {
            "io_batch": rt.io_batch,
            "max_inflight": rt.max_inflight,
            "ring_slots": rt.ring_slots,
            "slot_bytes": rt.slot_bytes,
            "reorder_size": rt.reorder_size,
            "reorder_payload": rt.reorder_payload,
            # effective epoch length: barriers stamp at dispatch-unit
            # boundaries, so the interval never undercuts io_batch (PV407)
            "checkpoint_interval": (
                max(rt.checkpoint_interval, rt.io_batch)
                if any(s.checkpointed for s in stages) else 0
            ),
            "columnar": int(rt.columnar),
            "device_batch": rt.device_batch,
            "device_workers": rt.device_workers,
            "device_inflight": rt.device_inflight,
        }
        return PhysicalPlan(
            backend="process", config=self.config, ops=ops, routing=routing,
            stages=stages, unstaged=rt.tail_node_names, ring=ring,
            worker_budget=rt.worker_budget, graph=graph,
        )


def _planned_ops(op_rows) -> List[PlannedOp]:
    total = sum(flow * cost for _n, _s, flow, cost in op_rows) or 1.0
    ops = []
    for _name, spec, flow, cost in op_rows:
        if spec.kind == STATEFUL:
            max_dop: Optional[int] = 1
        elif spec.kind == PARTITIONED:
            max_dop = spec.num_partitions
        else:
            max_dop = None
        schema_width = (
            spec.schema.width
            if spec.kind == DEVICE and spec.schema is not None else None
        )
        ops.append(
            PlannedOp(
                name=spec.name,
                kind=spec.kind,
                cost_us=round(cost, 3),
                selectivity=round(float(spec.selectivity), 4),
                flow=round(flow, 4),
                load_share=round(flow * cost / total, 4),
                max_dop=max_dop,
                schema_width=schema_width,
            )
        )
    return ops
