"""Dataflow-graph pipeline compilation (paper §2, §6).

The runtime executes a *dataflow DAG* of operators (the paper's computation
model): every ``OpSpec`` node becomes an :class:`~.operators.OperatorNode`
with its own worklist + reorder buffer, and edges wire one node's ordered
egress into the next node's worklist.  Two routing primitives generalize the
topology beyond linear chains while preserving ordered semantics:

- :class:`Split` — fan-out.  Routes each incoming tuple to exactly one of B
  branches (``policy="round_robin"`` or ``policy="keyed"`` with a ``key_fn``)
  and stamps it with a monotone *ticket* plus a :class:`_Frame` that counts the
  tuple's in-flight descendants between the split and its matching merge.
- :class:`Merge` — fan-in.  Collects each ticket's outputs (a frame completes
  when its descendant count hits zero, so filtered-out tuples punch their hole
  in the sequence instead of stalling it) and re-interleaves completed tickets
  in split-ingress order through the existing
  :class:`~.reorder.NonBlockingReorderBuffer`; overflow completions beyond the
  ring window are parked in a pending dict and retried — never spun on — so a
  single worker cannot livelock.

Because every path between a split and its merge preserves FIFO order (each
node's reorder buffer guarantees egress in push order), and the merge restores
ticket order across branches, a ``split -> branches -> merge`` region is
serial-order-equivalent: the DAG's egress equals the single-threaded reference.

Public API:

  ``GraphPipeline(nodes, edges, **opts)``
      ``nodes``: ``{name: OpSpec | Split | Merge}``;
      ``edges``: ``[(src_name, dst_name), ...]``.  The unique node with no
      incoming edge is the ingress; the unique node with no outgoing edge is
      the egress.  Only ``Split`` nodes may have out-degree > 1; only
      ``Merge`` nodes may have in-degree > 1.  Split/merge pairs may nest.
  ``CompiledPipeline(specs, **opts)``
      The linear-chain API, now a thin wrapper that lowers ``specs`` to a
      chain-shaped ``GraphPipeline``.

Latency markers (paper §7) are injected every ``marker_interval`` tuples at
ingress (atomically — concurrent producers each observe a unique count).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .operators import (
    DEVICE,
    OpSpec,
    OperatorNode,
    PARTITIONED,
    STATEFUL,
    STATELESS,
    _Marker,
)
from .reorder import NonBlockingReorderBuffer, ParkingReorderBuffer
from .serial import AtomicLong, SerialAssigner


def percentile_latencies(
    markers: Sequence[_Marker], lo: float = 0.2, hi: float = 0.8
) -> List[float]:
    """Processing latency (begin->exit) of completed markers in the [lo, hi]
    percentile range of arrival — the paper's §7 measurement protocol.
    Shared by every runtime so thread and process backends report over the
    same window convention."""
    ms = sorted((m for m in markers if m.exit and m.begin), key=lambda m: m.entry)
    if not ms:
        return []
    a, b = int(len(ms) * lo), max(int(len(ms) * hi), int(len(ms) * lo) + 1)
    return [m.exit - m.begin for m in ms[a:b]]


# --------------------------------------------------------------------- routing
class Split:
    """Fan-out routing node spec: one inbound edge, B outbound branches.

    ``round_robin`` balances load; ``keyed`` routes tuples with equal
    ``key_fn(value)`` to the same branch (hash-partitioned), which keeps
    partitioned-stateful operators inside branches semantics-preserving.
    """

    def __init__(self, policy: str = "round_robin", key_fn: Optional[Callable] = None):
        if policy not in ("round_robin", "keyed"):
            raise ValueError(f"unknown split policy {policy!r}")
        if policy == "keyed" and key_fn is None:
            raise ValueError("keyed split needs key_fn")
        self.policy = policy
        self.key_fn = key_fn


class Merge:
    """Fan-in routing node spec: B inbound branches, one outbound edge.

    Re-interleaves per-ticket output bundles in split-ingress order via a
    :class:`NonBlockingReorderBuffer` so ordered semantics survive fan-in.
    """

    def __init__(self, reorder_size: int = 1024):
        self.reorder_size = reorder_size


class _Frame:
    """In-flight descendant accounting for one split ticket.

    ``count`` = tuples derived from this ticket that are alive between the
    split and the merge.  An operator producing k outputs from one input adds
    k-1 *before* emitting (creation happens-before consumption, so the count
    can only reach 0 once every descendant has arrived at the merge or been
    filtered out).  Arrived values accumulate in path-FIFO order, which equals
    depth-first serial order along the (single) branch path of the ticket.
    """

    __slots__ = ("ticket", "merge", "values", "markers", "_count", "_lock")

    def __init__(self, ticket: int, merge: "_MergeRouter"):
        self.ticket = ticket
        self.merge = merge
        self.values: list = []  # guarded-by: self._lock
        self.markers: list = []  # guarded-by: self._lock
        self._count = 1  # guarded-by(rw): self._lock
        self._lock = threading.Lock()

    def add(self, delta: int) -> None:
        """Account an operator turning one descendant into 1 + delta."""
        with self._lock:
            self._count += delta
            done = self._count == 0
        if done:
            self.merge.complete(self)

    def arrive(self, value: Any, marker: Optional[_Marker]) -> None:
        with self._lock:
            self.values.append(value)
            if marker is not None:
                self.markers.append(marker)
            self._count -= 1
            done = self._count == 0
        if done:
            self.merge.complete(self)


class _Envelope:
    """A value traveling inside one or more nested split/merge regions."""

    __slots__ = ("frames", "payload")

    def __init__(self, frames: Tuple[_Frame, ...], payload: Any):
        self.frames = frames
        self.payload = payload


class _SplitRouter:
    """Executable form of :class:`Split`: stamps tickets, routes to branches."""

    def __init__(self, spec: Split, branches: List[Callable], merge: "_MergeRouter"):
        self.spec = spec
        self.branches = branches  # push callables of the branch head nodes
        self.merge = merge
        self._tickets = SerialAssigner()
        self._rr = AtomicLong(0)

    def route(self, value: Any, marker: Optional[_Marker]) -> None:
        payload = value.payload if isinstance(value, _Envelope) else value
        outer = value.frames if isinstance(value, _Envelope) else ()
        ticket = self._tickets.next()
        frame = _Frame(ticket, self.merge)
        if self.spec.policy == "round_robin":
            b = self._rr.fetch_add(1) % len(self.branches)
        else:
            b = hash(self.spec.key_fn(payload)) % len(self.branches)
        self.branches[b](_Envelope(outer + (frame,), payload), marker)


class _MergeRouter:
    """Executable form of :class:`Merge`: ordered fan-in.

    Completed tickets go through a NonBlockingReorderBuffer keyed on the split
    ticket, behind the :class:`ParkingReorderBuffer` overflow facade — a
    ticket completing beyond the ring window (while an earlier ticket is still
    in flight) parks instead of spinning, so a lone worker completing tickets
    far ahead cannot livelock the runtime.
    """

    def __init__(self, spec: Merge):
        self.downstream: Optional[Callable[[Any, Optional[_Marker]], None]] = None
        self._reorder = ParkingReorderBuffer(
            NonBlockingReorderBuffer(self._emit_bundle, size=spec.reorder_size)
        )

    def arrive(self, value: Any, marker: Optional[_Marker]) -> None:
        assert isinstance(value, _Envelope), "merge reached by un-split tuple"
        value.frames[-1].arrive(
            _Envelope(value.frames[:-1], value.payload) if len(value.frames) > 1
            else value.payload,
            marker,
        )

    def complete(self, frame: _Frame) -> None:
        self._reorder.send(frame.ticket, (frame.values, frame.markers))

    def pending_count(self) -> int:
        return self._reorder.parked_count()

    def _emit_bundle(self, bundle: tuple) -> None:
        values, markers = bundle
        down = self.downstream
        markers = list(markers)
        for v in values:
            down(v, markers.pop(0) if markers else None)
        for m in markers:  # markers whose tuples were filtered inside the region
            m.exit = time.perf_counter()
            if self.on_marker_drop is not None:
                self.on_marker_drop(m)

    on_marker_drop: Optional[Callable[[_Marker], None]] = None


# --------------------------------------------------------- envelope adaptation
def _wrap_spec(spec: OpSpec) -> OpSpec:
    """Derive a spec whose fn transparently handles :class:`_Envelope` values.

    Inside a split/merge region every value is enveloped; the adapter unwraps
    the payload for the user fn, re-wraps outputs (descendants inherit the
    frame stack), and accounts len(outs)-1 on every enclosing frame *before*
    the outputs are emitted (see :class:`_Frame`).
    """

    def adapt(outs: list, value: Any) -> list:
        if not isinstance(value, _Envelope):
            return outs
        for f in value.frames:
            f.add(len(outs) - 1)
        return [_Envelope(value.frames, o) for o in outs]

    if spec.kind in (STATELESS, DEVICE):
        fn = spec.fn

        def fn_sl(value):
            payload = value.payload if isinstance(value, _Envelope) else value
            return adapt(fn(payload), value)

        new_fn, new_key = fn_sl, None
    elif spec.kind == STATEFUL:
        fn = spec.fn

        def fn_sf(state, value):
            payload = value.payload if isinstance(value, _Envelope) else value
            state, outs = fn(state, payload)
            return state, adapt(outs, value)

        new_fn, new_key = fn_sf, None
    else:  # PARTITIONED
        fn, key_fn = spec.fn, spec.key_fn

        def fn_ps(state, key, value):
            payload = value.payload if isinstance(value, _Envelope) else value
            state, outs = fn(state, key, payload)
            return state, adapt(outs, value)

        def new_key(value):
            return key_fn(value.payload if isinstance(value, _Envelope) else value)

        new_fn = fn_ps

    return OpSpec(
        name=spec.name,
        kind=spec.kind,
        fn=new_fn,
        key_fn=new_key,
        num_partitions=spec.num_partitions,
        partitioner=spec.partitioner,
        init_state=spec.init_state,
        cost_us=spec.cost_us,
        selectivity=spec.selectivity,
        schema=spec.schema,
        device_kernel=spec.device_kernel,
        device_batch=spec.device_batch,
        device_backend=spec.device_backend,
    )


# ---------------------------------------------------------------- GraphPipeline
NodeSpec = Union[OpSpec, Split, Merge]


class GraphPipeline:
    """Compiled dataflow DAG (see module docstring for the API)."""

    def __init__(
        self,
        nodes: Dict[str, NodeSpec],
        edges: Sequence[Tuple[str, str]],
        *,
        reorder_scheme: str = "non_blocking",
        worklist_scheme: str = "hybrid",
        reorder_size: int = 1024,
        num_workers=1,  # int, or "auto" for one worker per core
        marker_interval: int = 64,
        collect_outputs: bool = False,
        batch_size: int = 1,
    ):
        from .costmodel import resolve_workers  # late: pipeline loads first

        num_workers = resolve_workers(num_workers)
        self.node_specs = dict(nodes)
        self.edges = [tuple(e) for e in edges]
        self.marker_interval = marker_interval
        self.collect_outputs = collect_outputs
        self.outputs: list = []  # guarded-by: self._egress_lock
        self.markers: list[_Marker] = []  # guarded-by: self._markers_lock
        self._markers_lock = threading.Lock()
        self._egress_count = 0  # guarded-by: self._egress_lock
        self._egress_lock = threading.Lock()
        self._ingress = AtomicLong(0)
        # lock-free: written once by the producer whose fetch_add claimed n==1
        self._first_push_ts: Optional[float] = None
        self._last_egress_ts: Optional[float] = None  # guarded-by: self._egress_lock
        # Micro-batching applies to plain operator chains; routing nodes keep
        # per-tuple granularity (ticket/frame accounting is per tuple), so a
        # graph with Split/Merge clamps the batch size back to 1.
        has_routing = any(
            isinstance(s, (Split, Merge)) for s in self.node_specs.values()
        )
        self.batch_size = 1 if has_routing else max(1, batch_size)
        self._accum_vals: list = []  # guarded-by: self._accum_lock
        self._accum_marks: list[_Marker] = []  # guarded-by: self._accum_lock
        self._accum_lock = threading.Lock()

        order = self._topo_order()
        succ: dict[str, list[str]] = {n: [] for n in self.node_specs}
        pred: dict[str, list[str]] = {n: [] for n in self.node_specs}
        for u, v in self.edges:
            succ[u].append(v)
            pred[v].append(u)
        self._validate_degrees(succ, pred)

        sources = [n for n in order if not pred[n]]
        sinks = [n for n in order if not succ[n]]
        if len(sources) != 1 or len(sinks) != 1:
            raise ValueError(
                f"graph needs exactly one ingress and one egress node "
                f"(got sources={sources}, sinks={sinks})"
            )
        self._source_name, self._sink_name = sources[0], sinks[0]

        # Build executables. OperatorNodes first (ops only), then routers.
        has_split = any(isinstance(s, Split) for s in self.node_specs.values())
        self.nodes: List[OperatorNode] = []  # op nodes in topo order
        self.node_names: List[str] = []
        self._exec: dict[str, Any] = {}  # name -> OperatorNode|_SplitRouter|_MergeRouter
        for name in order:
            spec = self.node_specs[name]
            if isinstance(spec, OpSpec):
                node = OperatorNode(
                    _wrap_spec(spec) if has_split else spec,
                    len(self.nodes),
                    reorder_scheme=reorder_scheme,
                    worklist_scheme=worklist_scheme,
                    reorder_size=reorder_size,
                    num_workers=num_workers,
                    batch_size=self.batch_size,
                )
                node.on_marker_drop = self._record_marker
                self._exec[name] = node
                self.nodes.append(node)
                self.node_names.append(name)
        self._merges: list[_MergeRouter] = []
        for name in order:
            spec = self.node_specs[name]
            if isinstance(spec, Merge):
                m = _MergeRouter(spec)
                m.on_marker_drop = self._record_marker
                self._exec[name] = m
                self._merges.append(m)
        for name in reversed(order):  # inner splits first: outer branch heads
            spec = self.node_specs[name]  # may be inner splits themselves
            if isinstance(spec, Split):
                merge_name = self._matching_merge(name, succ)
                branches = [self._inlet(v) for v in succ[name]]
                self._exec[name] = _SplitRouter(
                    spec, branches, self._exec[merge_name]
                )

        # Wire downstreams (op/merge outlets -> successor inlets or egress).
        for name in order:
            ex = self._exec[name]
            if isinstance(ex, _SplitRouter):
                continue  # wired at construction via branch inlets
            if name == self._sink_name:
                ex.downstream = self._egress
                if self.batch_size > 1:
                    ex.downstream_batch = self._egress_batch
            else:
                ex.downstream = self._inlet(succ[name][0])
                if self.batch_size > 1:  # chain-only: successor is an op node
                    ex.downstream_batch = self._exec[succ[name][0]].push_batch

        # Scheduler metadata: weighted edges between *op node indices*
        # (routing nodes collapsed; split edges carry fraction 1/B).
        self.sched_edges = self._op_edges(succ)

    # ---- graph plumbing ------------------------------------------------------
    def _topo_order(self) -> list[str]:
        names = set(self.node_specs)
        for u, v in self.edges:
            if u not in names or v not in names:
                raise ValueError(f"edge ({u!r}, {v!r}) references unknown node")
        indeg = {n: 0 for n in names}
        succ: dict[str, list[str]] = {n: [] for n in names}
        for u, v in self.edges:
            succ[u].append(v)
            indeg[v] += 1
        ready = sorted(n for n in names if indeg[n] == 0)
        order = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for v in succ[n]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
        if len(order) != len(names):
            raise ValueError("graph has a cycle")
        return order

    def _validate_degrees(self, succ, pred) -> None:
        for n, spec in self.node_specs.items():
            if isinstance(spec, Split):
                if len(succ[n]) < 2:
                    raise ValueError(f"split {n!r} needs >= 2 branches")
                if len(pred[n]) > 1:
                    raise ValueError(f"split {n!r} must have a single inbound edge")
            elif isinstance(spec, Merge):
                if len(pred[n]) < 2:
                    raise ValueError(f"merge {n!r} needs >= 2 inbound edges")
                if len(succ[n]) > 1:
                    raise ValueError(f"merge {n!r} must have a single outbound edge")
            else:
                if len(succ[n]) > 1:
                    raise ValueError(
                        f"op {n!r} has out-degree {len(succ[n])}; insert a Split"
                    )
                if len(pred[n]) > 1:
                    raise ValueError(
                        f"op {n!r} has in-degree {len(pred[n])}; insert a Merge"
                    )

    def _matching_merge(self, split_name: str, succ) -> str:
        """The merge closing ``split_name``'s region: follow each branch at
        depth-0 relative to the split until a Merge at relative depth 0."""
        targets = set()
        for start in succ[split_name]:
            depth, n = 0, start
            while True:
                spec = self.node_specs[n]
                if isinstance(spec, Split):
                    depth += 1
                elif isinstance(spec, Merge):
                    if depth == 0:
                        targets.add(n)
                        break
                    depth -= 1
                if not succ[n]:
                    raise ValueError(
                        f"branch of split {split_name!r} never reaches a merge"
                    )
                # After an inner split, any branch leads to its inner merge
                # (which pops depth back), so following branch 0 suffices.
                n = succ[n][0]
        if len(targets) != 1:
            raise ValueError(
                f"branches of split {split_name!r} converge on {sorted(targets)}; "
                "all branches must reach the same merge"
            )
        return targets.pop()

    def _inlet(self, name: str) -> Callable[[Any, Optional[_Marker]], None]:
        """The (value, marker) entry point of node ``name``."""
        ex = self._exec[name]
        if isinstance(ex, OperatorNode):
            return ex.push
        if isinstance(ex, _SplitRouter):
            return ex.route
        return ex.arrive

    def _op_edges(self, succ) -> list[tuple[int, int, float]]:
        """Edges between op-node indices with flow weights, collapsing
        routing nodes (a split divides flow evenly among its B branches)."""
        idx = {name: i for i, name in enumerate(self.node_names)}
        out: list[tuple[int, int, float]] = []

        def reach(name: str, w: float) -> list[tuple[int, float]]:
            spec = self.node_specs[name]
            if isinstance(spec, OpSpec):
                return [(idx[name], w)]
            if isinstance(spec, Split):
                got = []
                for v in succ[name]:
                    got.extend(reach(v, w / len(succ[name])))
                return got
            # Merge: pass through
            return reach(succ[name][0], w) if succ[name] else []

        for name in self.node_names:
            for v in succ[name]:
                for j, w in reach(v, 1.0):
                    out.append((idx[name], j, w))
        # edges out of the graph ingress if it is a routing node
        if self._source_name not in idx:
            for j, w in reach(self._source_name, 1.0):
                out.append((-1, j, w))
        return out

    # ---- ingress ------------------------------------------------------------
    def push(self, value: Any) -> None:
        """Push one tuple at the graph ingress (thread-safe; markers are
        injected here every ``marker_interval`` pushes)."""
        marker = None
        n = self._ingress.fetch_add(1) + 1
        if n == 1:
            # fetch_add makes push #1 unique, so exactly one producer ever
            # stores the window-start timestamp (no check-then-set race).
            self._first_push_ts = time.perf_counter()
        if self.marker_interval and n % self.marker_interval == 0:
            marker = _Marker(time.perf_counter())
        if self.batch_size > 1:
            # push_batch happens INSIDE the lock: sealing and serial
            # assignment must be atomic, or two concurrent producers could
            # enqueue sealed batches in the opposite order they accumulated.
            with self._accum_lock:
                self._accum_vals.append(value)
                if marker is not None:
                    # (offset-in-batch, marker): probes stay attached to the
                    # exact tuple they rode in on (see _operate_batch)
                    self._accum_marks.append((len(self._accum_vals) - 1, marker))
                if len(self._accum_vals) >= self.batch_size:
                    vals, marks = self._accum_vals, self._accum_marks
                    self._accum_vals, self._accum_marks = [], []
                    self._exec[self._source_name].push_batch(vals, marks)
            return
        self._inlet(self._source_name)(value, marker)

    def flush(self) -> None:
        """Release a partial ingress micro-batch (call when the source ends).

        No-op at ``batch_size=1``; the runtime calls this before draining."""
        if self.batch_size <= 1:
            return
        with self._accum_lock:
            vals, marks = self._accum_vals, self._accum_marks
            self._accum_vals, self._accum_marks = [], []
            if vals or marks:
                self._exec[self._source_name].push_batch(vals, marks)

    # ---- egress ---------------------------------------------------------------
    def _egress(self, value: Any, marker: Optional[_Marker]) -> None:
        with self._egress_lock:
            self._egress_count += 1
            self._last_egress_ts = time.perf_counter()
            if self.collect_outputs:
                self.outputs.append(value)
        if marker is not None:
            marker.exit = time.perf_counter()
            self._record_marker(marker)

    def _egress_batch(self, values: list, markers: list) -> None:
        now = time.perf_counter()
        with self._egress_lock:
            self._egress_count += len(values)
            self._last_egress_ts = now
            if self.collect_outputs:
                self.outputs.extend(values)
        for _, m in markers:
            m.exit = now
            self._record_marker(m)

    def _record_marker(self, marker: _Marker) -> None:
        with self._markers_lock:
            self.markers.append(marker)

    # ---- metrics ---------------------------------------------------------------
    @property
    def egress_count(self) -> int:
        """Tuples egressed so far."""
        return self._egress_count

    @property
    def ingress_count(self) -> int:
        """Tuples pushed at ingress so far (atomic; any thread may read)."""
        return self._ingress.load()

    def outputs_since(self, start: int) -> list:
        """Snapshot of collected outputs from index ``start`` on, taken under
        the egress lock — the incremental read behind the streaming
        :class:`~.api.Session`'s ordered ``results()`` iterator (requires
        ``collect_outputs=True``)."""
        with self._egress_lock:
            return self.outputs[start:]

    def consume_outputs(self, n: int) -> None:
        """Release the first ``n`` collected outputs (under the egress lock).
        The streaming Session trims its consumed prefix through this so a
        long-lived session's memory stays bounded by its in-flight window,
        not its full egress history."""
        with self._egress_lock:
            del self.outputs[:n]

    def processing_latencies(self, lo: float = 0.2, hi: float = 0.8) -> list[float]:
        """Marker latencies in the [lo, hi] arrival-percentile window (§7)."""
        with self._markers_lock:
            ms = list(self.markers)
        return percentile_latencies(ms, lo, hi)

    def processing_window(self) -> Optional[float]:
        """Seconds from first ingress push to last egress, if both happened —
        the active window ``egress_throughput`` is measured over.  A run that
        egressed 0 or 1 tuples has no meaningful window (first push and last
        egress coincide) and reports None."""
        if self._first_push_ts is None or self._last_egress_ts is None:
            return None
        if self._egress_count <= 1:
            return None
        return max(self._last_egress_ts - self._first_push_ts, 1e-9)

    def drained(self) -> bool:
        """Quiescence: no queued work, no worker mid-tuple, no merge holding
        an overflow bundle (a worker pushes downstream before it is released,
        so workers==0 makes pushes visible), no partial ingress micro-batch
        awaiting :meth:`flush`."""
        if self._accum_vals or self._accum_marks:
            return False
        return all(
            n.worklist_size() == 0 and n.workers.load() == 0
            and n.overflow_count() == 0
            for n in self.nodes
        ) and all(m.pending_count() == 0 for m in self._merges)


class CompiledPipeline(GraphPipeline):
    """Linear operator chain — a thin wrapper lowering to a chain GraphPipeline."""

    def __init__(
        self,
        specs: Sequence[OpSpec],
        *,
        reorder_scheme: str = "non_blocking",
        worklist_scheme: str = "hybrid",
        reorder_size: int = 1024,
        num_workers: int = 1,
        marker_interval: int = 64,
        collect_outputs: bool = False,
        batch_size: int = 1,
    ):
        specs = list(specs)
        if not specs:
            raise ValueError("pipeline needs at least one operator")
        names = [f"{i:03d}_{s.name}" for i, s in enumerate(specs)]
        super().__init__(
            nodes=dict(zip(names, specs)),
            edges=list(zip(names, names[1:])),
            reorder_scheme=reorder_scheme,
            worklist_scheme=worklist_scheme,
            reorder_size=reorder_size,
            num_workers=num_workers,
            marker_interval=marker_interval,
            collect_outputs=collect_outputs,
            batch_size=batch_size,
        )
        self.specs = specs


def compile_pipeline(specs: Sequence[OpSpec], **kw) -> CompiledPipeline:
    """Compile a linear operator chain (``CompiledPipeline(specs, **kw)``)."""
    return CompiledPipeline(specs, **kw)


def compile_graph(nodes: Dict[str, NodeSpec], edges, **kw) -> GraphPipeline:
    """Compile a dataflow DAG (``GraphPipeline(nodes, edges, **kw)``)."""
    return GraphPipeline(nodes, edges, **kw)
