"""Linear-chain pipeline compilation (paper §2).

``Pipeline`` holds the operator specs; ``compile()`` wires OperatorNodes into a
chain where node i's ordered egress pushes into node i+1's worklist, and the
last node's egress feeds a collector. Latency markers (paper §7) are injected
every ``marker_interval`` tuples at ingress.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, List, Optional, Sequence

from .operators import OpSpec, OperatorNode, _Marker


class CompiledPipeline:
    def __init__(
        self,
        specs: Sequence[OpSpec],
        *,
        reorder_scheme: str = "non_blocking",
        worklist_scheme: str = "hybrid",
        reorder_size: int = 1024,
        num_workers: int = 1,
        marker_interval: int = 64,
        collect_outputs: bool = False,
    ):
        self.specs = list(specs)
        self.nodes: List[OperatorNode] = [
            OperatorNode(
                spec,
                i,
                reorder_scheme=reorder_scheme,
                worklist_scheme=worklist_scheme,
                reorder_size=reorder_size,
                num_workers=num_workers,
            )
            for i, spec in enumerate(self.specs)
        ]
        self.marker_interval = marker_interval
        self.collect_outputs = collect_outputs
        self.outputs: list = []
        self.markers: list[_Marker] = []
        self._markers_lock = threading.Lock()
        self._egress_count = 0
        self._egress_lock = threading.Lock()
        self._ingress_count = 0

        for i, node in enumerate(self.nodes):
            if i + 1 < len(self.nodes):
                nxt = self.nodes[i + 1]
                node.downstream = lambda v, m, nxt=nxt: nxt.push(v, m)
            else:
                node.downstream = self._egress
            node.on_marker_drop = self._record_marker

    # ---- ingress ------------------------------------------------------------
    def push(self, value: Any) -> None:
        marker = None
        self._ingress_count += 1
        if self.marker_interval and self._ingress_count % self.marker_interval == 0:
            marker = _Marker(time.perf_counter())
        self.nodes[0].push(value, marker)

    # ---- egress ---------------------------------------------------------------
    def _egress(self, value: Any, marker: Optional[_Marker]) -> None:
        with self._egress_lock:
            self._egress_count += 1
            if self.collect_outputs:
                self.outputs.append(value)
        if marker is not None:
            marker.exit = time.perf_counter()
            self._record_marker(marker)

    def _record_marker(self, marker: _Marker) -> None:
        with self._markers_lock:
            self.markers.append(marker)

    # ---- metrics ---------------------------------------------------------------
    @property
    def egress_count(self) -> int:
        return self._egress_count

    def processing_latencies(self, lo: float = 0.2, hi: float = 0.8) -> list[float]:
        """Processing latency (begin->exit) of markers in the [lo, hi] percentile
        range of arrival, per the paper's measurement protocol."""
        with self._markers_lock:
            ms = sorted(self.markers, key=lambda m: m.entry)
        ms = [m for m in ms if m.exit and m.begin]
        if not ms:
            return []
        a, b = int(len(ms) * lo), max(int(len(ms) * hi), int(len(ms) * lo) + 1)
        return [m.exit - m.begin for m in ms[a:b]]

    def drained(self) -> bool:
        """Quiescence: no queued work AND no worker mid-tuple (a worker pushes
        downstream before it is released, so workers==0 makes pushes visible)."""
        return all(
            n.worklist_size() == 0 and n.workers.load() == 0 for n in self.nodes
        )


def compile_pipeline(specs: Sequence[OpSpec], **kw) -> CompiledPipeline:
    return CompiledPipeline(specs, **kw)
