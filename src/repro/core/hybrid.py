"""Partitioned-parallelism worklist schemes (paper §4).

Three strategies for feeding workers of a partitioned stateful operator:

- :class:`SharedQueueWorklist` (§4.1)      — one MPMC queue + per-key locks
  (dequeue+lock made atomic under a global lock; the naive, blocking scheme).
- :class:`PartitionedQueueWorklist` (§4.2) — one queue per bucket, workers own
  buckets statically (Volcano-style); no concurrency control but poor skew/order
  behaviour.
- :class:`HybridQueueWorklist` (§4.3)      — fig. 7: per-partition queues + a
  master queue of partition ids + per-partition delegation counters. Never
  blocks; processes almost in arrival order; partitions ≫ workers for load
  balance.

All schemes present the same interface:
  ``add(serial, key, tuple)``                    (producer side, addInput)
  ``consume(worker_id, operate, budget) -> int`` (worker side, consumeInputs)
``operate(serial, key, tuple)`` is the operator callback; ``budget`` caps tuples
processed per invocation (the scheduler's time slice); returns #processed.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Hashable

from .serial import AtomicLong

Operate = Callable[[int, Hashable, Any], None]


class Worklist:
    """Interface: add(serial, key, item) enqueues; consume(worker, operate,
    budget) runs up to ``budget`` tuples through ``operate``; len() is the
    queued-tuple count the scheduler reads."""

    def add(self, serial: int, key: Hashable, item: Any) -> None:
        """Enqueue one keyed tuple under its serial."""
        raise NotImplementedError

    def consume(self, worker_id: int, operate: Operate, budget: int) -> int:
        """Process up to ``budget`` queued tuples; returns how many ran."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class SharedQueueWorklist(Worklist):
    """§4.1 — single shared queue; atomicity of (dequeue, acquire key lock)
    ensured by a global lock; workers block if the key is busy."""

    def __init__(self, num_partitions: int, partitioner: Callable[[Hashable], int]):
        # lock-free: deque.append/popleft are atomic under the GIL; §4.1 serializes only the dequeue+key-lock pair (under _global), not the enqueue
        self._queue: collections.deque = collections.deque()
        self._global = threading.Lock()
        self._key_locks = [threading.Lock() for _ in range(num_partitions)]
        self._partitioner = partitioner
        self.blocked_time = 0.0  # guarded-by: self._global

    def add(self, serial, key, item):
        """Enqueue on the single shared queue."""
        self._queue.append((serial, key, item))

    def consume(self, worker_id, operate, budget):
        """Dequeue+key-lock atomically (may block on a busy key — §4.1's flaw)."""
        done = 0
        while done < budget:
            t0 = time.perf_counter()
            with self._global:  # makes dequeue+lock atomic (fig. 5 fix)
                try:
                    serial, key, item = self._queue.popleft()
                except IndexError:
                    self.blocked_time += time.perf_counter() - t0
                    return done
                lock = self._key_locks[self._partitioner(key)]
                # analysis: ignore[LK202]: §4.1's deliberate flaw — the scheme's defining property is that dequeue and key-lock acquisition are one atomic step, so the key wait happens under _global (fig. 5)
                lock.acquire()  # may block while holding _global: the flaw §4.1
                self.blocked_time += time.perf_counter() - t0
            try:
                operate(serial, key, item)
            finally:
                lock.release()
            done += 1
        return done

    def __len__(self):
        return len(self._queue)


class PartitionedQueueWorklist(Worklist):
    """§4.2 — static queue-per-bucket; worker w owns buckets {p : p % W == w}."""

    def __init__(
        self,
        num_partitions: int,
        partitioner: Callable[[Hashable], int],
        num_workers: int,
    ):
        self._queues = [collections.deque() for _ in range(num_partitions)]
        self._partitioner = partitioner
        self._num_workers = num_workers
        self._size = AtomicLong(0)

    def add(self, serial, key, item):
        """Enqueue on the tuple's bucket queue."""
        # Count BEFORE publishing: a consumer may process-and-decrement the
        # moment the tuple is visible, and a transiently negative size makes
        # __len__ raise (len() must be >= 0), killing the worker thread.
        self._size.fetch_add(1)
        self._queues[self._partitioner(key)].append((serial, key, item))

    def consume(self, worker_id, operate, budget):
        """Drain only the buckets this worker statically owns (p % W == w)."""
        done = 0
        my = worker_id % self._num_workers
        for p in range(my, len(self._queues), self._num_workers):
            q = self._queues[p]
            while done < budget:
                try:
                    serial, key, item = q.popleft()
                except IndexError:
                    break
                operate(serial, key, item)
                self._size.fetch_sub(1)
                done += 1
            if done >= budget:
                break
        return done

    def __len__(self):
        return max(self._size.load(), 0)


class HybridQueueWorklist(Worklist):
    """§4.3 / fig. 7 — the paper's contribution.

    ``count[p]`` serves double duty: exclusive access to partition p (the worker
    whose fetch_add observed 0 is the *active* worker) and a delegation counter
    (losers increment it and move on — never blocking).
    """

    def __init__(self, num_partitions: int, partitioner: Callable[[Hashable], int]):
        self._partition_queues = [collections.deque() for _ in range(num_partitions)]
        self._master: collections.deque = collections.deque()
        self._count = [AtomicLong(0) for _ in range(num_partitions)]
        self._partitioner = partitioner
        self._size = AtomicLong(0)
        self.delegated = 0  # instrumentation: tuples processed via delegation

    # fig. 7 addInput
    def add(self, serial, key, item):
        """Enqueue on the tuple's partition queue + the master queue."""
        p = self._partitioner(key)
        self._size.fetch_add(1)  # before publishing (see PartitionedQueue.add)
        self._partition_queues[p].append((serial, key, item))
        self._master.append(p)

    # fig. 7 consumeInputs (+ scheduler budget)
    def consume(self, worker_id, operate, budget):
        """Fig. 7: first worker into a partition becomes its active worker;
        losers delegate their tuple to it and move on (never blocking)."""
        done = 0
        while done < budget:
            try:
                p = self._master.popleft()
            except IndexError:
                return done
            if self._count[p].fetch_add(1) == 0:
                # active worker of p: drain own + delegated tuples
                while True:
                    serial, key, item = self._partition_queues[p].popleft()
                    operate(serial, key, item)
                    self._size.fetch_sub(1)
                    done += 1
                    if self._count[p].fetch_sub(1) <= 1:
                        break
                    if done >= budget:
                        # Time slice exhausted with delegations pending: hand
                        # the partition off instead of overrunning the budget.
                        # exchange(0) releases exclusivity (a future fetch_add
                        # sees 0 and becomes active); one master token per
                        # abandoned tuple restores the token<->tuple invariant.
                        pending = self._count[p].exchange(0)
                        for _ in range(pending):
                            self._master.append(p)
                        return done
            else:
                self.delegated += 1
                # delegated to the active worker; move on (non-blocking)
        return done

    def __len__(self):
        return max(self._size.load(), 0)


def make_worklist(
    scheme: str,
    num_partitions: int,
    partitioner: Callable[[Hashable], int],
    num_workers: int = 1,
) -> Worklist:
    """Build the worklist scheme by name: ``hybrid`` (fig. 7), ``partitioned``
    (§4.2 static bucket ownership), or ``shared`` (§4.1 single queue)."""
    if scheme == "hybrid":
        return HybridQueueWorklist(num_partitions, partitioner)
    if scheme == "partitioned":
        return PartitionedQueueWorklist(num_partitions, partitioner, num_workers)
    if scheme == "shared":
        return SharedQueueWorklist(num_partitions, partitioner)
    raise ValueError(f"unknown worklist scheme: {scheme!r}")
