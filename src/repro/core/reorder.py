"""Output-reordering schemes (paper §3) — the in-thread serial-number
protocol.

Serial-number protocol: every tuple is allotted a monotone serial (starting
at 1, :class:`~.serial.SerialAssigner`) *before* it is handed to concurrent
workers; each serial produces exactly one output bundle (possibly empty —
filtered tuples punch their hole in the sequence instead of stalling it).
Both schemes below order those bundles by serial before sending them
downstream, so concurrent execution is externally indistinguishable from the
single-threaded reference:

- :class:`LockBasedReorderBuffer` — fig. 2: a global lock protects a waiting
  buffer + ``next`` counter. Simple, but adders block while another worker drains.
- :class:`NonBlockingReorderBuffer` — fig. 4: bounded ring buffer indexed by
  ``t mod s``, atomic ``next``, and a try-lock flag. Adders never block; exactly
  one worker drains the contiguous ready prefix at a time.  Ring wire
  format: slot ``t mod s`` holds a one-shot :class:`_Slot` box (payloads are
  wrapped so ``None`` payloads are legal); an occupied slot *is* the
  publish, the drain empties it and bumps ``next``.

``send(t, output)`` returns False when the bounded ring cannot yet accept serial
``t`` (entry condition ``next <= t < next + s``); the caller must retry later —
this is the paper's back-pressure mechanism.

:class:`ParkingReorderBuffer` wraps either scheme with a spin-free overflow
side channel for callers that must never block *or* fail: rejected serials
park in a host-side heap and are re-sent once later traffic advances the
window.  Needed wherever in-flight serials can outrun the ring arbitrarily
(non-FIFO worklists, single-threaded engines, merge fan-in).  Invariant: a
parked serial is *claimed* under the lock before the re-send, so every
serial has exactly one sender — a duplicate send could re-populate a
drained slot and corrupt the sequence one window later.

The cross-process mirror of fig. 4 lives in :mod:`.shm`
(``ShmReorderRing``): same entry condition and hole-punching, plus span
slots (one publish covers a contiguous micro-batch), an in-band EOF marker,
and the crash/replay rules the staged process backend (:mod:`.procrun`)
builds on.  Keep the two in sync when evolving the protocol.
"""
from __future__ import annotations

import heapq
import threading
import time
from typing import Any, Callable, Optional

from .serial import AtomicFlag, AtomicLong

_EMPTY = None  # ring sentinel; payloads are wrapped so None payloads are legal


class _Slot:
    __slots__ = ("value",)

    def __init__(self, value: Any):
        self.value = value


class ReorderBuffer:
    """Common interface: send(t, output) -> bool; drains via send_downstream."""

    def send(self, t: int, output: Any) -> bool:  # pragma: no cover - interface
        """Admit serial ``t``'s output bundle; False = retry later (back-pressure)."""
        raise NotImplementedError

    def send_blocking(self, t: int, output: Any, spin: float = 1e-6) -> None:
        """Retry send until accepted (workers in the paper 'try again').

        ``spin`` sleeps between retries to yield the GIL — on real hardware this
        would be a PAUSE-loop; under CPython a 0-sleep spin starves the drainer.
        """
        while not self.send(t, output):
            if spin:
                time.sleep(spin)

    def accepts(self, t: int) -> bool:
        """Whether a send of serial ``t`` would be admitted right now."""
        return True  # unbounded schemes always accept


class LockBasedReorderBuffer(ReorderBuffer):
    """Fig. 2 — global lock + waiting dict. Blocking by construction."""

    def __init__(self, send_downstream: Callable[[Any], None], start: int = 1):
        self._send_downstream = send_downstream
        self._next = start  # guarded-by: self._lock
        self._waiting: dict[int, _Slot] = {}  # guarded-by: self._lock
        self._lock = threading.Lock()
        # Instrumentation: total time workers spent blocked on the lock.
        self.blocked_time = 0.0  # guarded-by: self._lock

    def send(self, t: int, output: Any) -> bool:
        """Admit serial ``t`` under the global lock; always succeeds."""
        t0 = time.perf_counter()
        with self._lock:
            self.blocked_time += time.perf_counter() - t0
            if t == self._next:
                # analysis: ignore[LK202]: fig. 2's deliberate blocking design — each node's buffer emits downstream under its own lock; instance locks nest strictly along the acyclic dataflow, so the order is a DAG
                self._send_downstream(output)
                self._next += 1
                while self._next in self._waiting:
                    # analysis: ignore[LK202]: same fig. 2 strawman as above — the drain loop emits under the instance lock by construction
                    self._send_downstream(self._waiting.pop(self._next).value)
                    self._next += 1
            else:
                self._waiting[t] = _Slot(output)
        return True


class NonBlockingReorderBuffer(ReorderBuffer):
    """Fig. 4 — bounded ring + atomic ``next`` + try-lock drain flag."""

    def __init__(
        self,
        send_downstream: Callable[[Any], None],
        size: int = 1024,
        start: int = 1,
    ):
        if size <= 0:
            raise ValueError("ring size must be positive")
        self._send_downstream = send_downstream
        self._size = size
        self._next = AtomicLong(start)
        # lock-free: fig. 4 — slot ownership via the entry condition (next <= t < next+size) and publish-before-advance; exactly one drainer via the try-lock flag
        self._buffer: list[Optional[_Slot]] = [_EMPTY] * size
        self._flag = AtomicFlag()
        self.blocked_time = 0.0  # always ~0; kept for symmetric instrumentation
        self._rejected = AtomicLong(0)  # entry-condition failures (ring full)

    @property
    def rejected_adds(self) -> int:
        """Entry-condition failures (ring full for the offered serial).
        Atomic: concurrent rejecting senders each count exactly once."""
        return self._rejected.load()

    def accepts(self, t: int) -> bool:
        """Entry condition ``next <= t < next + size`` (no side effects)."""
        n = self._next.load()
        return n <= t < n + self._size

    # -- paper fig. 4 ------------------------------------------------------
    def send(self, t: int, output: Any) -> bool:
        """Try to admit serial ``t`` (entry condition ``next <= t < next+s``),
        then drain the contiguous ready prefix; False = window full, retry."""
        success = self._try_add(t, output)
        self._send_pending_outputs()
        return success

    def _try_add(self, t: int, output: Any) -> bool:
        n = self._next.load()
        if n <= t < n + self._size:
            self._buffer[t % self._size] = _Slot(output)
            return True
        self._rejected.fetch_add(1)
        return False

    def _send_pending_outputs(self) -> None:
        while True:  # tail-recursion of fig. 4 L42 expressed as a loop
            if self._flag.test_and_set():
                return  # another worker is draining; do NOT block (the point)
            i = 0
            while True:
                n = self._next.load()
                i = n % self._size
                slot = self._buffer[i]
                if slot is not _EMPTY:
                    self._send_downstream(slot.value)
                    self._buffer[i] = _EMPTY
                    self._next.fetch_add(1)
                else:
                    self._flag.clear()
                    break
            # Re-check: an add may have raced with the flag clear (fig. 4 L39-42).
            if self._buffer[i] is _EMPTY:
                return


class ParkingReorderBuffer:
    """Reliable, never-blocking facade over a :class:`ReorderBuffer`.

    A bounded ring rejects serials beyond its window; spinning on the reject
    deadlocks as soon as every worker holds a far-future serial (non-FIFO
    worklists make that reachable) or the caller is single threaded.  Here a
    rejected serial parks in a min-heap instead, and :meth:`flush` re-sends
    parked serials once the window reaches them — every successful send calls
    it, so parked output drains as the stream progresses.

    Concurrency: a parked serial is *claimed* (popped) under the lock before
    the re-send, so exactly one thread ever sends a given serial — a duplicate
    send could otherwise re-populate a drained ring slot and corrupt the
    sequence one window later.  If the claimed send is rejected the entry is
    re-parked; the subsequent ``accepts`` check closes the race where the
    window advanced (and its owner's flush missed the re-parked entry) in
    between.
    """

    def __init__(self, inner: ReorderBuffer):
        self._inner = inner
        self._parked: dict[int, Any] = {}  # guarded-by(rw): self._lock
        # min-heap of parked serials (lazy deletes)
        self._heap: list[int] = []  # guarded-by(rw): self._lock
        self._lock = threading.Lock()

    def send(self, t: int, output: Any) -> None:
        """Admit serial ``t``, parking it (never blocking, never failing) if
        the inner ring's window cannot accept it yet."""
        if not self._inner.send(t, output):
            with self._lock:
                self._parked[t] = output
                heapq.heappush(self._heap, t)
        self.flush()

    def flush(self) -> None:
        """Re-send parked serials the advancing window can now accept."""
        while True:
            with self._lock:
                while self._heap and self._heap[0] not in self._parked:
                    heapq.heappop(self._heap)  # claimed by another flusher
                if not self._heap:
                    return
                t = self._heap[0]
                payload = self._parked.pop(t)  # claim: we are t's only sender
            if self._inner.send(t, payload):
                continue
            with self._lock:
                self._parked[t] = payload
                # Re-push: a concurrent flusher may have lazily popped t's
                # heap entry while it was claimed (t absent from the dict);
                # without this the entry would be unreachable forever.
                heapq.heappush(self._heap, t)
            if not self._inner.accepts(t):
                return  # window still short; a later send will flush
            # window advanced during the re-park: retry, we may be last

    def parked_count(self) -> int:
        """How many serials are currently parked (0 = fully drained)."""
        with self._lock:
            return len(self._parked)


def make_reorder_buffer(
    scheme: str, send_downstream: Callable[[Any], None], size: int = 1024
) -> ReorderBuffer:
    """Build the reorder scheme by name: ``non_blocking`` (fig. 4, bounded
    ring of ``size`` serials) or ``lock_based`` (fig. 2)."""
    if scheme == "non_blocking":
        return NonBlockingReorderBuffer(send_downstream, size=size)
    if scheme == "lock_based":
        return LockBasedReorderBuffer(send_downstream)
    raise ValueError(f"unknown reorder scheme: {scheme!r}")
