"""Output-reordering schemes (paper §3).

Both schemes order outputs of concurrently-processed tuples by their pre-allotted
serial number before they are sent downstream.

- :class:`LockBasedReorderBuffer` — fig. 2: a global lock protects a waiting
  buffer + ``next`` counter. Simple, but adders block while another worker drains.
- :class:`NonBlockingReorderBuffer` — fig. 4: bounded ring buffer indexed by
  ``t mod s``, atomic ``next``, and a try-lock flag. Adders never block; exactly
  one worker drains the contiguous ready prefix at a time.

``send(t, output)`` returns False when the bounded ring cannot yet accept serial
``t`` (entry condition ``next <= t < next + s``); the caller must retry later —
this is the paper's back-pressure mechanism.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional

from .serial import AtomicFlag, AtomicLong

_EMPTY = None  # ring sentinel; payloads are wrapped so None payloads are legal


class _Slot:
    __slots__ = ("value",)

    def __init__(self, value: Any):
        self.value = value


class ReorderBuffer:
    """Common interface: send(t, output) -> bool; drains via send_downstream."""

    def send(self, t: int, output: Any) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def send_blocking(self, t: int, output: Any, spin: float = 1e-6) -> None:
        """Retry send until accepted (workers in the paper 'try again').

        ``spin`` sleeps between retries to yield the GIL — on real hardware this
        would be a PAUSE-loop; under CPython a 0-sleep spin starves the drainer.
        """
        while not self.send(t, output):
            if spin:
                time.sleep(spin)


class LockBasedReorderBuffer(ReorderBuffer):
    """Fig. 2 — global lock + waiting dict. Blocking by construction."""

    def __init__(self, send_downstream: Callable[[Any], None], start: int = 1):
        self._send_downstream = send_downstream
        self._next = start
        self._waiting: dict[int, _Slot] = {}
        self._lock = threading.Lock()
        # Instrumentation: total time workers spent blocked on the lock.
        self.blocked_time = 0.0

    def send(self, t: int, output: Any) -> bool:
        t0 = time.perf_counter()
        with self._lock:
            self.blocked_time += time.perf_counter() - t0
            if t == self._next:
                self._send_downstream(output)
                self._next += 1
                while self._next in self._waiting:
                    self._send_downstream(self._waiting.pop(self._next).value)
                    self._next += 1
            else:
                self._waiting[t] = _Slot(output)
        return True


class NonBlockingReorderBuffer(ReorderBuffer):
    """Fig. 4 — bounded ring + atomic ``next`` + try-lock drain flag."""

    def __init__(
        self,
        send_downstream: Callable[[Any], None],
        size: int = 1024,
        start: int = 1,
    ):
        if size <= 0:
            raise ValueError("ring size must be positive")
        self._send_downstream = send_downstream
        self._size = size
        self._next = AtomicLong(start)
        self._buffer: list[Optional[_Slot]] = [_EMPTY] * size
        self._flag = AtomicFlag()
        self.blocked_time = 0.0  # always ~0; kept for symmetric instrumentation
        self.rejected_adds = 0  # entry-condition failures (ring full for t)

    # -- paper fig. 4 ------------------------------------------------------
    def send(self, t: int, output: Any) -> bool:
        success = self._try_add(t, output)
        self._send_pending_outputs()
        return success

    def _try_add(self, t: int, output: Any) -> bool:
        n = self._next.load()
        if n <= t < n + self._size:
            self._buffer[t % self._size] = _Slot(output)
            return True
        self.rejected_adds += 1
        return False

    def _send_pending_outputs(self) -> None:
        while True:  # tail-recursion of fig. 4 L42 expressed as a loop
            if self._flag.test_and_set():
                return  # another worker is draining; do NOT block (the point)
            i = 0
            while True:
                n = self._next.load()
                i = n % self._size
                slot = self._buffer[i]
                if slot is not _EMPTY:
                    self._send_downstream(slot.value)
                    self._buffer[i] = _EMPTY
                    self._next.fetch_add(1)
                else:
                    self._flag.clear()
                    break
            # Re-check: an add may have raced with the flag clear (fig. 4 L39-42).
            if self._buffer[i] is _EMPTY:
                return


def make_reorder_buffer(
    scheme: str, send_downstream: Callable[[Any], None], size: int = 1024
) -> ReorderBuffer:
    if scheme == "non_blocking":
        return NonBlockingReorderBuffer(send_downstream, size=size)
    if scheme == "lock_based":
        return LockBasedReorderBuffer(send_downstream)
    raise ValueError(f"unknown reorder scheme: {scheme!r}")
