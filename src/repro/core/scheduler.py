"""Dynamic scheduling heuristics (paper §6), generalized to DAG dataflow.

The central scheduler answers: which operator next, and how many tuples
(= constant time slice s / per-tuple cost c_i). Heuristics:

- QST (§6.1): queue-size throttling — earliest operator whose *output* queues
  are below its selectivity-scaled threshold T_i = C·cs_i / Σ cs_j.
- LP  (§6.2): last-in-pipeline — latest (topologically) schedulable operator.
- ET  (§6.3): estimated worklist completion time p_i = I_i·c_i/(w_i+1), max wins.
- CT  (§6.4): normalized current-window throughput n_i = (T_i^w + w_i·s)/(c_i·cs_i),
  min wins (the bottleneck operator).
- ADAPTIVE: CT's pick, plus a periodic controller (:meth:`Scheduler.adapt`)
  that re-estimates per-operator cost/selectivity, recomputes each node's
  share of total load, and resizes the effective parallelism cap M_i
  (``node.dop_cap``) — the paper's dynamic mapping of exposed parallelism
  onto machine parallelism (§2/§6).

Topology awareness: the pipeline hands the scheduler weighted op-to-op edges
``(u, v, w)`` (routing nodes collapsed; a B-way split contributes w=1/B).
``cs_i`` becomes the *flow rate* out of operator i per source tuple, computed
by propagating estimated selectivities through the graph — for a linear chain
this reduces exactly to the cumulative-selectivity product of the paper.

All heuristics consider only *schedulable* operators: w_i < M_i and non-empty
worklist.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .costmodel import op_cost_us
from .operators import OperatorNode

HEURISTICS = ("qst", "lp", "et", "ct", "adaptive")


class Scheduler:
    """Central scheduler data structure (paper §2.2/§6)."""

    def __init__(
        self,
        nodes: List[OperatorNode],
        heuristic: str = "ct",
        *,
        time_slice: float = 0.002,  # s, the constant slice (paper §6)
        capacity: int = 4096,  # C for QST
        window: float = 0.05,  # w for CT
        edges: Optional[Sequence[Tuple[int, int, float]]] = None,
        num_workers: int = 4,  # machine parallelism (adaptive controller)
        adapt_interval: float = 0.02,  # s between controller re-estimations
        cost_priors: Optional[Dict[str, float]] = None,  # {op name: cost_us}
    ):
        if heuristic not in HEURISTICS:
            raise ValueError(f"unknown heuristic {heuristic!r}; pick from {HEURISTICS}")
        self.nodes = nodes
        self.heuristic = heuristic
        self.time_slice = time_slice
        self.capacity = capacity
        self.window = window
        self.num_workers = num_workers
        self.adapt_interval = adapt_interval
        # Explicit cost priors override each spec's declared cost_us until
        # live estimates warm up — the same override surface the process
        # backend's allocator uses (costmodel.op_cost_us).
        self.cost_priors = dict(cost_priors) if cost_priors else None
        self.adaptations = 0  # controller invocations (instrumentation)
        self._lock = threading.Lock()
        self._window_start = time.perf_counter()  # guarded-by: self._lock
        # Weighted op->op edges; default: linear chain with unit weights.
        if edges is None:
            edges = [(i, i + 1, 1.0) for i in range(len(nodes) - 1)]
        self._edges = list(edges)
        self._out: list[list[tuple[int, float]]] = [[] for _ in nodes]
        has_in = [False] * len(nodes)
        self._ingress_flow = [0.0] * len(nodes)
        for u, v, w in self._edges:
            if u < 0:  # ingress fraction edge (source is a routing node)
                self._ingress_flow[v] += w
                has_in[v] = True
            else:
                self._out[u].append((v, w))
                has_in[v] = True
        for i, seen in enumerate(has_in):
            if not seen:
                self._ingress_flow[i] = 1.0

    # ------------------------------------------------------------------ utils
    def _cost(self, i: int) -> float:
        n = self.nodes[i]
        prior = op_cost_us(n.spec, self.cost_priors) * 1e-6
        return max(n.stats.cost(prior), 1e-9)

    def _selectivity(self, i: int) -> float:
        n = self.nodes[i]
        return n.stats.selectivity(n.spec.selectivity)

    def _flows(self) -> tuple[list[float], list[float]]:
        """(in_rate, out_rate) per op, per source tuple, via the weighted DAG.

        Node indices are in topological order, so a single ascending pass
        propagates flow correctly.
        """
        in_rate = list(self._ingress_flow)
        out_rate = [0.0] * len(self.nodes)
        for i in range(len(self.nodes)):
            out_rate[i] = max(in_rate[i] * self._selectivity(i), 1e-9)
            for v, w in self._out[i]:
                in_rate[v] += out_rate[i] * w
        return in_rate, out_rate

    def _budget(self, i: int) -> int:
        return max(1, int(self.time_slice / self._cost(i)))

    def _schedulable(self) -> list[int]:
        return [i for i, n in enumerate(self.nodes) if n.schedulable()]

    def idle_hint(self) -> bool:
        """True when the graph looks drained: every worklist is empty and no
        worker is mid-tuple.  Lets idle workers park (sleep at the backoff
        cap) instead of hot-spinning ``acquire()`` — new work always arrives
        via a push, which refills a worklist before the next poll."""
        return all(
            n.worklist_size() == 0 and n.workers.load() == 0 for n in self.nodes
        )

    def snapshot(self) -> List[dict]:
        """Live per-operator scheduling state, one dict per node: name,
        queued work, allotted workers, effective parallelism cap, and the
        current cost/selectivity estimates.  The introspection feed behind
        :meth:`.api.Session.stats` on the thread backend."""
        out = []
        for i, n in enumerate(self.nodes):
            out.append({
                "op": n.spec.name,
                "kind": n.spec.kind,
                "worklist": n.worklist_size(),
                "workers": n.workers.load(),
                "dop_cap": min(n.dop_cap, n.max_dop),
                "cost_us": self._cost(i) * 1e6,
                "selectivity": self._selectivity(i),
            })
        return out

    # ---------------------------------------------------------------- acquire
    def acquire(self) -> Optional[Tuple[OperatorNode, int]]:
        """Pick (node, tuple budget) for a worker, or None if nothing to do."""
        with self._lock:
            idx = self._pick()
            if idx is None:
                return None
            node = self.nodes[idx]
            node.workers.fetch_add(1)
            return node, self._budget(idx)

    def release(self, node: OperatorNode) -> None:
        """Return a worker's allotment after its :meth:`acquire` time slice."""
        node.workers.fetch_sub(1)

    # ------------------------------------------------------------- controller
    def adapt(self) -> None:
        """One adaptive-controller step: re-estimate cost/selectivity, then
        resize each operator's effective parallelism cap M_i proportionally to
        its share of total load (in_rate_i · c_i), bounded by its max DOP.

        A ``dop_cap`` is a *cap*, not a reservation: idle operators consume
        no workers, so caps may sum past ``num_workers`` and a hot operator
        must stay able to absorb every idle worker — which is why this uses
        ceil-of-share rather than the process backend's hard-partitioning
        :func:`~.costmodel.proportional_allocation` (there a stage width
        reserves forked processes).  The two backends do share one *cost*
        surface: :func:`~.costmodel.op_cost_us` folds ``cost_priors``
        overrides into the declared priors on both paths.  Estimates refresh
        implicitly: :meth:`OpStats.cost`/``selectivity`` fold in measured
        busy time and tuple counts once warmed up.
        """
        in_rate, _ = self._flows()
        loads = [in_rate[i] * self._cost(i) for i in range(len(self.nodes))]
        total = sum(loads) or 1.0
        for i, node in enumerate(self.nodes):
            share = loads[i] / total
            cap = max(1, math.ceil(self.num_workers * share))
            node.dop_cap = min(cap, node.max_dop)
        self.adaptations += 1

    # ----------------------------------------------------------------- picks
    def _pick(self) -> Optional[int]:  # holds: self._lock
        cand = self._schedulable()
        if not cand:
            return None
        if self.heuristic == "lp":
            return cand[-1]
        if self.heuristic == "qst":
            return self._pick_qst(cand)
        if self.heuristic == "et":
            return self._pick_et(cand)
        return self._pick_ct(cand)  # ct + adaptive

    def _pick_qst(self, cand: list[int]) -> Optional[int]:  # holds: self._lock
        _, out_rate = self._flows()
        total = sum(out_rate)
        for i in cand:
            succ = self._out[i]
            if not succ:
                return i  # egress operator: output is unbounded
            threshold = max(self.capacity * out_rate[i] / total, 1.0)
            if all(self.nodes[v].worklist_size() < threshold for v, _ in succ):
                return i
        return cand[0]  # all throttled: fall back to earliest (keeps progress)

    def _pick_et(self, cand: list[int]) -> int:  # holds: self._lock
        best, best_p = cand[0], -1.0
        for i in cand:
            n = self.nodes[i]
            p = n.worklist_size() * self._cost(i) / (n.workers.load() + 1)
            if p > best_p:
                best, best_p = i, p
        return best

    def _pick_ct(self, cand: list[int]) -> int:  # holds: self._lock
        now = time.perf_counter()
        if now - self._window_start > self.window:
            for n in self.nodes:
                n.stats.window_busy = 0.0
            self._window_start = now
        _, out_rate = self._flows()
        best, best_n = cand[0], float("inf")
        for i in cand:
            n = self.nodes[i]
            eff = (n.stats.window_busy + n.workers.load() * self.time_slice) / (
                self._cost(i) * out_rate[i]
            )
            if eff < best_n:
                best, best_n = i, eff
        return best
