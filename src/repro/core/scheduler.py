"""Dynamic scheduling heuristics (paper §6).

The central scheduler answers: which operator next, and how many tuples
(= constant time slice s / per-tuple cost c_i). Heuristics:

- QST (§6.1): queue-size throttling — earliest operator whose *output* queue is
  below its selectivity-scaled threshold T_i = C·cs_i / Σ cs_j.
- LP  (§6.2): last-in-pipeline — latest schedulable operator.
- ET  (§6.3): estimated worklist completion time p_i = I_i·c_i/(w_i+1), max wins.
- CT  (§6.4): normalized current-window throughput n_i = (T_i^w + w_i·s)/(c_i·cs_i),
  min wins (the bottleneck operator).

All consider only *schedulable* operators: w_i < M_i and non-empty worklist.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Tuple

from .operators import OperatorNode

HEURISTICS = ("qst", "lp", "et", "ct")


class Scheduler:
    """Central scheduler data structure (paper §2.2/§6)."""

    def __init__(
        self,
        nodes: List[OperatorNode],
        heuristic: str = "ct",
        *,
        time_slice: float = 0.002,  # s, the constant slice (paper §6)
        capacity: int = 4096,  # C for QST
        window: float = 0.05,  # w for CT
    ):
        if heuristic not in HEURISTICS:
            raise ValueError(f"unknown heuristic {heuristic!r}; pick from {HEURISTICS}")
        self.nodes = nodes
        self.heuristic = heuristic
        self.time_slice = time_slice
        self.capacity = capacity
        self.window = window
        self._lock = threading.Lock()
        self._window_start = time.perf_counter()
        # cumulative selectivity cs_i = prod_{k<=i} s_k (priors blended w/ estimates)
        self._cs_cache: list[float] = [1.0] * len(nodes)

    # ------------------------------------------------------------------ utils
    def _cost(self, i: int) -> float:
        n = self.nodes[i]
        return max(n.stats.cost(n.spec.cost_us * 1e-6), 1e-9)

    def _selectivity(self, i: int) -> float:
        n = self.nodes[i]
        return n.stats.selectivity(n.spec.selectivity)

    def _cum_selectivities(self) -> list[float]:
        cs, acc = [], 1.0
        for i in range(len(self.nodes)):
            acc *= self._selectivity(i)
            cs.append(max(acc, 1e-9))
        return cs

    def _budget(self, i: int) -> int:
        return max(1, int(self.time_slice / self._cost(i)))

    def _schedulable(self) -> list[int]:
        return [i for i, n in enumerate(self.nodes) if n.schedulable()]

    # ---------------------------------------------------------------- acquire
    def acquire(self) -> Optional[Tuple[OperatorNode, int]]:
        """Pick (node, tuple budget) for a worker, or None if nothing to do."""
        with self._lock:
            idx = self._pick()
            if idx is None:
                return None
            node = self.nodes[idx]
            node.workers.fetch_add(1)
            return node, self._budget(idx)

    def release(self, node: OperatorNode) -> None:
        node.workers.fetch_sub(1)

    # ----------------------------------------------------------------- picks
    def _pick(self) -> Optional[int]:
        cand = self._schedulable()
        if not cand:
            return None
        if self.heuristic == "lp":
            return cand[-1]
        if self.heuristic == "qst":
            return self._pick_qst(cand)
        if self.heuristic == "et":
            return self._pick_et(cand)
        return self._pick_ct(cand)

    def _pick_qst(self, cand: list[int]) -> Optional[int]:
        cs = self._cum_selectivities()
        total = sum(cs)
        for i in cand:
            if i + 1 >= len(self.nodes):
                return i  # last operator: egress is unbounded
            threshold = self.capacity * cs[i] / total
            if self.nodes[i + 1].worklist_size() < max(threshold, 1.0):
                return i
        return cand[0]  # all throttled: fall back to earliest (keeps progress)

    def _pick_et(self, cand: list[int]) -> int:
        best, best_p = cand[0], -1.0
        for i in cand:
            n = self.nodes[i]
            p = n.worklist_size() * self._cost(i) / (n.workers.load() + 1)
            if p > best_p:
                best, best_p = i, p
        return best

    def _pick_ct(self, cand: list[int]) -> int:
        now = time.perf_counter()
        if now - self._window_start > self.window:
            for n in self.nodes:
                n.stats.window_busy = 0.0
            self._window_start = now
        cs = self._cum_selectivities()
        best, best_n = cand[0], float("inf")
        for i in cand:
            n = self.nodes[i]
            eff = (n.stats.window_busy + n.workers.load() * self.time_slice) / (
                self._cost(i) * cs[i]
            )
            if eff < best_n:
                best, best_n = i, eff
        return best
