"""Staged process-parallel execution backend (sidesteps the GIL).

The threaded :class:`~.runtime.StreamRuntime` can never exceed ~1 core of
real Python work; this backend runs the pipeline on **forked OS processes**
connected by shared-memory exchange edges (:mod:`.shm`):

  parent ──▶ stage₀ workers ──exchange──▶ stage₁ workers ──…──▶ parent
             (W₀ procs)       (router)     (W₁ procs)          (egress)

Execution model (pipeline × data parallelism over *stages*):

- The operator chain/DAG prefix is cut into **stages** at partitioned/
  stateful boundaries: a stage is either a run of stateless operators
  (round-robin routing, ``num_workers``-way data parallel), a partitioned
  operator plus its trailing stateless run (**keyed** routing by the
  operator's partitioner, so per-key state never crosses workers), or a
  stateful operator plus trailing stateless run (one worker — the operator's
  intrinsic serial constraint, but it still leaves the parent and overlaps
  with every other stage).  Anything uncuttable (``Split``/``Merge`` regions,
  fan-out) remains a **tail** executed in the parent after the final reorder.
  ``stages=1`` reproduces the PR-2 ingress-only plan; ``stages=None`` (the
  default) cuts as deep as the graph allows.

- Each stage owns an :class:`~.shm.ExchangeRing`: per-worker ingress SPSC
  rings in, one serial-number reorder ring out (the paper's fig. 4
  non-blocking buffer, per stage).  The stage's *feeder* — the parent for
  stage 0, an **exchange router** process for every interior stage — drains
  the previous stage's reorder ring (already in stream order), assigns
  per-tuple serials, seals micro-batches of ``io_batch`` tuples, and routes
  them round-robin or by key.  Workers publish results under those serials:
  contiguous round-robin units as one span slot, keyed units one slot per
  tuple — per-worker batches carry per-tuple serials precisely so the
  downstream drain restores the cross-worker interleave order (this is what
  lets ``batch_size``/``io_batch`` and keyed stages compose).  End-of-stream
  is an in-band ``TAG_EOF`` published by each feeder at ``last_serial + 1``;
  ring contiguity delays it behind every real result, so EOF cascades stage
  by stage until the parent sees it at egress.

- The parent is a thin supervisor: it seals ingress units, drains the final
  reorder ring (running the uncuttable tail graph, if any, in serial order),
  monitors every child process, forwards spill bundles to the router that
  needs them, and aggregates stats.  It executes no operator ``fn`` bodies
  when the graph is fully staged (feeders — parent and routers — do still
  evaluate a keyed stage's ``key_fn``/``partitioner`` to route tuples, so
  those two callables must be cheap, exception-free, and fork-safe).

Crash tolerance: workers consume their ingress ring with peek → process →
publish → advance, so a killed worker strands at most one uncommitted unit
in shared memory; the parent re-forks a replacement onto the same rings and
the unit is transparently re-processed (duplicate publishes are idempotent —
see :mod:`.shm` — which requires segment functions to be **deterministic**).
Stateless stages recover this way per-worker.  Keyed/stateful stages
recover via **epoch checkpointing** (:mod:`.checkpoint`): the stage's
feeder stamps ``TAG_BARRIER`` records every ``checkpoint_interval`` serials
and keeps a replay log of every unit it pumped since the last complete
epoch; workers snapshot their state at each barrier and ack it to a
supervisor-held :class:`~.checkpoint.CheckpointStore`.  On a keyed/stateful
worker crash the supervisor halts the feeder, kills the rest of the group,
resets the ingress rings, re-forks the group preloaded with the epoch
snapshots, and re-pumps the log — per-serial publish idempotence makes the
recovered egress exact.  (``checkpoint_interval=0`` or
``restart_on_crash=False`` restores the old behaviour: such a crash
raises.)  Routers keep a crash-atomic *commit record* in the upstream
reorder header (:meth:`~.shm.ShmReorderRing.commit`) and are likewise
re-forked on death, resuming at the committed (read position, downstream
serial) pair; downstream duplicates are absorbed by per-serial publish
idempotence (stateless stages) or a worker-side ``last_seen`` trim
(keyed/stateful stages — state must not be double-applied).  A hung-not-
dead process (e.g. SIGSTOP) is caught by the supervisor's stall detector:
every worker/router bumps a monotone shm heartbeat, and a counter frozen
longer than ``stall_timeout`` gets SIGKILLed into the ordinary crash path.
Out of scope (documented): simultaneous death of a router and one of its
downstream workers, and a keyed/stateful crash after its feeder exited.

Deterministic fault injection (:mod:`.faults`) drives the chaos battery:
supervisor-side kill/hang/router-kill faults fire off drained-serial
counters; worker-side ``op_error``/``spill_delay`` faults ride fork
arguments.  Operator exceptions pass a per-op ``on_error`` policy —
``raise`` | ``skip`` | ``dead_letter`` — with quarantined tuples shipped to
the parent's ``dead_letters``.

Payloads ride fixed-width ring slots (units and result bundles pickled,
single int/float results raw); result bundles too large for a reorder slot
spill to the worker's pipe with a spill tag left in the ring, preserving
order — the parent relays spill bodies to the router that drains them.
With ``columnar=True`` fixed-width numeric units skip pickle entirely:
feeders seal them as ``TAG_COLBLOCK`` span slots (:mod:`repro.columnar`),
workers decode the column vectors zero-copy, and 1:1 numeric results ride
back out the same way.  Device stages work either way — with columnar off
the device worker converts pickled tuples to columns itself, serially —
so the knob is an honest pickle-vs-columnar A/B even on device chains.

**Device stages** (``OpSpec.kind == "device"``) are a fourth stage kind:
each worker wraps its op in a :class:`~repro.columnar.DeviceExecutor`,
accumulating columnar units to ``device_batch`` rows and dispatching them
asynchronously to a jax/pallas kernel (double-buffered; NumPy reference
without jax).  Because a device batch spans ingress units, the worker
must commit its ring cursor *before* publishing — so device stages are
not re-fork-recoverable and instead ride the keyed/stateful checkpoint +
replay-log group restore (publishes stay per-serial guarded, and
elementwise kernels make results independent of batch regrouping).  A
device worker also flushes partial batches on barriers, EOF, and upstream
stalls, so an idle pipeline can never wedge on rows parked below the
batch threshold.
"""
from __future__ import annotations

import collections
import itertools
import multiprocessing
import os
import pickle
import signal
import threading
import time
import uuid
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .checkpoint import CheckpointStore, decode_barrier, encode_barrier
from .costmodel import (
    CostModel,
    OccupancyMonitor,
    TrafficMonitor,
    default_budget,
)
from .faults import (
    DeadLetter, FaultPlan, HANG, InjectedFault, KILL, OP_ERROR, ROUTER_KILL,
    SPILL_DELAY, resolve_policies,
)
from .operators import DEVICE, OpSpec, PARTITIONED, STATEFUL, STATELESS, _Marker
from .pipeline import GraphPipeline, Merge, NodeSpec, Split, percentile_latencies
from .runtime import RunReport
from . import shm

_PICKLE = pickle.HIGHEST_PROTOCOL

# Optional coverage hook for forked children: they exit via os._exit (no
# atexit), so the coverage gate (scripts/coverage_gate.py) installs a dump
# callable here pre-fork; workers/routers invoke it right before _exit.
_COV_HOOK: Optional[Callable[[], None]] = None


# Idle-nap tuning for child processes.  On this class of kernel a single
# time.sleep() costs ~50 µs of CPU regardless of the requested duration, so
# liveness comes from napping LESS OFTEN, not napping shorter: floors start
# high enough to avoid micro-nap storms and caps bound the wake rate of a
# starved process (the latency cost is ms-scale on drain edges only).
_IDLE_MIN = 2e-5
_IDLE_MAX = 2e-3
_CONN_POLL_IVL = 0.005  # router-side parent-pipe poll period (spills/control)


def _sig_raise(signum, frame):
    """SIGTERM/SIGINT handler installed while a stream is live: convert the
    signal into SystemExit so the supervisor's ``finally: stop()`` path reaps
    children and unlinks every shm segment.  The handler body must stay
    lock-free (analysis rule FS303): it can interrupt the supervisor at an
    arbitrary bytecode, including inside pipe/lock internals."""
    raise SystemExit(128 + signum)


class UnstagedGraphWarning(UserWarning):
    """``backend="process"`` could not stage part of the graph.

    Routing nodes (``Split``/``Merge``) and everything downstream of them run
    serially in the parent tail, so their throughput is bounded by one core.
    ``unstaged`` names the nodes left in the tail.
    """

    def __init__(self, unstaged: Sequence[str]):
        self.unstaged = tuple(unstaged)
        super().__init__(
            "backend='process' cannot stage routing nodes: "
            f"{', '.join(self.unstaged)} run(s) serially in the parent tail "
            "(throughput bounded by the parent core); restructure the graph "
            "into a linear prefix or use backend='thread' for "
            "Split/Merge-heavy graphs"
        )


def _chain_nodes(specs: Sequence[OpSpec]):
    names = [f"{i:03d}_{s.name}" for i, s in enumerate(specs)]
    return dict(zip(names, specs)), list(zip(names, names[1:]))


# ------------------------------------------------------------------ stage plan
@dataclass
class StagePlan:
    """One process stage: a worker group executing a run of operators."""

    kind: str  # "stateless" | "keyed" | "stateful" | "device"
    ops: List[OpSpec] = field(default_factory=list)
    workers: int = 1
    index: int = 0
    # Ring headroom for elastic replanning: the exchange is built with this
    # many ingress rings so the live group can be re-forked wider than its
    # initial width without re-creating shared memory.  0 = no headroom.
    max_workers: int = 0

    @property
    def recoverable(self) -> bool:
        """Only stateless stages survive a worker crash (no lost state).
        Device stages are stateless in the fn sense but advance their ring
        cursor before publishing (batches span units), so they recover via
        the checkpoint/replay-log path, not per-worker re-fork."""
        return all(op.kind == STATELESS for op in self.ops)

    @property
    def resizable(self) -> bool:
        """Elastic replanning can re-fork this stage at a new width:
        stateless trivially, keyed via quiesced state migration; stateful
        stages are pinned at one worker and device stages at their
        ``device_workers`` width (PV410 verifies the pin)."""
        return (
            self.kind not in ("stateful", "device")
            and max(self.max_workers, 1) > 1
        )

    def describe(self) -> str:
        names = ",".join(op.name for op in self.ops) or "<identity>"
        return f"stage{self.index}[{self.kind} x{self.workers}: {names}]"


def _plan_stages(
    nodes: Dict[str, NodeSpec],
    edges: Sequence[Tuple[str, str]],
    num_workers: int,
    max_stages: Optional[int],
    allocate: Optional[Callable[[List["StagePlan"]], List[int]]] = None,
    device_workers: int = 1,
):
    """Cut the graph's linear ingress prefix into stages.

    Returns ``(stages, tail_nodes, tail_edges)``.  The walk stops at the
    first routing node (Split/Merge) or fan-out — that remainder is the
    parent-side tail.  ``max_stages=1`` reproduces the ingress-only plan
    (maximal stateless run, or leading partitioned op + stateless run).

    ``allocate`` replaces the flat ``num_workers`` width with a cost-model
    allocation: called with the stage list, it returns one width per stage
    (see :meth:`~.costmodel.CostModel.allocate`); stateful stages stay
    pinned at 1 regardless."""
    cap = max_stages if max_stages and max_stages > 0 else (1 << 30)
    succ: dict[str, list] = {n: [] for n in nodes}
    pred: dict[str, list] = {n: [] for n in nodes}
    for u, v in edges:
        succ[u].append(v)
        pred[v].append(u)
    sources = [n for n in nodes if not pred[n]]
    if len(sources) != 1:
        raise ValueError(f"graph needs exactly one ingress (got {sources})")

    stages: list[StagePlan] = []
    cur_ops: list[OpSpec] = []
    cur_kind: Optional[str] = None
    seg_names: set[str] = set()

    def close_stage():
        nonlocal cur_ops, cur_kind
        if cur_ops:
            w = 1 if cur_kind == "stateful" else num_workers
            stages.append(StagePlan(cur_kind, cur_ops, w, len(stages)))
        cur_ops, cur_kind = [], None

    cur: Optional[str] = sources[0]
    while cur is not None:
        spec = nodes.get(cur)
        if not isinstance(spec, OpSpec) or len(succ.get(cur, ())) > 1:
            break
        if spec.kind == STATELESS:
            if cur_kind is None:
                if len(stages) >= cap:
                    break
                cur_kind = "stateless"
        elif spec.kind == DEVICE:
            # A device op owns its stage alone (the worker body is the batch
            # executor, not the segment interpreter) at a width pre-pinned to
            # device_workers — the cost-model allocator never touches it.
            close_stage()
            if len(stages) >= cap:
                break
            dw = max(int(device_workers), 1)
            stages.append(
                StagePlan("device", [spec], dw, len(stages), max_workers=dw)
            )
            seg_names.add(cur)
            cur = succ[cur][0] if succ[cur] else None
            continue
        else:  # partitioned/stateful operators must head their own stage
            close_stage()
            if len(stages) >= cap:
                break
            cur_kind = "keyed" if spec.kind == PARTITIONED else "stateful"
        cur_ops.append(spec)
        seg_names.add(cur)
        cur = succ[cur][0] if succ[cur] else None
    close_stage()

    if not stages:  # routing-headed graph: identity pass-through stage
        stages = [StagePlan("stateless", [], num_workers, 0)]
    if allocate is not None:
        widths = allocate(stages)
        for plan, w in zip(stages, widths):
            if plan.kind not in ("stateful", "device"):
                plan.workers = max(int(w), 1)
    tail_nodes = {k: v for k, v in nodes.items() if k not in seg_names}
    tail_edges = [(u, v) for u, v in edges if u not in seg_names]
    return stages, tail_nodes, tail_edges


# ------------------------------------------------------------- worker process
def _init_states(ops: Sequence[OpSpec]) -> list:
    return [
        [op.init_state()] if op.kind == STATEFUL else {} for op in ops
    ]


def _apply_segment(ops: Sequence[OpSpec], states: list, value: Any) -> list:
    """Flat-map ``value`` through the stage's operator run (worker-side)."""
    vals = [value]
    for oi, op in enumerate(ops):
        nxt: list = []
        if op.kind in (STATELESS, DEVICE):  # device: per-value reference fn
            fn = op.fn
            for v in vals:
                nxt.extend(fn(v))
        elif op.kind == STATEFUL:  # single-worker stage: one state box
            box = states[oi]
            for v in vals:
                box[0], outs = op.fn(box[0], v)
                nxt.extend(outs)
        else:  # partitioned: per-key state, worker-local (keyed routing)
            st_map = states[oi]
            for v in vals:
                k = op.key_fn(v)
                s = st_map.get(k)
                if s is None:
                    s = op.init_state()
                s, outs = op.fn(s, k, v)
                st_map[k] = s
                nxt.extend(outs)
        vals = nxt
        if not vals:
            break
    return vals


def _apply_segment_safe(ops, states, value, policies):
    """Policy-guarded :func:`_apply_segment`: an operator exception checks
    its op's ``on_error`` policy — ``raise`` propagates, ``skip``/
    ``dead_letter`` drop the input tuple's whole remaining expansion at that
    op and return ``(outs_so_far=[], (op_name, error, policy))``.  Ops
    earlier in the run have already seen the tuple (their state mutations
    stand); the quarantine covers the op that raised."""
    vals = [value]
    for oi, op in enumerate(ops):
        try:
            nxt: list = []
            if op.kind in (STATELESS, DEVICE):
                fn = op.fn
                for v in vals:
                    nxt.extend(fn(v))
            elif op.kind == STATEFUL:
                box = states[oi]
                for v in vals:
                    box[0], outs = op.fn(box[0], v)
                    nxt.extend(outs)
            else:
                st_map = states[oi]
                for v in vals:
                    k = op.key_fn(v)
                    s = st_map.get(k)
                    if s is None:
                        s = op.init_state()
                    s, outs = op.fn(s, k, v)
                    st_map[k] = s
                    nxt.extend(outs)
        except BaseException as exc:  # noqa: BLE001 — policy decides
            pol = policies[oi]
            if pol == "raise":
                raise
            return [], (op.name, f"{type(exc).__name__}: {exc}", pol)
        vals = nxt
        if not vals:
            break
    return vals, None


def _publish(reorder, conn, serial, tag, data, span, beat=None,
             spill_delay=None) -> None:
    """Publish one result slot, spilling oversized bodies via the pipe; spins
    (with teardown escape) while the reorder window is full.  ``beat`` keeps
    the worker's heartbeat live through a long FULL spin (backpressure is
    not a stall); ``spill_delay`` is the fault-injection hook."""
    if len(data) > reorder.payload_bytes:
        if spill_delay:
            spec = spill_delay.pop(serial, None)
            if spec is not None:
                time.sleep(spec.delay)
        conn.send(("spill", serial, tag, data))  # body via pipe, before the tag
        tag, data = shm.TAG_SPILL, b""
    spin = _IDLE_MIN
    while True:
        st = reorder.try_publish(serial, tag, data, span)
        if st != shm.ShmReorderRing.FULL:
            return
        if reorder.stopped():
            return
        if beat is not None:
            beat()
        time.sleep(spin)
        spin = min(spin * 2, _IDLE_MAX)


def _worker_main(wid, ingress, reorder, conn, seg_ops, preload=None,
                 stage=0, dedup=False, policies=None, child_faults=None,
                 columnar=False, dev_cfg=None):
    """Stage worker body (entered via fork; exits with os._exit).

    Consumes peek → process → publish → advance so a crash strands at most
    one uncommitted unit (see module docstring).  ``preload`` carries
    migrated per-key state (elastic resize) or a restored epoch snapshot
    (crash recovery).  ``dedup`` (keyed/stateful stages) arms the
    ``last_seen`` serial trim so duplicate units re-dispatched by a
    restarted router are never re-applied to state.  Every publish is
    guarded by :meth:`~.shm.ShmReorderRing.published` — replayed or
    duplicate serials whose result already landed are skipped, never
    republished (a second publisher could race the slot's reuse).

    ``policies`` is one ``on_error`` policy per op (positional);
    ``child_faults`` carries this worker's injected ``op_error``/
    ``spill_delay`` triggers keyed by serial.

    ``columnar`` arms the result-side columnar codec (1:1 numeric results
    publish as ``TAG_COLBLOCK`` instead of pickled ``TAG_BUNDLES``);
    columnar *ingress* needs no flag — any worker decodes ``TAG_COLBLOCK``
    units on arrival.  ``dev_cfg`` is ``(device_batch, device_inflight,
    device_backend)`` for device stages, whose whole worker body is the
    batch-executor path (see the module docstring)."""
    ingress.sync_consumer()  # crash replacement: resume at the shared cursor
    states = preload if preload is not None else _init_states(seg_ops)
    busy = 0.0
    processed = 0
    code = 0
    beat = ingress.beat
    last_seen = 0  # highest serial applied to state (dedup stages only)
    guarded = policies is not None and any(p != "raise" for p in policies)
    op_err = (child_faults or {}).get(OP_ERROR) or None
    spill_delay = (child_faults or {}).get(SPILL_DELAY) or None
    dead: list = []  # (serial, op, value, error) quarantined this unit

    # Columnar plumbing — imported lazily so non-columnar streams never pay
    # the numpy import in every forked child.
    col = None  # repro.columnar.codec module
    colout = None  # result-side codec (columnar-armed non-device stages)
    executor = None  # DeviceExecutor (device stages)
    ColumnBlock = None
    if seg_ops and seg_ops[0].kind == DEVICE:
        from ..columnar import codec as col
        from ..columnar.block import ColumnBlock
        from ..columnar.device import DeviceExecutor

        dbatch, dinflight, dbackend = dev_cfg or (256, 2, "auto")
        executor = DeviceExecutor(
            seg_ops[0], batch=dbatch, inflight=dinflight, backend=dbackend
        )
    elif columnar:
        from ..columnar import codec as col

        colout = col.ColumnarCodec()

    def publish_block(out) -> None:
        # ordered-egress boundary: the executor synchronised `out` already;
        # publish rides the generic span/spill path under the block's head
        if not reorder.published(out.head_serial):
            _publish(reorder, conn, out.head_serial, shm.TAG_COLBLOCK,
                     col.encode_block(out), len(out), beat, spill_delay)

    def apply_one(serial, v):
        if op_err is not None and serial in op_err:
            op_err.pop(serial)
            msg = f"injected operator error at serial {serial}"
            pol = policies[0] if policies else "raise"
            if pol == "raise":
                raise InjectedFault(msg)
            err = (seg_ops[0].name if seg_ops else "<injected>",
                   f"InjectedFault: {msg}", pol)
            outs = []
        elif guarded:
            outs, err = _apply_segment_safe(seg_ops, states, v, policies)
        else:
            outs, err = _apply_segment(seg_ops, states, v), None
        if err is not None and err[2] == "dead_letter":
            dead.append((serial, err[0], v, err[1]))
        return outs

    try:
        idle = _IDLE_MIN
        while True:
            beat()
            # Sample the close flags BEFORE peeking: the producer publishes
            # its last records before setting closed, and stores are ordered,
            # so a peek issued after an observed close cannot miss a queued
            # record.  Peek-then-check races — an empty peek, then put+close
            # by the router, then the closed() read exits the worker with a
            # record abandoned in the ring, wedging the downstream reorder.
            closing = ingress.closed() or reorder.stopped()
            rec = ingress.peek()
            if rec is None:
                if (
                    executor is not None
                    and (executor.pending_rows or executor.inflight)
                    and (closing or idle >= 1e-3)
                ):
                    # liveness: an upstream stall (or EOF) must not park rows
                    # below the batch threshold — the inflight window could be
                    # wedged on exactly those serials.  Elementwise kernels
                    # make the partial-batch flush result-identical.
                    for out in executor.flush():
                        publish_block(out)
                if closing:
                    break
                time.sleep(idle)
                idle = min(idle * 2, _IDLE_MAX)
                continue
            idle = _IDLE_MIN
            serial, tag, data, nslots = rec
            if tag == shm.TAG_BARRIER:
                if executor is not None:
                    # every serial below the boundary must be published
                    # before the epoch can complete — once the replay log
                    # truncates at the boundary, unpublished older rows
                    # would be unrecoverable
                    for out in executor.flush():
                        publish_block(out)
                # epoch checkpoint: snapshot state-after-serials-< boundary
                # and ack over the pipe; nothing reaches the reorder ring.
                # Acking before advance keeps the snapshot ≤1 barrier stale
                # on a crash, and replayed barriers re-ack idempotently.
                epoch = decode_barrier(data)
                conn.send(("ckpt", wid, epoch, serial,
                           pickle.dumps(states, _PICKLE)))
                ingress.advance(nslots)
                continue
            t_begin = time.perf_counter()
            if tag == shm.TAG_KUNIT:
                serials, values, marks = pickle.loads(data)
                if dedup and serials and serials[0] <= last_seen:
                    # duplicate prefix from a restarted feeder: already
                    # applied AND published by this same worker (keyed
                    # routing is deterministic) — trim, don't re-apply
                    cut = 0
                    while cut < len(serials) and serials[cut] <= last_seen:
                        cut += 1
                    serials = serials[cut:]
                    values = values[cut:]
                    marks = [(i - cut, m) for i, m in marks if i >= cut]
                    if not serials:
                        ingress.advance(nslots)
                        continue
                by_off = dict(marks) if marks else None
                results = []
                for i, v in enumerate(values):
                    m = by_off.get(i) if by_off else None
                    if m is not None and not m.begin:
                        m.begin = time.perf_counter()
                    results.append((serials[i], apply_one(serials[i], v), m))
                if dedup:
                    last_seen = serials[-1]
                processed += len(values)
                busy += time.perf_counter() - t_begin
                # Per-SERIAL results so the downstream drain restores the
                # cross-worker interleave — but published as ONE batched
                # TAG_KBUNDLES slot at the unit's first serial (the drainer
                # scatter-stashes the rest), so reorder-ring traffic stays
                # per-unit.  Oversized batches fall back to per-tuple slots
                # (which may individually spill).  Both modes are publish-
                # guarded: the batching decision is deterministic, so a
                # crash-replayed unit re-derives exactly the slot shape its
                # predecessor used and the head check is exact.
                entries = []
                for s, outs, m in results:
                    if m is None:
                        btag, bdata = shm.encode_bundle(outs)
                    else:
                        if not outs:
                            m.exit = time.perf_counter()
                        btag, bdata = shm.TAG_MBUNDLE, pickle.dumps((outs, m), _PICKLE)
                    entries.append((s, btag, bdata))
                blob = pickle.dumps(entries, _PICKLE) if len(entries) > 1 else b""
                if len(entries) > 1 and len(blob) <= reorder.payload_bytes:
                    if not reorder.published(entries[0][0]):
                        _publish(reorder, conn, entries[0][0],
                                 shm.TAG_KBUNDLES, blob, 1, beat, spill_delay)
                else:
                    for s, btag, bdata in entries:
                        if not reorder.published(s):
                            _publish(reorder, conn, s, btag, bdata, 1,
                                     beat, spill_delay)
            else:  # TAG_UNIT/TAG_COLBLOCK: contiguous span [serial, serial+len)
                block = None
                if tag == shm.TAG_COLBLOCK:
                    if col is None:  # upstream device stage, columnar off
                        from ..columnar import codec as col
                    block = col.decode_block(data)
                    values, marks = None, block.marks
                else:
                    values, marks = pickle.loads(data)
                if executor is not None:
                    blk = block
                    if blk is None:
                        blk = ColumnBlock.from_values(
                            values, head_serial=serial, marks=marks,
                            schema=executor.schema,
                        )
                    elif blk.schema != executor.schema:
                        blk = ColumnBlock.from_values(
                            blk.to_values(), head_serial=serial, marks=marks,
                            schema=executor.schema,
                        )
                    if blk is not None:
                        for _, m in blk.marks:
                            if not m.begin:
                                m.begin = t_begin
                        ready = executor.submit(blk)
                        processed += len(blk)
                        busy += time.perf_counter() - t_begin
                        # Commit BEFORE publish: the device batch spans
                        # ingress units, so this worker can never be replayed
                        # by per-worker re-fork — device stages recover via
                        # the checkpoint/replay-log group restore, and the
                        # per-serial publish guards absorb replayed
                        # duplicates however the batches regroup.
                        ingress.advance(nslots)
                        for out in ready:
                            publish_block(out)
                        continue
                    # off-schema unit: per-value reference fallback below
                if values is None:
                    values = block.to_values()
                if dedup and serial <= last_seen:
                    cut = min(last_seen + 1 - serial, len(values))
                    values = values[cut:]
                    marks = [(i - cut, m) for i, m in marks if i >= cut]
                    serial += cut
                    if not values:
                        ingress.advance(nslots)
                        continue
                by_off = dict(marks) if marks else None
                bundles: list = []
                out_marks: list = []
                dropped: list = []
                for i, v in enumerate(values):
                    m = by_off.get(i) if by_off else None
                    if m is not None and not m.begin:
                        m.begin = time.perf_counter()
                    outs = apply_one(serial + i, v)
                    bundles.append(outs)
                    if m is not None:
                        if outs:
                            out_marks.append((i, m))
                        else:
                            m.exit = time.perf_counter()
                            dropped.append(m)
                if dedup:
                    last_seen = serial + len(values) - 1
                processed += len(values)
                busy += time.perf_counter() - t_begin
                if not reorder.published(serial):
                    enc = None
                    if colout is not None and not dropped and all(
                        len(b) == 1 for b in bundles
                    ):
                        # 1:1 numeric results stay columnar end-to-end; the
                        # slot shape (head, span) matches the TAG_BUNDLES
                        # fallback exactly, so the replay head check is
                        # indifferent to which encoding a predecessor chose
                        enc = colout.try_encode_unit(
                            [b[0] for b in bundles], out_marks, serial
                        )
                    if enc is not None:
                        _publish(reorder, conn, serial, shm.TAG_COLBLOCK,
                                 enc[0], len(values), beat, spill_delay)
                    else:
                        bdata = pickle.dumps(
                            (bundles, out_marks, dropped), _PICKLE
                        )
                        _publish(
                            reorder, conn, serial, shm.TAG_BUNDLES, bdata,
                            len(values), beat, spill_delay,
                        )
            if dead:
                conn.send(("dead", wid, dead))
                dead = []
            ingress.advance(nslots)  # commit only after the publish (replay)
    except BaseException as exc:  # noqa: BLE001 — forwarded to the parent
        code = 70
        try:
            conn.send(("error", wid, f"{type(exc).__name__}: {exc}"))
        except Exception:
            pass
    try:
        if code == 0 and ingress.handoff_requested():
            # elastic resize: the group is quiesced; hand worker-local state
            # back so the supervisor can re-shard it across the new width
            conn.send(("state", wid, pickle.dumps(states, _PICKLE)))
        conn.send(("stats", wid, busy, processed))
        conn.close()
    except Exception:
        pass
    if _COV_HOOK is not None:
        _COV_HOOK()
    os._exit(code)  # skip inherited atexit/resource_tracker teardown


# ------------------------------------------------------------------ dispatcher
class _Dispatcher:
    """The feeder half of an exchange edge: assigns per-tuple serials in
    stream order, seals ``io_batch``-sized units, and routes them into a
    stage's ingress rings (keyed for partitioned stages, round-robin
    otherwise).  Used by the parent (stage 0) and by every router."""

    def __init__(self, exchange: shm.ExchangeRing, plan: StagePlan,
                 io_batch: int, max_inflight: int, ckpt_interval: int = 0,
                 columnar: bool = False):
        self.x = exchange
        self.plan = plan
        self.workers = plan.workers  # ACTIVE width (<= exchange.consumers)
        self.io_batch = max(1, io_batch)
        self.max_inflight = max_inflight
        self.paused = False  # elastic replan: gate intake + liveness flushes
        self.keyed = plan.kind == "keyed"
        # Columnar sealing (non-keyed only — keyed units carry explicit
        # per-tuple serials and stay pickled).  Armed by the ``columnar``
        # knob alone: device workers accept both pickled units (converting
        # per tuple, serially) and TAG_COLBLOCK spans (zero-copy ingest),
        # so the flag is an honest A/B switch.  When feeding a device stage
        # the codec is pinned to the op's declared schema so blocks arrive
        # ready-typed.
        self._codec = None
        if columnar and not self.keyed:
            from ..columnar.codec import ColumnarCodec

            schema = (
                plan.ops[0].schema
                if plan.kind == "device" and plan.ops else None
            )
            self._codec = ColumnarCodec(schema)
        # Epoch checkpointing (keyed/stateful stages only): stamp a barrier
        # every ckpt_interval serials and keep a per-ring replay log of
        # every record pumped since the last COMPLETE epoch — the group-
        # restore recovery source (see module docstring).
        self.ckpt_interval = max(int(ckpt_interval or 0), 0)
        self.epoch = 0
        self._last_boundary = 0
        self._next_boundary = (
            1 + self.ckpt_interval if self.ckpt_interval else None
        )
        self._log: list[collections.deque] = [
            collections.deque() for _ in range(exchange.consumers)
        ]
        # accumulators/queues sized at the exchange's max width so an elastic
        # resize only moves the active-width cursor, never reallocates
        if self.keyed:
            head = plan.ops[0]
            self._key_fn, self._part = head.key_fn, head.partitioner
            # per-worker accumulators: (serials, values, marks)
            self._acc = [([], [], []) for _ in range(exchange.consumers)]
        else:
            self._vals: list = []
            self._marks: list = []
            self._head_serial = 1
        self.next_serial = 1
        self._rr = itertools.cycle(range(self.workers))
        # sealed units awaiting ring space: per-worker FIFO (keyed units must
        # stay ordered per ring; cross-ring order is restored by the reorder)
        self._outq: list[collections.deque] = [
            collections.deque() for _ in range(exchange.consumers)
        ]
        self._queued = 0

    def set_workers(self, w: int) -> None:
        """Elastic resize: point routing at the new active width.  Only legal
        on a quiesced dispatcher (accumulators and out-queues empty — the
        supervisor's pause → quiesce protocol guarantees it)."""
        self.workers = w
        self._rr = itertools.cycle(range(w))

    # -- intake gate --------------------------------------------------------
    def inflight(self) -> int:
        return self.next_serial - self.x.reorder.shared_next()

    def ready(self) -> bool:
        """Whether the feeder should accept more upstream tuples."""
        return (
            not self.paused
            and self._queued < 2 * self.workers
            and self.inflight() < self.max_inflight
        )

    # -- epoch barriers / replay log ----------------------------------------
    def stamp_barrier(self) -> None:
        """Seal the partials and append one ``TAG_BARRIER`` record per
        active ring: every serial < the boundary precedes it in its ring
        (per-ring FIFO), so a worker's barrier snapshot is exactly the
        state-at-boundary.  Barriers ride the out-queues and the replay log
        like any unit (a restored group re-acks them idempotently)."""
        self.flush()
        b = self.next_serial
        if b == self._last_boundary:  # no serials since the last barrier
            return
        self.epoch += 1
        self._last_boundary = b
        payload = encode_barrier(self.epoch)
        for w in range(self.workers):
            self._outq[w].append((b, shm.TAG_BARRIER, payload))
            self._queued += 1
        self._next_boundary = b + self.ckpt_interval

    def force_barrier(self) -> None:
        """Stamp an out-of-cadence barrier now (supervisor ``ckpt_now``,
        e.g. right after a router restart emptied the replay log)."""
        if self._next_boundary is not None and not self.paused:
            self.stamp_barrier()

    def truncate_log(self, boundary: int) -> None:
        """Epoch complete at ``boundary``: drop replayable records below it
        (units are entirely < or ≥ a boundary — barriers flush first) and
        the completed epoch's own barrier."""
        for q in self._log:
            while q:
                serial, tag, _data = q[0]
                if tag == shm.TAG_BARRIER:
                    if serial > boundary:
                        break
                elif serial >= boundary:
                    break
                q.popleft()

    def requeue_log(self) -> None:
        """Group restore: move the replay log back to the out-queue heads
        (the rings were reset; everything re-logs as it re-pumps)."""
        for w in range(len(self._outq)):
            log = self._log[w]
            if log:
                self._outq[w].extendleft(reversed(log))
                self._queued += len(log)
                self._log[w] = collections.deque()

    def restore_serial(self, serial: int) -> None:
        """Restarted-feeder resume: continue serial assignment exactly
        where the commit record left off."""
        self.next_serial = serial
        if not self.keyed:
            self._head_serial = serial

    # -- sealing ------------------------------------------------------------
    def add(self, value: Any, marker: Optional[_Marker]) -> None:
        if (
            self._next_boundary is not None
            and self.next_serial >= self._next_boundary
        ):
            self.stamp_barrier()
        serial = self.next_serial
        self.next_serial += 1
        if self.keyed:
            w = self._part(self._key_fn(value)) % self.workers
            serials, vals, marks = self._acc[w]
            if marker is not None:
                marks.append((len(vals), marker))
            serials.append(serial)
            vals.append(value)
            if len(vals) >= self.io_batch:
                self._seal_keyed(w)
        else:
            if marker is not None:
                self._marks.append((len(self._vals), marker))
            self._vals.append(value)
            if len(self._vals) >= self.io_batch:
                self._seal_contiguous()

    def _seal_keyed(self, w: int) -> None:
        serials, vals, marks = self._acc[w]
        if not vals:
            return
        self._acc[w] = ([], [], [])
        data = pickle.dumps((serials, vals, marks), _PICKLE)
        self._outq[w].append((serials[0], shm.TAG_KUNIT, data))
        self._queued += 1

    def _seal_contiguous(self) -> None:
        vals, marks = self._vals, self._marks
        if not vals:
            return
        self._vals, self._marks = [], []
        head = self._head_serial
        self._head_serial = self.next_serial
        if self._codec is not None:
            enc = self._codec.try_encode_unit(vals, marks, head)
            if enc is not None:
                self._outq[next(self._rr)].append(
                    (head, shm.TAG_COLBLOCK, enc[0])
                )
                self._queued += 1
                return
        data = pickle.dumps((vals, marks), _PICKLE)
        self._outq[next(self._rr)].append((head, shm.TAG_UNIT, data))
        self._queued += 1

    def add_block(self, block) -> bool:
        """Columnar pass-through: route a whole decoded block as one unit,
        re-stamped with this stage's serials — no per-tuple add, no pickle.
        Returns False when the block must be re-fed per-value instead
        (keyed routing, or a schema pinned to a different layout)."""
        if self.keyed or self._codec is None:
            return False
        if self._codec.schema is None:
            self._codec.schema = block.schema
        elif block.schema != self._codec.schema:
            return False
        if (
            self._next_boundary is not None
            and self.next_serial >= self._next_boundary
        ):
            self.stamp_barrier()
        self._seal_contiguous()  # partial scalar adds precede this block
        from ..columnar.codec import encode_block

        head = self.next_serial
        self.next_serial += len(block)
        self._head_serial = self.next_serial
        data = encode_block(block.with_serials(head))
        self._outq[next(self._rr)].append((head, shm.TAG_COLBLOCK, data))
        self._queued += 1
        return True

    def flush(self) -> None:
        """Seal every partial accumulator (source end / upstream idle)."""
        if self.keyed:
            for w in range(self.workers):
                self._seal_keyed(w)
        else:
            self._seal_contiguous()

    # -- dispatch -----------------------------------------------------------
    def pump(self) -> bool:
        """Move sealed units into ingress rings; True if anything moved.
        With checkpointing armed, every record that enters a ring is also
        appended to that ring's replay log — the log is exactly what was
        pumped since the last complete epoch, in per-ring order."""
        progress = False
        log = self.ckpt_interval > 0
        for w, q in enumerate(self._outq):
            ring = self.x.rings[w]
            while q:
                serial, tag, data = q[0]
                if not ring.put(serial, tag, data):
                    break  # ring full: backpressure, try again later
                q.popleft()
                if log:
                    self._log[w].append((serial, tag, data))
                self._queued -= 1
                progress = True
        return progress

    def pending(self) -> bool:
        return self._queued > 0 or (
            any(acc[1] for acc in self._acc) if self.keyed else bool(self._vals)
        )

    def publish_eof(self) -> bool:
        """Publish the in-band end-of-stream marker at ``last_serial + 1``.
        Contiguity holds it behind every real result.  False while the
        reorder window cannot accept it yet."""
        st = self.x.reorder.try_publish(self.next_serial, shm.TAG_EOF, b"")
        return st != shm.ShmReorderRing.FULL

    def stall_flush(self) -> bool:
        """The feeders' shared liveness rule: when the pipeline stalls,
        release partial units.  Keyed batches fill unevenly, so a waiting
        partial can hold exactly the serial the downstream drain (and
        therefore the inflight window) is blocked on — keeping it would
        deadlock.  Returns True if anything was dispatched.  No-op while the
        dispatcher is paused for an elastic replan (nothing may enter the
        rings mid-quiesce)."""
        if self.paused:
            return False
        self.flush()
        return self.pump()


# -------------------------------------------------------------- router process
def _pump_router_conn(conn, spills, ctrl=None) -> None:
    """Drain parent→router messages (spill bodies + elastic pause/resume
    control, which lands in ``ctrl``); never blocks."""
    try:
        while conn.poll():
            msg = conn.recv()
            if msg[0] == "spill":
                spills[msg[1]] = (msg[2], msg[3])
            elif ctrl is not None:
                ctrl.append(msg)
    except (EOFError, OSError):
        pass


def _await_spill(spills, serial, pump, timeout: float = 10.0, describe=None):
    """Wait (≤ ``timeout`` s) for a spill body to land in ``spills`` via
    ``pump`` — a callable draining pending pipe messages.  Shared by the
    parent (conns sweep) and the routers (parent-relay pipe).  ``describe``
    supplies stage/backlog context for the raise so a lost spill is
    diagnosable from the exception alone."""
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if serial in spills:
            return spills.pop(serial)
        pump()
        time.sleep(0.001)
    ctx = f" ({describe()})" if describe is not None else ""
    raise TimeoutError(
        f"spilled bundle for serial {serial} never arrived within "
        f"{timeout:.1f}s{ctx}; raise spill_timeout in ProcessOptions if the "
        "pipe relay is just slow"
    )


def _router_main(ridx, upstream, exchange, conn, plan, io_batch, max_inflight,
                 ckpt_interval=0, spill_timeout=10.0, columnar=False):
    """Exchange-router body: drain the upstream stage's reorder ring (stream
    order), re-stamp serials, seal/route units into the downstream stage, and
    cascade EOF.  Never runs operator ``fn`` bodies — though keyed routing
    does evaluate the downstream head's ``key_fn``/``partitioner`` here.

    The upstream drain is read-ahead/commit split (restartability): reads
    move a router-local cursor, and the shared window — whose slots double
    as the replay source for a router replacement — advances only at
    :meth:`~.shm.ShmReorderRing.commit` points, taken when everything read
    has durably left router memory (accumulators, out-queues, and scatter
    stash all empty; forced via a flush once read-ahead spans half the
    ring).  A freshly forked replacement resumes from the committed
    (read position, downstream serial) pair via ``sync_drainer``.

    Parent-pipe control verbs: ``("pause",)`` → flush, stop feeding, ack
    ``("paused", ridx, next_serial)`` once drained (elastic quiesce);
    ``("resume", new_width[, boundary])`` → re-point routing (and truncate
    the replay log at the resize's synthetic checkpoint);
    ``("ckpt_done", epoch, boundary)`` → truncate the replay log;
    ``("ckpt_now",)`` → stamp an immediate barrier; ``("halt",)`` → ack
    ``("halted", ridx)`` and block (touching nothing) until
    ``("restore",)`` re-queues the replay log — the downstream group-restore
    window."""
    exchange.sync_feeder()  # restart: reload the ingress producer cursors
    resume_serial = upstream.sync_drainer()  # restart: committed pair
    disp = _Dispatcher(exchange, plan, io_batch, max_inflight, ckpt_interval,
                       columnar=columnar)
    if resume_serial > 1:
        disp.restore_serial(resume_serial)
    committed = upstream.read_pos()
    commit_span = max(upstream.size // 2, 1)
    want_commit = False
    spills: dict[int, tuple[int, bytes]] = {}
    ctrl: collections.deque = collections.deque()

    def pump_conn():
        upstream.beat_drainer()
        _pump_router_conn(conn, spills, ctrl)

    def service_ctrl():
        """Apply queued control verbs; blocks inside a halt window."""
        while ctrl:
            msg = ctrl.popleft()
            if msg[0] == "pause":
                disp.flush()  # seal partials: drain to a serial boundary
                disp.paused = True
                state["acked"] = False
            elif msg[0] == "resume":
                disp.set_workers(msg[1])
                if len(msg) > 2:  # resize = synthetic checkpoint
                    disp.truncate_log(msg[2])
                disp.paused = False
            elif msg[0] == "ckpt_done":
                disp.truncate_log(msg[2])
            elif msg[0] == "ckpt_now":
                disp.force_barrier()
            elif msg[0] == "halt":
                # downstream group restore: ack immediately and freeze —
                # the supervisor is about to reset our ingress rings, so
                # nothing may be pumped until ("restore",) re-queues the log
                conn.send(("halted", ridx))
                while True:
                    upstream.beat_drainer()
                    if ctrl:
                        m2 = ctrl.popleft()
                        if m2[0] == "restore":
                            disp.requeue_log()
                            break
                        continue  # drop stale verbs queued behind the halt
                    if conn.poll(0.01):
                        m2 = conn.recv()
                        if m2[0] == "spill":
                            spills[m2[1]] = (m2[2], m2[3])
                        else:
                            ctrl.append(m2)

    state = {"acked": False}
    describe = lambda: (  # noqa: E731
        f"stage {ridx} router, ingress backlog "
        f"{exchange.backlog_slots()} slots"
    )
    busy = 0.0
    code = 0
    try:
        idle = _IDLE_MIN
        eof = False
        conn_at = 0.0
        while not eof:
            if upstream.stopped():
                break
            now = time.monotonic()
            if now >= conn_at or disp.paused:
                # the parent pipe carries only rare traffic (spill bodies,
                # elastic control): poll it on a period, not per iteration —
                # Connection.poll() is a ~20 µs syscall on this kernel
                conn_at = now + _CONN_POLL_IVL
                pump_conn()
            service_ctrl()
            if disp.paused:
                if disp.pump():
                    continue  # keep moving sealed units into the rings
                if not state["acked"] and not disp.pending():
                    conn.send(("paused", ridx, disp.next_serial))
                    state["acked"] = True
                time.sleep(1e-3)
                continue
            drained = 0
            if disp.ready():
                t0 = time.perf_counter()
                for _ in range(64):  # batch the drain: one pump per sweep
                    got = upstream.read_ahead()
                    if got is None:
                        break
                    t, tag, data, _span = got
                    if tag == shm.TAG_EOF:
                        eof = True
                        break
                    if tag == shm.TAG_SPILL:
                        tag, data = _await_spill(
                            spills, t, pump_conn, spill_timeout, describe
                        )
                    _route_result(disp, conn, tag, data)
                    drained += 1
                if drained:
                    busy += time.perf_counter() - t0
            # commit policy: once read-ahead spans half the upstream window
            # (publishers would soon stall on FULL), flush the partials and
            # take the next safe commit point — everything read durably in
            # the downstream rings, no scatter entries awaiting their serial
            if upstream.read_pos() - committed >= commit_span:
                want_commit = True
                disp.flush()
            if (
                want_commit
                and not disp.pending()
                and not upstream.has_stashed()
            ):
                upstream.commit(disp.next_serial)
                committed = upstream.read_pos()
                want_commit = False
            if drained or eof:
                idle = _IDLE_MIN
                disp.pump()
                continue
            moved = disp.pump()
            if not moved and idle >= 1e-4:
                moved = disp.stall_flush()  # liveness: see _Dispatcher
                if (
                    not moved
                    and not disp.pending()
                    and not upstream.has_stashed()
                    and upstream.read_pos() > committed
                ):
                    # quiescent: bank the progress as a commit point
                    upstream.commit(disp.next_serial)
                    committed = upstream.read_pos()
                    want_commit = False
            if moved:
                idle = _IDLE_MIN
            else:
                time.sleep(idle)
                idle = min(idle * 2, _IDLE_MAX)
        if eof:
            disp.flush()
            spin = _IDLE_MIN
            done = False
            # Control stays serviced through the drain: a downstream group
            # restore can halt us here and refill the queue from the replay
            # log, which re-opens the close_ingress → publish_eof sequence.
            while not done and not exchange.reorder.stopped():
                pump_conn()
                service_ctrl()
                if disp.pending():  # drain our queue into the rings
                    if disp.pump():
                        spin = _IDLE_MIN
                    else:
                        time.sleep(spin)
                        spin = min(spin * 2, _IDLE_MAX)
                    continue
                exchange.close_ingress()  # workers drain the rest, then exit
                if disp.publish_eof():  # cascade EOF downstream
                    done = True
                else:
                    time.sleep(spin)
                    spin = min(spin * 2, _IDLE_MAX)
            if done and not upstream.has_stashed():
                upstream.commit(disp.next_serial)  # final window release
    except BaseException as exc:  # noqa: BLE001
        code = 71
        try:
            conn.send(("error", f"router{ridx}", f"{type(exc).__name__}: {exc}"))
        except Exception:
            pass
    try:
        conn.send(("stats", f"router{ridx}", busy, 0))
        conn.close()
    except Exception:
        pass
    if _COV_HOOK is not None:
        _COV_HOOK()
    os._exit(code)


def _route_result(disp, conn, tag, data) -> None:
    """Flatten one drained result slot into the downstream tuple stream."""
    if tag == shm.TAG_BUNDLES:
        bundles, out_marks, dropped = pickle.loads(data)
        if dropped:  # probes whose tuples were filtered: record at the parent
            conn.send(("marks", dropped))
        mk = dict(out_marks) if out_marks else None
        for i, outs in enumerate(bundles):
            m = mk.get(i) if mk else None
            for j, v in enumerate(outs):
                disp.add(v, m if j == 0 else None)
    elif tag == shm.TAG_MBUNDLE:
        outs, m = pickle.loads(data)
        if not outs and m is not None:
            conn.send(("marks", [m]))
        for j, v in enumerate(outs):
            disp.add(v, m if j == 0 else None)
    elif tag == shm.TAG_COLBLOCK:
        from ..columnar.codec import decode_block

        block = decode_block(data)
        if not disp.add_block(block):
            # keyed routing (or schema mismatch): per-value re-dispatch
            mk = dict(block.marks) if block.marks else None
            for i, v in enumerate(block.to_values()):
                disp.add(v, mk.get(i) if mk else None)
    else:
        for v in shm.decode_bundle(tag, data):
            disp.add(v, None)


# -------------------------------------------------------------- process runtime
class ProcessRuntime:
    """Drives a dataflow graph with staged OS-process worker groups connected
    by shared-memory exchange edges.

    Mirrors the :class:`~.runtime.StreamRuntime` reporting surface
    (``run(source) -> RunReport``) and the pipeline result surface
    (``outputs``, ``egress_count``, ``markers``) so ``run_pipeline``/
    ``run_graph`` can return it in the pipeline slot.

    ``num_workers`` is the worker-group size of each data-parallel stage
    (stateful stages always run one worker); ``stages`` caps how many stages
    the planner may cut (``None`` = as many as the graph allows, ``1`` = the
    ingress-only plan of PR 2).

    ``num_workers="auto"`` replaces the flat width with a cost-model
    allocation (:mod:`.costmodel`): a ``worker_budget`` (default: cores + 1)
    is divided across stages in proportion to their predicted load, from
    declared/explicit ``cost_priors`` or — when no priors are given — a short
    profiled calibration pass over the first ``calibrate_tuples`` source
    tuples.  Auto mode also enables **elastic replanning** (``elastic=True``
    forces it for flat widths too): the supervisor samples per-stage
    occupancy every ``replan_interval`` seconds and, when one stage holds
    more than ``replan_threshold`` of the queued work for
    ``replan_patience`` consecutive samples, quiesces the affected stages at
    a serial-number boundary and re-forks their worker groups at the
    re-estimated widths (keyed state migrates through the quiesced handoff;
    see ``docs/architecture.md``).
    """

    def __init__(
        self,
        nodes: Dict[str, NodeSpec],
        edges: Sequence[Tuple[str, str]],
        *,
        num_workers=4,  # int, or "auto" for cost-model allocation
        marker_interval: int = 64,
        collect_outputs: bool = False,
        io_batch: Optional[int] = None,
        batch_size: int = 1,
        stages: Optional[int] = None,
        ring_slots: int = 2048,
        slot_bytes: int = 1024,
        reorder_size: int = 1024,
        reorder_payload: int = 4096,
        max_inflight: Optional[int] = None,  # dispatch units; default 8/worker
        restart_on_crash: bool = True,
        reorder_scheme: str = "non_blocking",
        worklist_scheme: str = "hybrid",
        worker_budget: Optional[int] = None,
        cost_priors: Optional[Dict[str, float]] = None,
        elastic: Optional[bool] = None,
        calibrate_tuples: int = 64,
        replan_interval: float = 0.25,
        replan_threshold: float = 0.55,
        replan_patience: int = 3,
        traffic_elastic: Optional[bool] = None,  # None = on when elastic
        traffic_interval: float = 0.5,
        traffic_grow_util: float = 0.85,
        traffic_shrink_util: float = 0.30,
        traffic_patience: int = 2,
        traffic_cooldown: float = 2.0,
        resize_latency_budget: Optional[float] = None,  # p99 guard; None off
        stage_widths: Optional[Sequence[int]] = None,  # pin a PhysicalPlan's widths
        columnar: bool = False,  # seal numeric units as TAG_COLBLOCK blocks
        device_batch: int = 256,  # rows per device kernel dispatch
        device_workers: int = 1,  # pinned width of every device stage
        device_inflight: int = 2,  # async dispatches in flight (2 = dbl-buf)
        device_backend: str = "auto",  # auto | jax | numpy
        checkpoint_interval: int = 1024,  # serials per epoch; 0 disables
        stall_timeout: Optional[float] = None,  # hung-process detector; None off
        spill_timeout: float = 10.0,  # spill-body relay deadline (seconds)
        fault_plan: Optional[FaultPlan] = None,  # chaos-harness schedule
        on_error="raise",  # str | {op_name: str} of raise/skip/dead_letter
        **_ignored,  # thread-backend knobs (heuristic, ...) have no meaning here
    ):
        self.auto_workers = num_workers == "auto"
        if self.auto_workers:
            num_workers = 1  # provisional; the allocator sets real widths
        if not isinstance(num_workers, int) or num_workers < 1:
            raise ValueError(
                "num_workers must be a positive int or 'auto', got "
                f"{num_workers!r}"
            )
        if "fork" not in multiprocessing.get_all_start_methods():
            raise RuntimeError(
                "process backend requires the fork start method (POSIX); "
                "use backend='thread' on this platform"
            )
        self._ctx = multiprocessing.get_context("fork")
        self.num_workers = num_workers
        self.marker_interval = marker_interval
        self.collect_outputs = collect_outputs
        self.ring_slots = ring_slots
        self.slot_bytes = slot_bytes
        self.reorder_size = reorder_size
        self.reorder_payload = reorder_payload
        # batch_size (the thread path's knob) doubles as the dispatch-unit
        # size when io_batch is not given, so the two backends share one dial.
        if io_batch is None:
            io_batch = batch_size if batch_size and batch_size > 1 else 32
        self.io_batch = max(1, io_batch)
        self.columnar = bool(columnar)
        if not isinstance(device_batch, int) or device_batch < 1:
            raise ValueError(
                f"device_batch must be a positive int, got {device_batch!r}"
            )
        # a device batch smaller than a dispatch unit would split units
        # across dispatches for no win; clamp to the PV411 floor
        self.device_batch = max(device_batch, self.io_batch)
        if not isinstance(device_workers, int) or device_workers < 1:
            raise ValueError(
                f"device_workers must be a positive int, got {device_workers!r}"
            )
        self.device_workers = device_workers
        if not isinstance(device_inflight, int) or device_inflight < 1:
            raise ValueError(
                f"device_inflight must be a positive int, got "
                f"{device_inflight!r}"
            )
        self.device_inflight = device_inflight
        if device_backend not in ("auto", "jax", "numpy"):
            raise ValueError(
                f"device_backend must be auto|jax|numpy, got {device_backend!r}"
            )
        self.device_backend = device_backend
        self.restart_on_crash = restart_on_crash
        if not isinstance(checkpoint_interval, int) or checkpoint_interval < 0:
            raise ValueError(
                "checkpoint_interval must be an int >= 0 (0 disables), got "
                f"{checkpoint_interval!r}"
            )
        self.checkpoint_interval = checkpoint_interval
        self.stall_timeout = stall_timeout
        self.spill_timeout = float(spill_timeout)
        self.fault_plan = fault_plan
        if fault_plan is not None:
            fault_plan.validate()
        self.on_error = on_error
        # Parent nap ceiling while the stages grind.  On small boxes the
        # supervisor's wake rate competes with the worker groups for cores;
        # raising the cap trades a little drain latency for worker headroom.
        self.parent_idle_cap = float(_ignored.pop("parent_idle_cap", 5e-4))
        self._tail_opts = dict(
            reorder_scheme=reorder_scheme, worklist_scheme=worklist_scheme
        )

        self.cost_priors = dict(cost_priors) if cost_priors else None
        self.worker_budget = worker_budget
        self.calibrate_tuples = max(int(calibrate_tuples), 0)
        self.elastic = self.auto_workers if elastic is None else bool(elastic)
        self.replan_interval = replan_interval
        self.replan_threshold = replan_threshold
        self.replan_patience = replan_patience
        # traffic-reactive elasticity needs the elastic machinery (stage
        # headroom, quiesce/re-fork); an explicit True arms both.
        if traffic_elastic is None:
            self.traffic_elastic = self.elastic
        else:
            self.traffic_elastic = bool(traffic_elastic)
            if self.traffic_elastic and elastic is False:
                raise ValueError(
                    "traffic_elastic=True requires elastic replanning "
                    "(elastic must not be False)"
                )
            if self.traffic_elastic:
                self.elastic = True
        self.traffic_interval = traffic_interval
        self.traffic_grow_util = traffic_grow_util
        self.traffic_shrink_util = traffic_shrink_util
        self.traffic_patience = traffic_patience
        self.traffic_cooldown = traffic_cooldown
        self.resize_latency_budget = resize_latency_budget

        self.node_specs = dict(nodes)
        self.edges = [tuple(e) for e in edges]
        allocate = None
        if self.auto_workers:
            budget = worker_budget if worker_budget else default_budget()
            self.worker_budget = budget

            def allocate(plans):  # noqa: F811 — prior-based initial widths
                self.cost_model = CostModel(
                    plans, self.cost_priors, device_batch=self.device_batch
                )
                return self.cost_model.allocate(budget)

        self.stage_plans, tail_nodes, tail_edges = _plan_stages(
            self.node_specs, self.edges, num_workers, stages, allocate,
            device_workers=self.device_workers,
        )
        if not self.auto_workers:
            self.cost_model = CostModel(
                self.stage_plans, self.cost_priors,
                device_batch=self.device_batch,
            )
        # Executing a pre-made PhysicalPlan: pin the planner's widths (the
        # plan was built from the same priors, so this is reproducibility,
        # not override) and skip the run-time calibration pass — elastic
        # replanning, when enabled, may still adjust the live widths.
        self.pinned_widths = list(stage_widths) if stage_widths else None
        if self.pinned_widths:
            if len(self.pinned_widths) != len(self.stage_plans):
                raise ValueError(
                    f"stage_widths has {len(self.pinned_widths)} entries for "
                    f"{len(self.stage_plans)} planned stages"
                )
            for plan, w in zip(self.stage_plans, self.pinned_widths):
                if plan.kind not in ("stateful", "device"):
                    plan.workers = max(int(w), 1)
        if self.worker_budget is None:
            # elastic replanning with flat widths: the budget it may
            # redistribute is exactly what the flat plan spent
            self.worker_budget = sum(p.workers for p in self.stage_plans)
        self._set_stage_headroom()
        # In-flight serials are doubly bounded: by the reorder window
        # (correctness — workers must be able to publish) and by this backlog
        # throttle (latency — an unbounded backlog pushes queueing delay into
        # every marker while adding nothing once each worker has spare units).
        widest = max(p.workers for p in self.stage_plans)
        self._explicit_inflight = max_inflight is not None
        units = max_inflight if max_inflight else 8 * max(num_workers, widest)
        self.max_inflight = min(reorder_size, max(units * self.io_batch, 1))

        self.tail_node_names = sorted(tail_nodes)  # plan introspection
        unstaged_routing = [
            name for name, spec in tail_nodes.items()
            if isinstance(spec, (Split, Merge))
        ]
        if unstaged_routing:
            warnings.warn(
                UnstagedGraphWarning(sorted(tail_nodes)), stacklevel=3
            )
        self._tail: Optional[GraphPipeline] = None
        if tail_nodes:
            self._tail = GraphPipeline(
                tail_nodes,
                tail_edges,
                marker_interval=0,  # markers are injected by the parent
                collect_outputs=collect_outputs,
                num_workers=1,
                **self._tail_opts,
            )

        # result surface (used directly when the tail is empty)
        # lock-free: only the single-threaded parent supervisor touches these
        self.outputs: list = []
        self.markers: list[_Marker] = []
        self._egress_count = 0
        self._first_push_ts: Optional[float] = None
        self._last_egress_ts: Optional[float] = None

        # live state
        self._exchanges: List[shm.ExchangeRing] = []
        self._procs: List[Optional[multiprocessing.Process]] = []
        self._pinfo: List[tuple] = []  # ("worker", stage, widx) | ("router", stage)
        self._conns: List[Any] = []
        self._router_conns: dict[int, Any] = {}  # stage idx -> parent-side duplex
        self._disp: Optional[_Dispatcher] = None
        self._spills: dict[int, tuple[int, bytes]] = {}
        self._eof_seen = False
        self._worker_busy = 0.0
        self._worker_processed = 0
        self.restarts = 0  # crash-recovery instrumentation

        # fault-tolerance state (armed per start_stream in _setup)
        self.dead_letters: List[DeadLetter] = []
        self.recoveries = 0  # completed recovery events (group or router)
        self.recovery_time_s = 0.0  # supervisor time inside group restores
        self._ckpt: Optional[CheckpointStore] = None
        self._log_floor: dict[int, int] = {}  # stage -> lowest replayable serial
        self._beats: dict[int, tuple] = {}  # proc idx -> (pid, beat, ts)
        self._halted: set[int] = set()  # stages whose feeder acked a halt
        self._spill_cache: dict[int, dict[int, tuple]] = {}  # stage -> serial -> msg
        self._dead_seen: set[tuple] = set()  # (stage, serial, op) dedup
        self._fault_queue: list = []  # [FaultSpec, fired] pairs
        self._prev_sig: list = []  # (signum, prior handler) to restore

        # elastic replanning state
        self._monitor: Optional[OccupancyMonitor] = None
        self._traffic: Optional[TrafficMonitor] = None
        self._resizes: collections.deque = collections.deque()
        self._active_replan: Optional[dict] = None
        self._handoff: dict[tuple[int, int], bytes] = {}  # (stage, widx) -> blob
        self.replans = 0  # completed elastic replan events (instrumentation)
        # resize-latency accounting (the p99-guard's evidence trail)
        self.resize_stalls: List[float] = []  # begin->finish wall s, completed
        self.resize_aborts = 0  # guard-triggered aborts (stall > budget)
        self.resize_reverts = 0  # over-budget traffic resizes undone
        self.grows = 0  # completed resizes that widened a stage
        self.shrinks = 0  # completed resizes that narrowed a stage

    @classmethod
    def from_chain(cls, specs: Sequence[OpSpec], **kw) -> "ProcessRuntime":
        """Build a runtime for a linear operator chain (names auto-derived)."""
        nodes, edges = _chain_nodes(list(specs))
        return cls(nodes, edges, **kw)

    def _set_stage_headroom(self) -> None:
        """Fix each stage's ring headroom (``StagePlan.max_workers``): the
        widest group an elastic resize may re-fork.  Bounded by the worker
        budget minus one worker for every other stage, and by the stage's
        intrinsic cap (stateful: 1, keyed: its partition count)."""
        caps = self.cost_model.stage_caps()
        spare = max(self.worker_budget - (len(self.stage_plans) - 1), 1)
        for plan, cap in zip(self.stage_plans, caps):
            if not self.elastic or plan.kind in ("stateful", "device"):
                plan.max_workers = plan.workers
            else:
                plan.max_workers = max(min(cap, spare), plan.workers)

    # --------------------------------------------------------------- topology
    @property
    def num_stages(self) -> int:
        """How many stages the planner cut (1 = ingress-only plan)."""
        return len(self.stage_plans)

    def stage_widths(self) -> list[int]:
        """Current per-stage worker-group widths (allocation introspection)."""
        return [p.workers for p in self.stage_plans]

    def worker_groups(self) -> list[list[multiprocessing.Process]]:
        """Live worker processes per stage (crash tests / introspection)."""
        groups: list[list] = [[] for _ in self.stage_plans]
        for p, info in zip(self._procs, self._pinfo):
            if p is not None and info[0] == "worker":
                groups[info[1]].append(p)
        return groups

    # -------------------------------------------------------------- lifecycle
    def _ckpt_enabled(self, stage: int) -> bool:
        """Whether this stage recovers by epoch checkpoint + replay
        (keyed/stateful stages for their state, device stages because their
        batches span ring units; stateless re-forks per worker)."""
        return (
            self.checkpoint_interval > 0
            and self.restart_on_crash
            and self.stage_plans[stage].kind in ("keyed", "stateful", "device")
        )

    def _stage_ckpt_interval(self, stage: int) -> int:
        # barriers stamp at dispatch-unit boundaries, so an interval below
        # io_batch would degenerate to one epoch per unit; clamp (PV407)
        if not self._ckpt_enabled(stage):
            return 0
        return max(self.checkpoint_interval, self.io_batch)

    def _fork_worker(self, stage: int, widx: int, slot: Optional[int] = None,
                     preload=None):
        x = self._exchanges[stage]
        plan = self.stage_plans[stage]
        if plan.kind == "device" and plan.ops:
            from ..columnar.device import jax_fork_hazard, resolve_backend

            backend = resolve_backend(
                plan.ops[0].device_backend or self.device_backend
            )
            if backend == "jax" and jax_fork_hazard():
                # Fail fast: a forked child of a jax-initialized parent
                # deadlocks on its first computation (inherited XLA
                # threadpool locks), which would otherwise surface as an
                # opaque drain timeout a minute from now.
                raise RuntimeError(
                    "cannot fork a jax device worker: this process has "
                    "already initialized a jax backend (e.g. ran a jax "
                    "computation or created a PRNGKey), and forked "
                    "children of an initialized parent deadlock inside "
                    "XLA. Run the engine before any in-process jax work, "
                    "or pin device_backend='numpy' for this run. "
                    "See docs/columnar.md (fork safety)."
                )
        parent_conn, child_conn = self._ctx.Pipe(duplex=False)
        child_faults = (
            self.fault_plan.child_specs(stage, widx)
            if self.fault_plan is not None else None
        )
        dev_cfg = (
            (self.device_batch, self.device_inflight, self.device_backend)
            if plan.kind == "device" else None
        )
        proc = self._ctx.Process(
            target=_worker_main,
            args=(widx, x.rings[widx], x.reorder, child_conn, plan.ops,
                  preload, stage, plan.kind != "stateless",
                  resolve_policies(self.on_error, plan.ops), child_faults,
                  self.columnar, dev_cfg),
            daemon=True,
        )
        proc.start()
        child_conn.close()
        if slot is None:
            self._procs.append(proc)
            self._pinfo.append(("worker", stage, widx))
            self._conns.append(parent_conn)
        else:  # crash replacement: same rings, fresh pipe
            self._procs[slot] = proc
            self._conns[slot] = parent_conn

    def _fork_router(self, stage: int, slot: Optional[int] = None) -> None:
        parent_conn, child_conn = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(
            target=_router_main,
            args=(stage, self._exchanges[stage - 1].reorder,
                  self._exchanges[stage], child_conn,
                  self.stage_plans[stage], self.io_batch, self.max_inflight,
                  self._stage_ckpt_interval(stage), self.spill_timeout,
                  self.columnar),
            daemon=True,
        )
        proc.start()
        child_conn.close()
        if slot is None:
            self._procs.append(proc)
            self._pinfo.append(("router", stage))
            self._conns.append(parent_conn)
        else:  # crash replacement: resumes from the reorder commit record
            self._procs[slot] = proc
            self._conns[slot] = parent_conn
        self._router_conns[stage] = parent_conn

    def _setup(self) -> None:
        run_id = f"repro_{os.getpid()}_{uuid.uuid4().hex[:8]}"
        self._exchanges = [
            shm.ExchangeRing(
                f"{run_id}_s{plan.index}",
                max(plan.max_workers, plan.workers),  # elastic ring headroom
                ring_slots=self.ring_slots,
                slot_bytes=self.slot_bytes,
                reorder_size=self.reorder_size,
                reorder_payload=self.reorder_payload,
            )
            for plan in self.stage_plans
        ]
        for x, plan in zip(self._exchanges, self.stage_plans):
            x.set_active_width(plan.workers)
        # stage-0 workers first (supervision order mirrors the dataflow)
        for stage, plan in enumerate(self.stage_plans):
            for w in range(plan.workers):
                self._fork_worker(stage, w)
        for stage in range(1, len(self.stage_plans)):
            self._fork_router(stage)
        self._disp = _Dispatcher(
            self._exchanges[0], self.stage_plans[0], self.io_batch,
            self.max_inflight, self._stage_ckpt_interval(0),
            columnar=self.columnar,
        )
        self._ckpt = CheckpointStore()
        self._log_floor = {s: 1 for s in range(len(self.stage_plans))}
        self._beats = {}
        self._halted = set()
        self._spill_cache = {}
        self._dead_seen = set()
        self.dead_letters = []
        self._fault_queue = (
            [[spec, False] for spec in self.fault_plan.supervisor_specs()]
            if self.fault_plan is not None else []
        )
        self._eof_seen = False
        self._monitor = None
        self._traffic = None
        if self.elastic and any(p.resizable for p in self.stage_plans):
            self._monitor = OccupancyMonitor(
                self.cost_model,
                self.worker_budget,
                interval=self.replan_interval,
                occupancy_threshold=self.replan_threshold,
                patience=self.replan_patience,
            )
            if self.traffic_elastic:
                # inert until a serving tier feeds it via observe_traffic()
                self._traffic = TrafficMonitor(
                    self.cost_model,
                    self.worker_budget,
                    interval=self.traffic_interval,
                    grow_util=self.traffic_grow_util,
                    shrink_util=self.traffic_shrink_util,
                    patience=self.traffic_patience,
                    cooldown=self.traffic_cooldown,
                )
        self._resizes.clear()
        self._active_replan = None
        self._handoff = {}

    def stop(self) -> None:
        """Tear everything down; idempotent, always unlinks shared memory."""
        for signum, prev in self._prev_sig:  # restore caller's handlers first
            try:
                signal.signal(signum, prev)
            except (ValueError, OSError):
                pass
        self._prev_sig = []
        for x in self._exchanges:
            try:
                x.request_stop()  # unstick FULL-spinning publishers/routers
                x.close_ingress()
            except Exception:
                pass
        for p in self._procs:
            if p is not None:
                p.join(timeout=5.0)
                if p.is_alive():
                    p.terminate()
                    p.join(timeout=1.0)
                if p.is_alive():  # SIGSTOPped children ignore SIGTERM
                    p.kill()
                    p.join(timeout=1.0)
        self._drain_conns(final=True)
        for conn in self._conns:
            try:
                conn.close()
            except Exception:
                pass
        for x in self._exchanges:
            x.close()
            x.unlink()
        self._exchanges = []
        self._procs, self._pinfo, self._conns = [], [], []
        self._router_conns = {}
        self._disp = None
        self._monitor = None
        self._traffic = None
        self._active_replan = None
        self._resizes.clear()
        self._handoff = {}
        self._ckpt = None
        self._beats = {}
        self._halted = set()
        self._spill_cache = {}

    # ---------------------------------------------------------------- plumbing
    def _drain_conns(self, final: bool = False) -> None:
        """Sweep child pipes for spills / stats / marks / errors.

        ``final`` (cleanup context) swallows worker errors: by then every
        input has drained, so a late error cannot have corrupted the output.
        """
        for idx, conn in enumerate(self._conns):
            if conn is None:
                continue
            try:
                while conn.poll():
                    self._on_message(idx, conn.recv(), ignore_errors=final)
            except (EOFError, OSError):
                continue

    def _on_message(self, idx: int, msg, ignore_errors: bool = False) -> None:
        kind = msg[0]
        if kind == "spill":
            # Route the body to whoever drains that stage's reorder ring:
            # the next stage's router, or the parent for the final stage.
            # Router-bound bodies are also cached until the drain commits
            # past them — a restarted router re-reads the spill tag and the
            # body in the dead router's memory is gone.
            stage = self._pinfo[idx][1]
            target = self._router_conns.get(stage + 1)
            if target is None:
                self._spills[msg[1]] = (msg[2], msg[3])
            else:
                self._spill_cache.setdefault(stage + 1, {})[msg[1]] = msg
                try:
                    target.send(msg)
                except (BrokenPipeError, OSError):
                    pass  # router died: the cache replays after its restart
        elif kind == "ckpt":  # worker's epoch-barrier state snapshot
            info = self._pinfo[idx]
            stage = info[1]
            done = self._ckpt.ack(
                stage, msg[1], msg[2], msg[3], msg[4],
                self.stage_plans[stage].workers,
            )
            if done is not None:  # epoch complete: truncate the replay log
                self._log_floor[stage] = max(
                    self._log_floor.get(stage, 1), done.boundary
                )
                if stage == 0:
                    self._disp.truncate_log(done.boundary)
                else:
                    conn = self._router_conns.get(stage)
                    if conn is not None:
                        try:
                            conn.send(("ckpt_done", done.epoch, done.boundary))
                        except (BrokenPipeError, OSError):
                            pass  # dead router keeps a longer log: harmless
        elif kind == "dead":  # quarantined tuples (on_error="dead_letter")
            info = self._pinfo[idx]
            for serial, op, value, error in msg[2]:
                key = (info[1], serial, op)
                if key in self._dead_seen:
                    continue  # duplicate unit re-processed after a restart
                self._dead_seen.add(key)
                self.dead_letters.append(
                    DeadLetter(info[1], msg[1], serial, op, value, error)
                )
        elif kind == "halted":  # router acked a group-restore halt
            self._halted.add(msg[1])
        elif kind == "stats":
            self._worker_busy += msg[2]
            self._worker_processed += msg[3]
        elif kind == "marks":  # probes dropped mid-pipeline (filtered tuples)
            for m in msg[1]:
                self._record_dropped(m)
        elif kind == "state":  # elastic handoff: worker-local state snapshot
            info = self._pinfo[idx]
            if info[0] == "worker":
                self._handoff[(info[1], info[2])] = msg[2]
        elif kind == "paused":  # router acked an elastic pause
            rep = self._active_replan
            if (
                rep is not None
                and rep["phase"] == "pausing"
                and rep["stage"] == msg[1]
            ):
                rep["boundary"] = msg[2]
                rep["phase"] = "quiesce"
        elif kind == "error" and not ignore_errors:
            raise RuntimeError(f"worker {msg[1]} failed: {msg[2]}")

    def _record_dropped(self, m: _Marker) -> None:
        if not m.exit:
            m.exit = time.perf_counter()
        if self._tail is not None:
            self._tail._record_marker(m)
        else:
            self.markers.append(m)

    def _take_spill(self, serial: int) -> tuple[int, bytes]:
        return _await_spill(
            self._spills, serial, self._drain_conns, self.spill_timeout,
            lambda: (
                "final-stage drain, ingress backlog "
                f"{self._exchanges[-1].backlog_slots()} slots"
            ),
        )

    def _evict_spills(self) -> None:
        """Drop cached spill bodies once their stage's drain has committed
        past them (a restarted router can never re-request those serials)."""
        for tstage, cache in self._spill_cache.items():
            nxt = self._exchanges[tstage - 1].reorder.shared_next()
            for s in [s for s in cache if s < nxt]:
                del cache[s]

    # --------------------------------------------------------------- monitor
    def _check_procs(self) -> None:
        for idx, p in enumerate(self._procs):
            if p is None or p.is_alive():
                continue
            # Salvage every message first — a user-fn error beats a crash
            # diagnosis, and spills/stats must not be lost.
            try:
                while self._conns[idx].poll():
                    self._on_message(idx, self._conns[idx].recv())
            except (EOFError, OSError):
                pass
            if p.exitcode == 0:  # normal exit (stage drained)
                self._procs[idx] = None
                continue
            self._on_crash(idx, p)

    def _on_crash(self, idx: int, proc) -> None:
        info = self._pinfo[idx]
        if info[0] == "router":
            if not self.restart_on_crash:
                raise RuntimeError(
                    f"exchange router for stage {info[1]} died "
                    f"(exitcode {proc.exitcode})"
                )
            self._recover_router(idx, info[1])
            return
        _, stage, widx = info
        plan = self.stage_plans[stage]
        if not plan.recoverable:
            # keyed/stateful: recover from the epoch checkpoint unless the
            # operator explicitly opted out (checkpoint_interval=0 /
            # restart_on_crash=False), which keeps the historical raise
            if self._ckpt_enabled(stage):
                self._restore_group(stage)
                return
            raise RuntimeError(
                f"worker process died in {plan.describe()}; worker-local "
                "state is lost and cannot be replayed (only stateless stages "
                "are crash-tolerant)"
            )
        if not self.restart_on_crash:
            raise RuntimeError(
                f"worker {widx} of stage {stage} died (restart_on_crash=False)"
            )
        try:
            self._conns[idx].close()
        except Exception:
            pass
        # Re-fork onto the SAME rings: the dead worker committed its ring
        # head only after publishing, so at most one unit is re-processed
        # and duplicate publishes are idempotent (deterministic segments).
        self._fork_worker(stage, widx, slot=idx)
        self.restarts += 1

    def _recover_router(self, idx: int, stage: int) -> None:
        """Re-fork a dead exchange router onto the same exchanges.  The
        replacement resumes from the upstream reorder's commit record (read
        position + downstream serial); the window between the record and the
        dead router's actual progress is re-dispatched, and downstream
        absorbs the duplicates (worker ``last_seen`` trim on keyed/stateful
        stages, per-serial publish idempotence on stateless ones)."""
        rep = self._active_replan
        if rep is not None and rep["stage"] == stage:
            if rep["phase"] == "collect":
                raise RuntimeError(
                    f"exchange router for stage {stage} died while its "
                    "elastic replan was collecting worker state; the "
                    "quiesce boundary is unrecoverable"
                )
            self._abort_replan()  # pre-quiesce: nothing irreversible yet
        try:
            self._conns[idx].close()
        except Exception:
            pass
        rec = self._exchanges[stage - 1].reorder.commit_record()
        resume = rec[1] if rec is not None else 1
        if self._ckpt_enabled(stage):
            # the dead router's replay log died with it: the new log only
            # covers serials >= resume, so checkpoints older than that can
            # no longer restore this stage — and a fresh barrier is forced
            # below to close the exposure window fast
            self._log_floor[stage] = max(self._log_floor.get(stage, 1), resume)
        self._fork_router(stage, slot=idx)
        conn = self._router_conns[stage]
        for _serial, msg in sorted(self._spill_cache.get(stage, {}).items()):
            try:
                conn.send(msg)  # bodies the dead router held in memory
            except (BrokenPipeError, OSError):
                break
        if self._ckpt_enabled(stage):
            try:
                conn.send(("ckpt_now",))
            except (BrokenPipeError, OSError):
                pass
        self.restarts += 1
        self.recoveries += 1

    def _restore_group(self, stage: int) -> None:
        """Keyed/stateful crash recovery: halt the stage's feeder, kill the
        remaining group members (their state is mid-epoch and must not
        advance), reset the ingress rings, re-fork the group preloaded with
        the latest complete epoch snapshot, and re-pump the feeder's replay
        log from the epoch boundary.  Runs synchronously in the supervisor —
        the stream stalls for the duration (measured in
        ``recovery_time_s``)."""
        t0 = time.perf_counter()
        plan = self.stage_plans[stage]
        x = self._exchanges[stage]
        rep = self._active_replan
        if rep is not None and rep["stage"] == stage:
            if rep["phase"] == "collect":
                raise RuntimeError(
                    f"worker group of stage {stage} lost a member while "
                    "handing off elastic-resize state; the handoff snapshot "
                    "is incomplete and cannot be restored"
                )
            self._abort_replan()  # pre-quiesce: nothing irreversible yet
        ckpt = self._ckpt.latest(stage)
        boundary = ckpt.boundary if ckpt is not None else 1
        floor = self._log_floor.get(stage, 1)
        if boundary < floor:
            raise RuntimeError(
                f"cannot restore stage {stage}: the replay log covers serials"
                f" >= {floor} but the latest checkpoint boundary is "
                f"{boundary} (its feeder restarted before a fresh epoch "
                "completed)"
            )
        # -- halt the feeder: nothing may enter the rings while they reset
        if stage > 0:
            ridx = self._router_slot(stage)
            conn = self._router_conns.get(stage)
            alive = (
                ridx is not None and self._procs[ridx] is not None
                and self._procs[ridx].is_alive()
            )
            if not alive or conn is None:
                raise RuntimeError(
                    f"worker died in {plan.describe()} but its feeder router "
                    "is gone too; simultaneous feeder+worker failures are "
                    "unrecoverable (the replay window died with the router)"
                )
            self._halted.discard(stage)
            conn.send(("halt",))
            deadline = time.perf_counter() + 10.0
            while stage not in self._halted:
                self._drain_conns()
                if not self._procs[ridx].is_alive():
                    raise RuntimeError(
                        f"stage {stage} feeder router died during the group "
                        "restore; simultaneous failures are unrecoverable"
                    )
                if time.perf_counter() > deadline:
                    raise RuntimeError(
                        f"stage {stage} feeder failed to halt for group "
                        "restore within 10s"
                    )
                time.sleep(1e-3)
        # -- kill and reap the rest of the group (later-wins slot map: a
        # resized stage leaves dead pinfo entries behind at the old width)
        slots: dict[int, int] = {}
        for i, info in enumerate(self._pinfo):
            if info[0] != "worker" or info[1] != stage:
                continue
            slots[info[2]] = i
            p = self._procs[i]
            if p is not None:
                if p.is_alive():
                    try:
                        os.kill(p.pid, signal.SIGKILL)
                    except (ProcessLookupError, OSError):
                        pass
                p.join(timeout=5.0)
                self._procs[i] = None
            try:
                if self._conns[i] is not None:
                    self._conns[i].close()
            except Exception:
                pass
        # -- reset the rings and re-fork at the same width with the snapshot
        x.reopen_ingress()  # EOF may already have closed them
        x.reset_ingress()
        self._ckpt.clear_pending(stage)
        blobs = ckpt.blobs if ckpt is not None else {}
        for widx in range(plan.workers):
            blob = blobs.get(widx)
            preload = pickle.loads(blob) if blob is not None else None
            self._fork_worker(stage, widx, slot=slots.get(widx),
                              preload=preload)
        # -- re-pump everything since the boundary
        if stage == 0:
            self._disp.requeue_log()
        else:
            self._router_conns[stage].send(("restore",))
            self._halted.discard(stage)
        self.restarts += plan.workers
        self.recoveries += 1
        self.recovery_time_s += time.perf_counter() - t0

    # ------------------------------------------------------ elastic replanning
    # Protocol (see docs/architecture.md): pause the stage's feeder → let the
    # stage drain to a serial-number boundary (every dispatched serial
    # processed, published, AND consumed downstream) → ask the quiesced group
    # to hand its worker-local state back over the pipes → re-fork the group
    # at the new width with the state re-sharded by the new key routing →
    # resume the feeder.  Order and loss-freedom are inherited from the crash
    # protocol: nothing is in flight across the boundary, and the re-forked
    # workers consume the same rings with peek → publish → advance.
    def observe_traffic(self, signals: Dict) -> None:
        """Feed a serving-tier load snapshot (``SessionMux.load_signals``
        dict) to the traffic-reactive elasticity policy.

        No-op when the policy is off (``traffic_elastic`` resolved False)
        or the runtime has no resizable stage.  Must be called from the
        supervisor-owning thread (the same one that pushes/services)."""
        if self._traffic is not None:
            self._traffic.ingest(signals)

    def _drive_elastic(self, now: float, src_done: bool) -> None:
        if self._active_replan is not None:
            self._step_replan(now, src_done)
            return
        if self._resizes:
            if src_done:  # drain phase: a resize can no longer pay for itself
                self._resizes.clear()
                return
            stage, new_w, origin = self._resizes.popleft()
            self._begin_replan(stage, new_w, now, origin=origin)
            return
        if src_done:
            return
        mon_due = self._monitor is not None and self._monitor.due(now)
        tm_due = self._traffic is not None and self._traffic.due(now)
        if not (mon_due or tm_due):
            return
        drained = [x.progress()[0] for x in self._exchanges]
        backlog = [x.backlog_slots() for x in self._exchanges]
        widths = [p.workers for p in self.stage_plans]
        resizable = [p.resizable for p in self.stage_plans]
        props: List[Tuple[int, int, str]] = []
        if mon_due:
            for stage, w in self._monitor.sample(
                now, drained, backlog, widths, resizable
            ) or ():
                props.append((stage, w, "occupancy"))
        if tm_due and not props:  # skew proposals take the turn; traffic next
            for stage, w in self._traffic.sample(
                now, drained, backlog, widths, resizable
            ) or ():
                props.append((stage, w, "traffic"))
        for stage, w, origin in props:
            plan = self.stage_plans[stage]
            w = min(max(w, 1), plan.max_workers)
            if w != plan.workers:
                self._resizes.append((stage, w, origin))

    def _begin_replan(
        self, stage: int, new_w: int, now: float, origin: str = "occupancy"
    ) -> None:
        rep = {
            "stage": stage, "new_w": new_w, "old_w":
            self.stage_plans[stage].workers, "origin": origin, "t0": now,
            "deadline": now + 10.0, "boundary": None,
        }
        if stage == 0:  # the parent itself is the feeder
            self._disp.paused = True
            self._disp.flush()
            rep["phase"] = "flush"
        else:
            conn = self._router_conns.get(stage)
            if conn is None:
                return
            try:
                conn.send(("pause",))
            except (BrokenPipeError, OSError):
                return  # router already gone (EOF cascade): replan is moot
            rep["phase"] = "pausing"
        self._active_replan = rep

    def _step_replan(self, now: float, src_done: bool) -> None:
        rep = self._active_replan
        stage = rep["stage"]
        plan = self.stage_plans[stage]
        x = self._exchanges[stage]
        phase = rep["phase"]
        budget = self.resize_latency_budget
        if (
            phase in ("flush", "pausing", "quiesce")
            and budget is not None
            and now - rep["t0"] > budget
        ):
            # p99 guard: the quiesce stall already exceeds the latency
            # budget — abort pre-quiesce (nothing irreversible yet) and
            # back the policy off so it is not immediately retried
            self.resize_aborts += 1
            if self._traffic is not None:
                self._traffic.resize_result(
                    now, stall_s=now - rep["t0"], aborted=True
                )
            self._abort_replan()
            return
        if phase in ("flush", "pausing", "quiesce") and (
            src_done or now > rep["deadline"]
        ):
            self._abort_replan()  # nothing irreversible has happened yet
            return
        if phase == "flush":  # stage 0: push the sealed partials into rings
            self._disp.pump()
            if not self._disp.pending():
                rep["boundary"] = self._disp.next_serial
                rep["phase"] = "quiesce"
        elif phase == "pausing":
            # waiting for the router's ("paused", stage, serial) ack, which
            # arrives via _on_message; a router that exited meanwhile (EOF
            # cascade raced the pause) makes the replan moot
            ridx = self._router_slot(stage)
            if ridx is None or self._procs[ridx] is None:
                self._abort_replan()
        elif phase == "quiesce":
            if (
                x.backlog_slots() == 0
                and x.reorder.shared_next() >= rep["boundary"]
            ):
                # serial boundary reached: every dispatched tuple processed,
                # published, and drained downstream — collect the group
                for key in [k for k in self._handoff if k[0] == stage]:
                    del self._handoff[key]
                x.request_handoff()  # before close: exiting workers see it
                x.close_ingress()
                rep["expected"] = [
                    i for i, info in enumerate(self._pinfo)
                    if info[0] == "worker" and info[1] == stage
                    and self._procs[i] is not None
                ]
                rep["phase"] = "collect"
        elif phase == "collect":
            if now > rep["deadline"]:
                raise RuntimeError(
                    f"elastic replan of stage {stage} stuck collecting "
                    "worker state (quiesced workers failed to exit)"
                )
            if all(self._procs[i] is None for i in rep["expected"]):
                self._finish_replan(rep, plan, x)

    def _finish_replan(self, rep: dict, plan: StagePlan, x) -> None:
        stage, new_w = rep["stage"], rep["new_w"]
        preloads = self._build_preloads(plan, new_w)
        x.reopen_ingress()
        for j in range(new_w):
            self._fork_worker(stage, j, preload=preloads[j])
        plan.workers = new_w
        x.set_active_width(new_w)
        ckpt_boundary = None
        if self._ckpt_enabled(stage):
            # the quiesced handoff IS a complete snapshot at the new width:
            # bank it as a synthetic checkpoint so a later crash restores at
            # the resized sharding, and truncate the replay log below it
            boundary = rep["boundary"]
            self._ckpt.clear_pending(stage)
            self._ckpt.force(stage, boundary, {
                j: pickle.dumps(preloads[j], _PICKLE) for j in range(new_w)
            })
            self._log_floor[stage] = max(
                self._log_floor.get(stage, 1), boundary
            )
            ckpt_boundary = boundary
        if stage == 0:
            if ckpt_boundary is not None:
                self._disp.truncate_log(ckpt_boundary)
            self._disp.set_workers(new_w)
            self._disp.paused = False
        else:
            conn = self._router_conns.get(stage)
            if conn is not None:
                if ckpt_boundary is not None:
                    conn.send(("resume", new_w, ckpt_boundary))
                else:
                    conn.send(("resume", new_w))
        self.replans += 1
        if new_w > rep["old_w"]:
            self.grows += 1
        elif new_w < rep["old_w"]:
            self.shrinks += 1
        now = time.perf_counter()
        stall = now - rep["t0"]
        self.resize_stalls.append(stall)
        budget = self.resize_latency_budget
        over = budget is not None and stall > budget
        if self._traffic is not None:
            self._traffic.resize_result(now, stall_s=stall, over_budget=over)
        if over and rep["origin"] == "traffic":
            # p99 guard, undo path: the resize completed but its stall blew
            # the budget — return to the prior width (the revert itself is
            # never re-reverted) and leave the policy in extended cooldown
            self.resize_reverts += 1
            self._resizes.append((stage, rep["old_w"], "revert"))
        self._active_replan = None

    def _abort_replan(self) -> None:
        rep, self._active_replan = self._active_replan, None
        self._resizes.clear()  # stale siblings of an aborted width vector
        stage = rep["stage"]
        if stage == 0:
            self._disp.paused = False
        else:
            conn = self._router_conns.get(stage)
            if conn is not None:
                try:  # resume at the unchanged width
                    conn.send(("resume", self.stage_plans[stage].workers))
                except (BrokenPipeError, OSError):
                    pass

    def _router_slot(self, stage: int) -> Optional[int]:
        for i, info in enumerate(self._pinfo):
            if info[0] == "router" and info[1] == stage:
                return i
        return None

    def _build_preloads(self, plan: StagePlan, new_w: int) -> list:
        """Merge the quiesced group's handed-off state and re-shard it by the
        new width's key routing (worker j owns keys with
        ``partitioner(key) % new_w == j`` — exactly how the dispatcher will
        route them)."""
        merged = _init_states(plan.ops)
        for (stage, _widx), blob in sorted(self._handoff.items()):
            if stage != plan.index:
                continue
            st = pickle.loads(blob)
            for oi, op in enumerate(plan.ops):
                if op.kind == PARTITIONED:
                    merged[oi].update(st[oi])  # key sets are disjoint
        preloads = []
        for j in range(new_w):
            states_j = []
            for oi, op in enumerate(plan.ops):
                if op.kind == PARTITIONED:
                    part = op.partitioner
                    states_j.append({
                        k: v for k, v in merged[oi].items()
                        if part(k) % new_w == j
                    })
                else:  # stateless placeholder (stateful stages never resize)
                    states_j.append({})
            preloads.append(states_j)
        return preloads

    # ------------------------------------------------------- stall supervision
    def _check_stalls(self, now: float) -> None:
        """Hung-process detector: every worker bumps a heartbeat in its
        ingress ring header (also while spinning on a FULL reorder window)
        and every router one in the upstream reorder header.  A live process
        whose counter is frozen longer than ``stall_timeout`` is presumed
        hung (SIGSTOP, deadlocked fn, ...) and SIGKILLed — which converts it
        into an ordinary crash the next :meth:`_check_procs` pass recovers.
        ``stall_timeout`` must exceed the worst single-unit operator time,
        or a slow-but-healthy worker gets shot mid-unit."""
        for idx, p in enumerate(self._procs):
            if p is None or not p.is_alive():
                self._beats.pop(idx, None)
                continue
            info = self._pinfo[idx]
            if info[0] == "worker":
                hb = self._exchanges[info[1]].rings[info[2]].heartbeat()
            else:  # router: drains the upstream stage's reorder ring
                hb = self._exchanges[info[1] - 1].reorder.drainer_heartbeat()
            prev = self._beats.get(idx)
            if prev is None or prev[0] != p.pid or prev[1] != hb:
                self._beats[idx] = (p.pid, hb, now)
                continue
            if now - prev[2] > self.stall_timeout:
                try:
                    os.kill(p.pid, signal.SIGKILL)  # works on stopped procs
                except (ProcessLookupError, OSError):
                    pass
                self._beats.pop(idx, None)

    def _drive_faults(self, now: float) -> None:
        """Fire due supervisor-side injected faults (see :mod:`.faults`):
        each spec triggers once, when its stage's drained-serial counter
        crosses the spec's serial — stream-position-deterministic, not
        wall-clock-deterministic."""
        for item in self._fault_queue:
            spec, fired = item
            if fired:
                continue
            stage = min(spec.stage, len(self.stage_plans) - 1)
            if self._exchanges[stage].reorder.shared_next() <= spec.serial:
                continue
            item[1] = True
            target = None
            if spec.kind == ROUTER_KILL:
                ridx = self._router_slot(max(stage, 1))
                if ridx is not None:
                    target = self._procs[ridx]
            else:
                for i, info in enumerate(self._pinfo):
                    if (
                        info[0] == "worker" and info[1] == stage
                        and info[2] == spec.worker
                        and self._procs[i] is not None
                    ):
                        target = self._procs[i]
            if target is None or not target.is_alive():
                continue  # already gone: the fault is moot
            sig = signal.SIGSTOP if spec.kind == HANG else signal.SIGKILL
            try:
                os.kill(target.pid, sig)
            except (ProcessLookupError, OSError):
                pass

    # ------------------------------------------------------------------ drive
    # The parent-side drive surface is split into a push-driven *stream
    # protocol* — start_stream() → stream_push()* → end_stream() →
    # finish_stream() — with run() as the finite-iterable driver on top.
    # Everything here executes in the caller's thread (the parent is a thin
    # single-threaded supervisor), so the streaming :class:`~.api.Session`
    # can interleave pushes with ordered result reads without extra locking:
    # _service_once() is the one crank that moves dispatch, final-ring
    # drain, the serial tail, supervision, and elastic replanning forward.

    def start_stream(self) -> None:
        """Fork the stage worker groups and arm the push-driven protocol.

        Unlike :meth:`run`, no source calibration pass happens here (there
        is no source yet): ``workers="auto"`` widths come from declared or
        explicit ``cost_priors`` — elastic replanning, when enabled, refines
        them live from observed occupancy."""
        self._setup()
        # Graceful Ctrl-C / SIGTERM: convert to SystemExit so the callers'
        # ``finally: stop()`` reaps children and unlinks every shm segment.
        # Only legal (and only installed) on the main thread; prior handlers
        # are restored in stop().
        # analysis: ignore[FS301]: read-only main-thread identity query; no primitive is created, nothing crosses the fork
        if threading.current_thread() is threading.main_thread():
            for signum in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev_sig.append(
                        (signum, signal.signal(signum, _sig_raise))
                    )
                except (ValueError, OSError):
                    pass
        self._stream_t0 = time.perf_counter()
        self._n_in = 0
        self._src_done = False
        self._eof_published = False
        self._monitor_at = self._stream_t0
        self._stall = 0
        self._idle = 2e-5

    def _stream_add(self, value: Any) -> None:
        """Seal one tuple into the stage-0 dispatcher (marker accounting)."""
        if self._first_push_ts is None:
            self._first_push_ts = time.perf_counter()
        self._n_in += 1
        marker = None
        if self.marker_interval and self._n_in % self.marker_interval == 0:
            marker = _Marker(time.perf_counter())
        self._disp.add(value, marker)

    def stream_push(self, value: Any) -> None:
        """Push one tuple into the live stream (blocking backpressure).

        When the dispatcher's intake gate is closed (in-flight window full or
        out-queues backed up), services the pipeline until space frees — so a
        fast producer is throttled to the pipeline's pace instead of growing
        an unbounded parent-side queue.  Worker/router failures surface here
        (and in :meth:`finish_stream`) as ``RuntimeError``."""
        if self._src_done:
            raise RuntimeError("stream input already closed (end_stream)")
        spin = _IDLE_MIN
        while not self._disp.ready():
            if self._service_once():
                spin = _IDLE_MIN
            else:
                time.sleep(spin)
                spin = min(spin * 2, self.parent_idle_cap)
        self._stream_add(value)

    def stream_try_push(self, value: Any) -> bool:
        """Non-blocking :meth:`stream_push`: when the intake gate is closed,
        run one supervisor crank (so a rejected push still moves the
        pipeline) and report ``False`` instead of spinning.  The streaming
        multiplexer uses this to keep scheduling *other* sessions while the
        in-flight window is full."""
        if self._src_done:
            raise RuntimeError("stream input already closed (end_stream)")
        if not self._disp.ready():
            self._service_once()
            if not self._disp.ready():
                return False
        self._stream_add(value)
        return True

    def end_stream(self) -> None:
        """Close the stream's input side: flush partial dispatch units and
        let the in-band EOF cascade begin once the queues drain."""
        if not self._src_done:
            self._src_done = True
            self._disp.flush()

    def _service_once(self) -> bool:
        """One supervisor crank: dispatch sealed units, publish EOF when the
        input side is done, drain the final reorder ring (running the serial
        tail), and run periodic supervision (child pipes, crash re-fork,
        elastic replanning).  Returns True if anything moved."""
        progress = False
        disp = self._disp
        if disp.pump():
            progress = True
        if self._src_done and not self._eof_published and not disp.pending():
            if disp.publish_eof():
                self._eof_published = True
                progress = True
        if self._drain_final():
            progress = True
        if progress and self._tail is not None:
            self._pump_tail()
        now = time.perf_counter()
        if now >= self._monitor_at:
            self._monitor_at = now + 0.02
            self._drain_conns()
            if self._fault_queue:
                self._drive_faults(now)
            self._check_procs()
            if self.stall_timeout is not None:
                self._check_stalls(now)
            if self._spill_cache:
                self._evict_spills()
            if self._monitor is not None or self._active_replan:
                self._drive_elastic(now, self._src_done)
        if progress:
            self._stall = 0
        else:
            self._stall += 1
            if self._stall >= 50:
                disp.stall_flush()  # liveness: see _Dispatcher
                self._stall = 0
        return progress

    def stream_drained(self) -> bool:
        """True once the in-band EOF reached the parent and the serial tail
        (if any) is quiescent — i.e. every pushed tuple has egressed."""
        if not self._eof_seen:
            return False
        if self._tail is None:
            return True
        self._pump_tail()
        return self._tail.drained()

    def finish_stream(self, drain_timeout: float = 60.0) -> RunReport:
        """Drain the closed stream to quiescence, tear down, and report."""
        self.end_stream()
        deadline = time.perf_counter() + drain_timeout
        try:
            while not self.stream_drained():
                if self._service_once():
                    self._idle = 2e-5
                    continue
                if time.perf_counter() > deadline:
                    raise TimeoutError("process pipeline failed to drain")
                # back off while the stages grind: a busy-polling parent
                # steals the very cores the worker groups need
                time.sleep(self._idle)
                self._idle = min(self._idle * 2, self.parent_idle_cap)
        finally:
            self.stop()
        wall = time.perf_counter() - self._stream_t0
        return self._report(self._n_in, wall)

    def collected_outputs(self) -> list:
        """The live ordered output list (``collect_outputs=True``): the
        tail pipeline's when a serial tail exists, else the parent's own.
        Parent-side state mutated only by the caller's thread, so streaming
        readers may index into it between :meth:`_service_once` cranks."""
        if self._tail is not None:
            return self._tail.outputs
        return self.outputs

    def run(
        self,
        source: Iterable,
        *,
        drain: bool = True,
        drain_timeout: float = 60.0,
    ) -> RunReport:
        """Drive a finite ``source`` to drain and report — the one-shot
        driver over the stream protocol above (plus the ``workers="auto"``
        calibration pass, which needs the source's first tuples)."""
        src = iter(source)
        if (
            self.auto_workers
            and self.cost_priors is None
            and self.calibrate_tuples > 0
            and self.pinned_widths is None
        ):
            # calibration pass: profile the operator fns on a buffered prefix
            # of the real stream (dry run, state discarded), then re-allocate
            # widths from the measured costs before any process is forked
            sample = list(itertools.islice(src, self.calibrate_tuples))
            if self.cost_model.calibrate(sample):
                widths = self.cost_model.allocate(self.worker_budget)
                for plan, w in zip(self.stage_plans, widths):
                    if plan.kind not in ("stateful", "device"):
                        plan.workers = max(int(w), 1)
                self._set_stage_headroom()
                if not self._explicit_inflight:  # user's latency cap wins
                    widest = max(p.workers for p in self.stage_plans)
                    self.max_inflight = min(
                        self.reorder_size, 8 * widest * self.io_batch
                    )
            if sample:
                src = itertools.chain(sample, src)
        self.start_stream()
        deadline = None
        try:
            while True:
                progress = False
                # -- intake: seal source tuples into stage-0 units -----------
                while not self._src_done and self._disp.ready():
                    try:
                        value = next(src)
                    except StopIteration:
                        self.end_stream()
                        deadline = time.perf_counter() + drain_timeout
                        break
                    self._stream_add(value)
                    progress = True
                if self._service_once():
                    progress = True
                # -- termination ---------------------------------------------
                if self._eof_seen and self.stream_drained():
                    break
                if not drain and self._src_done:
                    break
                if progress:
                    self._idle = 2e-5
                else:
                    if deadline is not None and time.perf_counter() > deadline:
                        raise TimeoutError("process pipeline failed to drain")
                    # back off while the stages grind: a busy-polling parent
                    # steals the very cores the worker groups need
                    time.sleep(self._idle)
                    self._idle = min(self._idle * 2, self.parent_idle_cap)
        finally:
            self.stop()
        wall = time.perf_counter() - self._stream_t0
        return self._report(self._n_in, wall)

    def _drain_final(self, limit: int = 256) -> bool:
        progress = False
        for _ in range(limit):
            got = self._exchanges[-1].reorder.poll()
            if got is None:
                break
            t, tag, data, _span = got
            progress = True
            if tag == shm.TAG_EOF:
                self._eof_seen = True
                break
            if tag == shm.TAG_SPILL:
                tag, data = self._take_spill(t)
            if tag == shm.TAG_BUNDLES:
                bundles, out_marks, dropped = pickle.loads(data)
                for m in dropped:
                    self._record_dropped(m)
                mk = dict(out_marks) if out_marks else None
                for i, outs in enumerate(bundles):
                    self._emit(outs, mk.get(i) if mk else None)
            elif tag == shm.TAG_MBUNDLE:
                outs, m = pickle.loads(data)
                if outs:
                    self._emit(outs, m)
                elif m is not None:
                    self._record_dropped(m)
            elif tag == shm.TAG_COLBLOCK:
                from ..columnar.codec import decode_block

                block = decode_block(data)
                mk = dict(block.marks) if block.marks else None
                for i, v in enumerate(block.to_values()):
                    self._emit([v], mk.get(i) if mk else None)
            else:
                self._emit(shm.decode_bundle(tag, data), None)
        return progress

    # ------------------------------------------------------------------- tail
    def _emit(self, outs: list, marker: Optional[_Marker]) -> None:
        if self._tail is not None:
            inlet = self._tail._inlet(self._tail._source_name)
            for j, v in enumerate(outs):
                inlet(v, marker if j == 0 else None)
            if not outs and marker is not None:
                self._record_dropped(marker)
            return
        now = time.perf_counter()
        self._egress_count += len(outs)
        if outs:
            self._last_egress_ts = now
        if self.collect_outputs:
            self.outputs.extend(outs)
        if marker is not None:
            if outs:
                marker.exit = now
                self.markers.append(marker)
            else:
                self._record_dropped(marker)

    def _pump_tail(self) -> None:
        """Run the tail graph to quiescence, single-threaded (serial order)."""
        tail = self._tail
        while True:
            did = 0
            for node in tail.nodes:
                did += node.work(0, 1 << 30)
            if did == 0:
                return

    # ----------------------------------------------------------------- report
    @property
    def egress_count(self) -> int:
        """Tuples egressed so far (tail-aware)."""
        if self._tail is not None:
            return self._tail.egress_count
        return self._egress_count

    def processing_latencies(self, lo: float = 0.2, hi: float = 0.8) -> list:
        """Marker latencies in the [lo, hi] arrival-percentile window (§7)."""
        ms = self.markers if self._tail is None else self._tail.markers
        return percentile_latencies(ms, lo, hi)

    def _report(self, n_in: int, wall: float) -> RunReport:
        if self._tail is not None:
            self.outputs = self._tail.outputs
            self.markers = list(self._tail.markers)
            last_out = self._tail._last_egress_ts
        else:
            last_out = self._last_egress_ts
        lats = sorted(self.processing_latencies())
        mean_lat = sum(lats) / len(lats) if lats else 0.0
        p99 = lats[int(0.99 * (len(lats) - 1))] if lats else 0.0
        n_procs = sum(p.workers for p in self.stage_plans) + max(
            len(self.stage_plans) - 1, 0
        )
        busy = self._worker_busy / (n_procs * wall) if wall > 0 else 0.0
        window = wall
        if self._first_push_ts is not None and last_out is not None:
            window = max(last_out - self._first_push_ts, 1e-9)
        out_n = self.egress_count
        # A 0/1-tuple egress has no meaningful first-push→last-egress window
        # (it would divide by ~0 and report absurd rates): report 0.0.
        egress_thru = out_n / window if (window > 0 and out_n > 1) else 0.0
        return RunReport(
            tuples_in=n_in,
            tuples_out=out_n,
            wall_time=wall,
            throughput=n_in / wall if wall > 0 else 0.0,
            egress_throughput=egress_thru,
            mean_latency=mean_lat,
            p99_latency=p99,
            worker_busy_frac=busy,
        )
