"""Process-parallel execution backend (sidesteps the GIL).

The threaded :class:`~.runtime.StreamRuntime` can never exceed ~1 core of
real Python work; this backend runs each worker in its own **forked OS
process** and moves tuples over shared-memory rings (:mod:`.shm`):

  parent ──ingress SPSC ring──▶ worker₀..worker_{N-1} ──reorder ring──▶ parent

Execution model (data parallelism over the *parallel segment*):

- The operator chain is split into a **parallel segment** — the maximal
  ingress prefix every worker can execute independently — and a **tail**
  executed in the parent, in serial order, after the reorder.  The segment is
  the leading run of stateless operators (round-robin routing); if the chain
  *starts* with a partitioned-stateful operator, that operator plus the
  following stateless run forms the segment and tuples are routed by its
  partitioner, so per-key state stays worker-local (keyed routing).
- Every dispatch unit gets a global serial; each worker publishes exactly one
  result per serial (possibly empty — filtered tuples punch their hole) into
  a shared-memory reorder ring mirroring the paper's non-blocking reorder
  buffer, so parent-side egress is in exact ingress order: the process
  backend's output equals the sequential reference, same as the threaded
  backend.
- The dispatch unit is a **micro-batch** of ``io_batch`` tuples (round-robin
  routing only; keyed routing stays per-tuple because per-worker batch
  accumulation would reorder tuples across workers).  Batching amortizes the
  parent's per-tuple encode/dispatch/drain cost — the single parent process
  otherwise becomes the scaling bottleneck it was built to remove.
- Crash tolerance (stateless segments): the parent tracks in-flight serials
  per worker; if a worker dies it is re-forked and its un-drained serials are
  re-dispatched.  Replayed serials that were already drained fail the reorder
  ring's entry condition (``t < next``) and are dropped; duplicate publishes
  of an in-window serial are idempotent because segment functions are
  deterministic.  Keyed segments lose worker-local state on a crash, so there
  a dead worker raises instead of restarting.

Payloads ride fixed-width ring slots (ints/floats raw, batches and odd
payloads pickled — the slow path); result bundles too large for a slot spill
to a per-worker pipe with a spill tag left in the ring, preserving order.
"""
from __future__ import annotations

import collections
import itertools
import multiprocessing
import os
import pickle
import time
import uuid
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .operators import OpSpec, PARTITIONED, STATELESS, _Marker
from .pipeline import GraphPipeline, NodeSpec, percentile_latencies
from .runtime import RunReport
from . import shm

TAG_BATCH = 16  # record payload is pickle([values]) / pickle([bundles])


def _chain_nodes(specs: Sequence[OpSpec]):
    names = [f"{i:03d}_{s.name}" for i, s in enumerate(specs)]
    return dict(zip(names, specs)), list(zip(names, names[1:]))


def _apply_segment(ops: List[OpSpec], states: List[dict], value: Any) -> list:
    """Flat-map ``value`` through the parallel segment (worker-side)."""
    vals = [value]
    for oi, op in enumerate(ops):
        nxt: list = []
        if op.kind == STATELESS:
            fn = op.fn
            for v in vals:
                nxt.extend(fn(v))
        else:  # partitioned: per-key state, worker-local (keyed routing)
            st_map = states[oi]
            for v in vals:
                k = op.key_fn(v)
                s = st_map.get(k)
                if s is None:
                    s = op.init_state()
                s, outs = op.fn(s, k, v)
                st_map[k] = s
                nxt.extend(outs)
        vals = nxt
        if not vals:
            break
    return vals


def _worker_main(wid, ingress, reorder, conn, seg_ops):
    """Worker process body (entered via fork; exits with os._exit)."""
    states = [dict() for _ in seg_ops]
    busy = 0.0
    processed = 0
    code = 0
    try:
        idle = 1e-6
        while True:
            rec = ingress.get()
            if rec is None:
                if ingress.closed():
                    break
                time.sleep(idle)
                idle = min(idle * 2, 1e-3)
                continue
            idle = 1e-6
            serial, tag, data = rec
            t_begin = time.perf_counter()
            if tag == TAG_BATCH:
                values = pickle.loads(data)
                bundles = [_apply_segment(seg_ops, states, v) for v in values]
                processed += len(values)
                btag, bdata = TAG_BATCH, pickle.dumps(
                    bundles, protocol=pickle.HIGHEST_PROTOCOL
                )
            else:
                value = shm.decode_value(tag, data)
                outs = _apply_segment(seg_ops, states, value)
                processed += 1
                btag, bdata = shm.encode_bundle(outs)
            busy += time.perf_counter() - t_begin
            if len(bdata) > reorder.payload_bytes:
                conn.send(("spill", serial, btag, bdata))  # body via pipe
                btag, bdata = shm.TAG_SPILL, b""
            spin = 1e-6
            while True:
                st = reorder.try_publish(serial, btag, bdata, t_begin)
                if st != shm.ShmReorderRing.FULL:
                    break
                time.sleep(spin)
                spin = min(spin * 2, 1e-3)
    except BaseException as exc:  # noqa: BLE001 — forwarded to the parent
        code = 70
        try:
            conn.send(("error", wid, f"{type(exc).__name__}: {exc}"))
        except Exception:
            pass
    try:
        conn.send(("stats", wid, busy, processed))
        conn.close()
    except Exception:
        pass
    os._exit(code)  # skip inherited atexit/resource_tracker teardown


class ProcessRuntime:
    """Drives a dataflow graph with OS-process workers + shared-memory rings.

    Mirrors the :class:`~.runtime.StreamRuntime` reporting surface
    (``run(source) -> RunReport``) and the pipeline result surface
    (``outputs``, ``egress_count``, ``markers``) so ``run_pipeline``/
    ``run_graph`` can return it in the pipeline slot.
    """

    def __init__(
        self,
        nodes: Dict[str, NodeSpec],
        edges: Sequence[Tuple[str, str]],
        *,
        num_workers: int = 4,
        marker_interval: int = 64,
        collect_outputs: bool = False,
        io_batch: int = 32,
        ring_slots: int = 2048,
        slot_bytes: int = 1024,
        reorder_size: int = 1024,
        reorder_payload: int = 4096,
        max_inflight: Optional[int] = None,  # dispatch units; default 8/worker
        restart_on_crash: bool = True,
        reorder_scheme: str = "non_blocking",
        worklist_scheme: str = "hybrid",
        **_ignored,  # thread-backend knobs (heuristic, ...) have no meaning here
    ):
        if num_workers < 1:
            raise ValueError("need at least one worker process")
        if "fork" not in multiprocessing.get_all_start_methods():
            raise RuntimeError(
                "process backend requires the fork start method (POSIX); "
                "use backend='thread' on this platform"
            )
        self._ctx = multiprocessing.get_context("fork")
        self.num_workers = num_workers
        self.marker_interval = marker_interval
        self.collect_outputs = collect_outputs
        self.ring_slots = ring_slots
        self.slot_bytes = slot_bytes
        self.reorder_size = reorder_size
        self.reorder_payload = reorder_payload
        # In-flight dispatch units are doubly bounded: by the reorder window
        # (correctness — workers must be able to publish) and by this backlog
        # throttle (latency — an unbounded backlog pushes queueing delay into
        # every marker while adding nothing once each worker has spare units).
        self.max_inflight = min(
            reorder_size, max_inflight if max_inflight else 8 * num_workers
        )
        self.restart_on_crash = restart_on_crash
        self._tail_opts = dict(
            reorder_scheme=reorder_scheme, worklist_scheme=worklist_scheme
        )

        self.node_specs = dict(nodes)
        self.edges = [tuple(e) for e in edges]
        self._segment, tail_nodes, tail_edges = self._split(nodes, self.edges)
        self._keyed = bool(self._segment) and self._segment[0].kind == PARTITIONED
        # Keyed routing keeps per-tuple dispatch: batches accumulate per
        # worker, which would interleave egress across workers otherwise.
        self.io_batch = 1 if self._keyed else max(1, io_batch)
        self._tail: Optional[GraphPipeline] = None
        if tail_nodes:
            self._tail = GraphPipeline(
                tail_nodes,
                tail_edges,
                marker_interval=0,  # markers are injected by the parent
                collect_outputs=collect_outputs,
                num_workers=1,
                **self._tail_opts,
            )

        # result surface (used directly when the tail is empty)
        self.outputs: list = []
        self.markers: list[_Marker] = []
        self._egress_count = 0
        self._first_push_ts: Optional[float] = None
        self._last_egress_ts: Optional[float] = None

        # live state
        self._ingress: List[Optional[shm.ShmSpscRing]] = []
        self._reorder: Optional[shm.ShmReorderRing] = None
        self._procs: List[Optional[multiprocessing.Process]] = []
        self._conns: List[Any] = []
        self._dead_rings: List[shm.ShmSpscRing] = []
        self._spills: dict[int, tuple[int, bytes]] = {}
        self._worker_busy = 0.0
        self._worker_processed = 0
        self.restarts = 0  # crash-recovery instrumentation

    @classmethod
    def from_chain(cls, specs: Sequence[OpSpec], **kw) -> "ProcessRuntime":
        nodes, edges = _chain_nodes(list(specs))
        return cls(nodes, edges, **kw)

    # ------------------------------------------------------------ graph split
    @staticmethod
    def _split(nodes: Dict[str, NodeSpec], edges):
        """(segment ops, tail nodes, tail edges): the parallel segment is the
        maximal worker-executable ingress prefix of the graph."""
        succ: dict[str, list] = {n: [] for n in nodes}
        pred: dict[str, list] = {n: [] for n in nodes}
        for u, v in edges:
            succ[u].append(v)
            pred[v].append(u)
        sources = [n for n in nodes if not pred[n]]
        if len(sources) != 1:
            raise ValueError(f"graph needs exactly one ingress (got {sources})")
        segment: list[OpSpec] = []
        seg_names: set[str] = set()
        cur = sources[0]
        while cur is not None:
            spec = nodes.get(cur)
            if not isinstance(spec, OpSpec) or len(succ.get(cur, ())) > 1:
                break
            if spec.kind == STATELESS:
                pass
            elif spec.kind == PARTITIONED and not segment:
                pass  # keyed-routing head
            else:
                break
            segment.append(spec)
            seg_names.add(cur)
            cur = succ[cur][0] if succ[cur] else None
        tail_nodes = {k: v for k, v in nodes.items() if k not in seg_names}
        tail_edges = [(u, v) for u, v in edges if u not in seg_names]
        return segment, tail_nodes, tail_edges

    # -------------------------------------------------------------- lifecycle
    def _spawn_worker(self, widx: int) -> None:
        prefix = f"repro_{os.getpid()}_{uuid.uuid4().hex[:8]}_w{widx}"
        ring = shm.ShmSpscRing(prefix, slots=self.ring_slots,
                               slot_bytes=self.slot_bytes)
        parent_conn, child_conn = self._ctx.Pipe(duplex=False)
        proc = self._ctx.Process(
            target=_worker_main,
            args=(widx, ring, self._reorder, child_conn, self._segment),
            daemon=True,
        )
        proc.start()
        child_conn.close()
        if widx < len(self._ingress):
            self._ingress[widx] = ring
            self._procs[widx] = proc
            self._conns[widx] = parent_conn
        else:
            self._ingress.append(ring)
            self._procs.append(proc)
            self._conns.append(parent_conn)

    def _setup(self) -> None:
        prefix = f"repro_{os.getpid()}_{uuid.uuid4().hex[:8]}"
        self._reorder = shm.ShmReorderRing(
            prefix, size=self.reorder_size, payload_bytes=self.reorder_payload
        )
        for w in range(self.num_workers):
            self._spawn_worker(w)

    def stop(self) -> None:
        """Tear everything down; idempotent, always unlinks shared memory."""
        for ring in self._ingress:
            if ring is not None:
                try:
                    ring.close_ring()
                except Exception:
                    pass
        for p in self._procs:
            if p is not None:
                p.join(timeout=5.0)
                if p.is_alive():
                    p.terminate()
                    p.join(timeout=1.0)
        self._drain_conns(final=True)
        for conn in self._conns:
            try:
                conn.close()
            except Exception:
                pass
        for ring in self._ingress + self._dead_rings:
            if ring is not None:
                ring.close()
                ring.unlink()
        if self._reorder is not None:
            self._reorder.close()
            self._reorder.unlink()
        self._ingress, self._procs, self._conns = [], [], []
        self._dead_rings = []
        self._reorder = None

    # ---------------------------------------------------------------- helpers
    def _route(self, value: Any) -> int:
        if self._keyed:
            op = self._segment[0]
            return op.partitioner(op.key_fn(value)) % self.num_workers
        return -1  # round-robin: any worker

    def _drain_conns(self, final: bool = False) -> None:
        """Sweep worker pipes for spills / stats / errors.

        ``final`` (cleanup context) swallows worker errors: by then every
        input has drained, so a late error cannot have corrupted the output.
        """
        for conn in self._conns:
            if conn is None:
                continue
            try:
                while conn.poll():
                    self._on_message(conn.recv(), ignore_errors=final)
            except (EOFError, OSError):
                continue

    def _on_message(self, msg, ignore_errors: bool = False) -> None:
        kind = msg[0]
        if kind == "spill":
            self._spills[msg[1]] = (msg[2], msg[3])
        elif kind == "stats":
            self._worker_busy += msg[2]
            self._worker_processed += msg[3]
        elif kind == "error" and not ignore_errors:
            raise RuntimeError(f"worker {msg[1]} failed in operator fn: {msg[2]}")

    def _take_spill(self, serial: int, widx: int) -> tuple[int, bytes]:
        if serial in self._spills:
            return self._spills.pop(serial)
        deadline = time.perf_counter() + 10.0
        while time.perf_counter() < deadline:
            conn = self._conns[widx]
            if conn is not None:
                try:
                    if conn.poll(0.001):
                        self._on_message(conn.recv())
                except (EOFError, OSError):
                    self._drain_conns()  # worker died: sweep every pipe
            else:
                self._drain_conns()
            if serial in self._spills:
                return self._spills.pop(serial)
        raise TimeoutError(f"spilled bundle for serial {serial} never arrived")

    def _handle_crash(self, widx: int, inflight: dict) -> list:
        """Respawn worker ``widx``; return its un-drained serials for replay."""
        if self._keyed:
            raise RuntimeError(
                "worker process died under keyed routing; per-key state is "
                "lost and cannot be replayed (use a stateless segment for "
                "crash tolerance)"
            )
        if not self.restart_on_crash:
            raise RuntimeError(f"worker {widx} died (restart_on_crash=False)")
        # salvage spills already sent, then retire the pipe and rings
        try:
            while self._conns[widx].poll():
                self._on_message(self._conns[widx].recv())
        except (EOFError, OSError):
            pass
        try:
            self._conns[widx].close()
        except Exception:
            pass
        self._conns[widx] = None
        old = self._ingress[widx]
        if old is not None:
            self._dead_rings.append(old)  # unlink at stop(); may be mid-write
            self._ingress[widx] = None
        self._spawn_worker(widx)
        self.restarts += 1
        return sorted(t for t, (w, _, _) in inflight.items() if w == widx)

    # ------------------------------------------------------------------ drive
    def run(
        self,
        source: Iterable,
        *,
        drain: bool = True,
        drain_timeout: float = 60.0,
    ) -> RunReport:
        self._setup()
        t0 = time.perf_counter()
        n_in = 0
        # serial -> (widx, tag, data) of every dispatched-but-undrained unit
        inflight: dict[int, tuple[int, int, bytes]] = {}
        # serial -> [(offset-in-batch, marker), ...]
        markers: dict[int, list[tuple[int, _Marker]]] = {}
        outq: collections.deque = collections.deque()  # ready (serial,tag,data,widx)
        next_serial = 1
        rr = itertools.cycle(range(self.num_workers))
        src = iter(source)
        src_done = False
        acc_vals: list = []
        acc_marks: list[tuple[int, _Marker]] = []
        deadline = None
        monitor_at = t0

        def seal_batch():
            nonlocal next_serial, acc_vals, acc_marks
            serial = next_serial
            next_serial += 1
            if self.io_batch > 1:
                tag, data = TAG_BATCH, pickle.dumps(
                    acc_vals, protocol=pickle.HIGHEST_PROTOCOL
                )
                widx = -1
            else:
                tag, data = shm.encode_value(acc_vals[0])
                widx = self._route(acc_vals[0])
            if acc_marks:
                markers[serial] = acc_marks
            outq.append((serial, tag, data, widx))
            acc_vals, acc_marks = [], []

        try:
            while True:
                progress = False

                # -- intake: seal source tuples into dispatch units ----------
                while (
                    not src_done
                    and len(outq) < 2 * self.num_workers
                    and next_serial - self._reorder.next_serial < self.max_inflight
                ):
                    try:
                        value = next(src)
                    except StopIteration:
                        src_done = True
                        if acc_vals:
                            seal_batch()
                        deadline = time.perf_counter() + drain_timeout
                        break
                    if self._first_push_ts is None:
                        self._first_push_ts = time.perf_counter()
                    n_in += 1
                    acc_vals.append(value)
                    if self.marker_interval and n_in % self.marker_interval == 0:
                        acc_marks.append(
                            (len(acc_vals) - 1, _Marker(time.perf_counter()))
                        )
                    if len(acc_vals) >= self.io_batch:
                        seal_batch()

                # -- dispatch ready units to worker rings --------------------
                while outq:
                    serial, tag, data, widx = outq[0]
                    if widx == -2:  # crash replay entry
                        if serial not in inflight:
                            outq.popleft()  # drained while queued for replay
                            continue
                        widx = -1  # route anywhere (stateless segment)
                    if widx < 0:
                        sent = False
                        for _ in range(self.num_workers):
                            w = next(rr)
                            if self._ingress[w].put(serial, tag, data):
                                widx, sent = w, True
                                break
                        if not sent:
                            break  # every ring full; drain first
                    elif not self._ingress[widx].put(serial, tag, data):
                        break  # keyed: single legal target, wait
                    outq.popleft()
                    inflight[serial] = (widx, tag, data)
                    progress = True

                # -- drain the reorder ring in serial order ------------------
                for _ in range(64):
                    got = self._reorder.poll()
                    if got is None:
                        break
                    t, tag, begin, data = got
                    widx = inflight.pop(t)[0]
                    if tag == shm.TAG_SPILL:
                        tag, data = self._take_spill(t, widx)
                    marks = markers.pop(t, ())
                    if tag == TAG_BATCH:
                        bundles = pickle.loads(data)
                        mk = dict(marks)
                        for i, outs in enumerate(bundles):
                            m = mk.get(i)
                            if m is not None:
                                m.begin = begin
                            self._emit(outs, m)
                    else:
                        outs = shm.decode_bundle(tag, data)
                        m = marks[0][1] if marks else None
                        if m is not None:
                            m.begin = begin
                        self._emit(outs, m)
                    progress = True
                if progress and self._tail is not None:
                    self._pump_tail()

                # -- crash monitor (periodic) --------------------------------
                now = time.perf_counter()
                if now >= monitor_at:
                    monitor_at = now + 0.02
                    self._drain_conns()
                    for widx, p in enumerate(self._procs):
                        if p is not None and not p.is_alive():
                            for t in self._handle_crash(widx, inflight):
                                if self._reorder.published(t):
                                    continue  # result survived; just drain it
                                _, tag, data = inflight[t]
                                outq.appendleft((t, tag, data, -2))
                            progress = True

                # -- termination ---------------------------------------------
                if src_done and not outq and not inflight:
                    if self._tail is None or self._tail.drained():
                        break
                    self._pump_tail()
                    if self._tail.drained():
                        break
                if not drain and src_done:
                    break
                if not progress:
                    if deadline is not None and time.perf_counter() > deadline:
                        raise TimeoutError("process pipeline failed to drain")
                    time.sleep(2e-5)
        finally:
            self.stop()
        wall = time.perf_counter() - t0
        return self._report(n_in, wall)

    # ------------------------------------------------------------------- tail
    def _emit(self, outs: list, marker: Optional[_Marker]) -> None:
        if self._tail is not None:
            inlet = self._tail._inlet(self._tail._source_name)
            for j, v in enumerate(outs):
                inlet(v, marker if j == 0 else None)
            if not outs and marker is not None:
                marker.exit = time.perf_counter()
                self._tail._record_marker(marker)
            return
        now = time.perf_counter()
        self._egress_count += len(outs)
        if outs:
            self._last_egress_ts = now
        if self.collect_outputs:
            self.outputs.extend(outs)
        if marker is not None:
            marker.exit = now
            self.markers.append(marker)

    def _pump_tail(self) -> None:
        """Run the tail graph to quiescence, single-threaded (serial order)."""
        tail = self._tail
        while True:
            did = 0
            for node in tail.nodes:
                did += node.work(0, 1 << 30)
            if did == 0:
                return

    # ----------------------------------------------------------------- report
    @property
    def egress_count(self) -> int:
        if self._tail is not None:
            return self._tail.egress_count
        return self._egress_count

    def processing_latencies(self, lo: float = 0.2, hi: float = 0.8) -> list:
        ms = self.markers if self._tail is None else self._tail.markers
        return percentile_latencies(ms, lo, hi)

    def _report(self, n_in: int, wall: float) -> RunReport:
        if self._tail is not None:
            self.outputs = self._tail.outputs
            self.markers = list(self._tail.markers)
            last_out = self._tail._last_egress_ts
        else:
            last_out = self._last_egress_ts
        lats = sorted(self.processing_latencies())
        mean_lat = sum(lats) / len(lats) if lats else 0.0
        p99 = lats[int(0.99 * (len(lats) - 1))] if lats else 0.0
        busy = self._worker_busy / (self.num_workers * wall) if wall > 0 else 0.0
        window = wall
        if self._first_push_ts is not None and last_out is not None:
            window = max(last_out - self._first_push_ts, 1e-9)
        out_n = self.egress_count
        return RunReport(
            tuples_in=n_in,
            tuples_out=out_n,
            wall_time=wall,
            throughput=n_in / wall if wall > 0 else 0.0,
            egress_throughput=out_n / window if window > 0 else 0.0,
            mean_latency=mean_lat,
            p99_latency=p99,
            worker_busy_frac=busy,
        )
