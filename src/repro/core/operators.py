"""Operator abstractions and their executable (schedulable) nodes (paper §2, §5).

An :class:`OpSpec` declares an operator; ``compile`` (in pipeline.py) turns each
into an :class:`OperatorNode` — an independently schedulable unit owning its
worklist(s), reorder buffer, and runtime statistics, exactly the decoupled
asynchronous execution model of §2.2.

Operator function signatures:
  stateless:    fn(value) -> list[out]
  stateful:     fn(state, value) -> (state, list[out])
  partitioned:  fn(state, key, value) -> (state, list[out])
  device:       fn(value) -> list[out]   (the NumPy reference; the process
                backend instead batches columnar blocks through the declared
                ``device_kernel`` via :class:`repro.columnar.DeviceExecutor`)

Contract: operator functions must be **deterministic** (same state/value in,
same outputs out) and side-effect-free outside their own state.  The thread
backend merely assumes this for reproducibility, but the process backend
(:mod:`.procrun`) *relies* on it — crash recovery re-executes a dead
worker's uncommitted unit and treats duplicate publishes as idempotent,
which is only sound for deterministic functions.  Functions (and their
closures) must also survive ``fork``-style pickling when they ride
process-backend dispatch units.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable, Optional

from .hybrid import make_worklist
from .reorder import ParkingReorderBuffer, make_reorder_buffer
from .serial import AtomicLong, SerialAssigner

STATELESS = "stateless"
STATEFUL = "stateful"
PARTITIONED = "partitioned"
DEVICE = "device"


@dataclass
class OpSpec:
    name: str
    kind: str  # stateless | stateful | partitioned | device
    fn: Callable
    key_fn: Optional[Callable[[Any], Hashable]] = None
    num_partitions: int = 1
    partitioner: Optional[Callable[[Hashable], int]] = None
    init_state: Callable[[], Any] = lambda: None
    # Declared priors (used by the scheduler before estimates warm up, and by
    # the discrete-event simulator as ground-truth virtual costs).
    cost_us: float = 1.0
    selectivity: float = 1.0
    # Device-offload declaration (kind == DEVICE only; see repro.columnar).
    # ``fn`` stays the per-value NumPy reference so every non-device path
    # (thread backend, calibration, correctness tests) runs the spec as-is.
    schema: Any = None  # repro.columnar.Schema of the fixed-width rows
    device_kernel: Any = None  # (registry name, frozen params tuple)
    device_batch: int = 0  # rows per device dispatch (0 = runtime knob)
    device_backend: str = "auto"  # auto | jax | numpy

    def __post_init__(self):
        if self.kind not in (STATELESS, STATEFUL, PARTITIONED, DEVICE):
            raise ValueError(f"bad operator kind {self.kind!r}")
        if self.kind == PARTITIONED:
            if self.key_fn is None:
                raise ValueError(f"{self.name}: partitioned operator needs key_fn")
            if self.partitioner is None:
                n = self.num_partitions
                self.partitioner = lambda k, n=n: hash(k) % n
        if self.kind == DEVICE:
            if self.device_kernel is None or self.schema is None:
                raise ValueError(
                    f"{self.name}: device operator needs device_kernel and schema"
                )
            if self.selectivity != 1.0:
                # Elementwise column maps are 1:1 by construction; anything
                # else would make partial-batch flushes change results.
                raise ValueError(f"{self.name}: device operators are 1:1")


class _Marker:
    """Latency probe riding on a tuple (paper §7 'marker wrappers')."""

    __slots__ = ("entry", "begin", "exit")

    def __init__(self, entry: float):
        self.entry = entry  # enqueue at pipeline ingress
        self.begin = 0.0  # first operator starts processing (=> processing latency)
        self.exit = 0.0  # egress


@dataclass
class OpStats:
    consumed: int = 0
    produced: int = 0
    busy_time: float = 0.0  # seconds of worker time spent in fn
    window_busy: float = 0.0  # worker time in current CT window

    def cost(self, prior: float) -> float:
        """Estimated per-tuple processing cost in seconds."""
        if self.consumed < 8:
            return prior
        return self.busy_time / self.consumed

    def selectivity(self, prior: float) -> float:
        """Estimated outputs per input (``prior`` until estimates warm up)."""
        if self.consumed < 8:
            return prior
        return self.produced / self.consumed


class OperatorNode:
    """Independently schedulable executable operator."""

    def __init__(
        self,
        spec: OpSpec,
        index: int,
        *,
        reorder_scheme: str = "non_blocking",
        worklist_scheme: str = "hybrid",
        reorder_size: int = 1024,
        num_workers: int = 1,
        batch_size: int = 1,
    ):
        self.spec = spec
        self.index = index
        # Micro-batched tuple flow: tuples travel node-to-node in batches,
        # amortizing per-tuple queue/reorder/lock overhead.  Stateless and
        # stateful nodes enqueue whole batches (one serial, one reorder send,
        # one downstream push per batch); partitioned nodes unpack batches to
        # per-tuple worklist items (bucket ownership is per-tuple) and their
        # egress re-enters the batched flow one bundle at a time.
        self.batched = batch_size > 1
        self.downstream: Optional[Callable[[Any, Optional[_Marker]], None]] = None
        self.downstream_batch: Optional[Callable[[list, list], None]] = None
        self.stats = OpStats()
        self.workers = AtomicLong(0)  # currently allotted workers (w_i)
        # Effective parallelism cap M_i: the adaptive controller lowers this
        # below max_dop to match the operator's estimated load share.
        self.dop_cap = 1 << 30
        self._serials = SerialAssigner()
        self._stats_lock = threading.Lock()

        self._queued_tuples = AtomicLong(0)  # batched-mode tuple count
        if spec.kind == STATEFUL:
            self.max_dop = 1
            self._state = spec.init_state()
            self._queue: collections.deque = collections.deque()
            self._reorder = None  # single worker => already ordered
        elif spec.kind in (STATELESS, DEVICE):
            # DEVICE runs its per-value NumPy reference here: on the thread
            # backend a device op is just a stateless flat-map (batched
            # kernel dispatch exists only on the process backend).
            self.max_dop = 1 << 30  # effectively ∞ (capped by cores)
            self._queue = collections.deque()
            # Parking wrapper: non-FIFO worklists (Volcano bucket ownership,
            # hybrid budget handoffs) can pull a serial arbitrarily far ahead
            # of the ring window; spinning on the reject would deadlock once
            # every worker holds a far-future serial.
            self._reorder = ParkingReorderBuffer(
                make_reorder_buffer(reorder_scheme, self._emit, size=reorder_size)
            )
        else:  # PARTITIONED
            self.max_dop = spec.num_partitions
            self._states: dict[int, Any] = {}
            self._worklist = make_worklist(
                worklist_scheme,
                spec.num_partitions,
                spec.partitioner,
                num_workers=num_workers,
            )
            self._reorder = ParkingReorderBuffer(
                make_reorder_buffer(reorder_scheme, self._emit, size=reorder_size)
            )

    # ---- producer side ----------------------------------------------------
    def push(self, value: Any, marker: Optional[_Marker] = None) -> None:
        """Enqueue one tuple (serial assigned here, in push order)."""
        serial = self._serials.next()
        if self.spec.kind == PARTITIONED:
            key = self.spec.key_fn(value)
            self._worklist.add(serial, key, (value, marker))
        else:
            self._queue.append((serial, value, marker))

    def push_batch(self, values: list, markers: list) -> None:
        """Batched-mode inlet: one queue entry (and one serial) per batch.

        ``markers`` is a list of ``(offset-in-batch, marker)`` pairs — probes
        stay attached to the exact tuple they rode in on (offsets are
        remapped through every flat-map, see :meth:`_operate_batch`).
        """
        if self.spec.kind == PARTITIONED:
            # Bucket ownership is per-tuple: unpack, pairing by offset.
            by_off = dict(markers) if markers else None
            for i, v in enumerate(values):
                self.push(v, by_off.get(i) if by_off else None)
            return
        serial = self._serials.next()
        self._queued_tuples.fetch_add(len(values))
        self._queue.append((serial, values, markers))

    # ---- scheduler interface -----------------------------------------------
    def worklist_size(self) -> int:
        """Queued tuples awaiting this operator (scheduler's I_i)."""
        if self.spec.kind == PARTITIONED:
            return len(self._worklist)
        if self.batched:
            return max(self._queued_tuples.load(), 0)
        return len(self._queue)

    def schedulable(self) -> bool:
        """Whether a worker may be assigned here: queued work exists and the
        effective parallelism cap ``min(max_dop, dop_cap)`` is not reached."""
        cap = min(self.max_dop, self.dop_cap)
        return self.workers.load() < cap and self.worklist_size() > 0

    # ---- worker side --------------------------------------------------------
    def work(self, worker_id: int, budget: int) -> int:
        """Process up to ``budget`` tuples; returns the number processed."""
        if self.spec.kind == PARTITIONED:
            return self._worklist.consume(worker_id, self._operate_partitioned, budget)
        done = 0
        while done < budget:
            try:
                serial, value, marker = self._queue.popleft()
            except IndexError:
                break
            if self.batched:  # entry is (serial, values, markers)
                n = max(len(value), 1)
                self._queued_tuples.fetch_sub(len(value))
                self._operate_batch(serial, value, marker)
                done += n
            else:
                self._operate(serial, value, marker)
                done += 1
        return done

    # ---- internals ----------------------------------------------------------
    def _operate(self, serial: int, value: Any, marker: Optional[_Marker]) -> None:
        if marker is not None and self.index == 0 and not marker.begin:
            # not already stamped: a process-backend tail pipeline receives
            # markers whose begin was set in the worker's parallel segment
            marker.begin = time.perf_counter()
        t0 = time.perf_counter()
        if self.spec.kind == STATEFUL:
            self._state, outs = self.spec.fn(self._state, value)
        else:
            outs = self.spec.fn(value)
        dt = time.perf_counter() - t0
        self._account(dt, len(outs))
        if self._reorder is None:
            self._emit((outs, marker))
        else:
            self._reorder.send(serial, (outs, marker))

    def _operate_partitioned(self, serial: int, key: Hashable, item) -> None:
        value, marker = item
        if marker is not None and self.index == 0 and not marker.begin:
            marker.begin = time.perf_counter()
        t0 = time.perf_counter()
        # State is per KEY (the partition/bucket only controls concurrency —
        # tuples in one bucket are serialized, but each key has its own state,
        # exactly the paper's partitioned-stateful semantics).
        state = self._states.get(key)
        if state is None:
            state = self.spec.init_state()
        state, outs = self.spec.fn(state, key, value)
        self._states[key] = state
        dt = time.perf_counter() - t0
        self._account(dt, len(outs))
        if self.batched:  # re-enter the batched flow as a 1-tuple bundle
            self._reorder.send(serial, (outs, [(0, marker)] if marker else []))
        else:
            self._reorder.send(serial, (outs, marker))

    def _operate_batch(self, serial: int, values: list, markers: list) -> None:
        """Process one micro-batch: one fn sweep, one reorder send, one
        downstream push — the per-tuple overhead amortization.

        Marker offsets are remapped through the flat-map: a probe on input i
        re-attaches to the first output of input i; if input i produced no
        output its probe's journey ends here (exit stamped, recorded).
        """
        if self.index == 0:
            for _, m in markers:
                if not m.begin:
                    m.begin = time.perf_counter()
        by_off = dict(markers) if markers else None
        out_markers: list = []
        dropped: list = []
        t0 = time.perf_counter()
        outs: list = []
        stateful = self.spec.kind == STATEFUL
        state, fn = (self._state if stateful else None), self.spec.fn
        for i, v in enumerate(values):
            if stateful:
                state, o = fn(state, v)
            else:
                o = fn(v)
            if by_off is not None:
                m = by_off.get(i)
                if m is not None:
                    if o:
                        out_markers.append((len(outs), m))
                    else:
                        dropped.append(m)
            outs.extend(o)
        if stateful:
            self._state = state
        dt = time.perf_counter() - t0
        self._account(dt, len(outs), n_in=len(values))
        for m in dropped:
            m.exit = time.perf_counter()
            if self.on_marker_drop is not None:
                self.on_marker_drop(m)
        if self._reorder is None:
            self._emit((outs, out_markers))
        else:
            self._reorder.send(serial, (outs, out_markers))

    def overflow_count(self) -> int:
        """Serials parked past the reorder window (0 = no overflow)."""
        return 0 if self._reorder is None else self._reorder.parked_count()

    def _account(self, dt: float, n_out: int, n_in: int = 1) -> None:
        with self._stats_lock:
            s = self.stats
            s.consumed += n_in
            s.produced += n_out
            s.busy_time += dt
            s.window_busy += dt

    def _emit(self, payload) -> None:
        if self.batched:
            # payload is (outs, [(offset, marker)]); one downstream call per batch
            outs, markers = payload
            if outs:
                self.downstream_batch(outs, markers)
                return
            for _, m in markers:
                # batch fully filtered: the probes' journeys end here
                m.exit = time.perf_counter()
                if self.on_marker_drop is not None:
                    self.on_marker_drop(m)
            return
        outs, marker = payload
        down = self.downstream
        for j, out in enumerate(outs):
            down(out, marker if j == 0 else None)
        if not outs and marker is not None:
            # Tuple was filtered out: its journey ends here; record exit so the
            # latency probe is not lost. Wired by the pipeline.
            marker.exit = time.perf_counter()
            if self.on_marker_drop is not None:
                self.on_marker_drop(marker)

    on_marker_drop: Optional[Callable[["_Marker"], None]] = None
