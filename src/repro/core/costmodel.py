"""Cost-model-driven per-stage worker allocation (ROADMAP: "per-stage
worker-count allocation from cost priors").

The staged process backend (:mod:`.procrun`) cuts a pipeline into stages and
— before this module — handed every data-parallel stage the same flat
``num_workers``.  That starves a skewed pipeline's hot stage: the paper's
central claim is that handling *load imbalance*, not merely exposing data
parallelism, is what makes ordered streaming scale.  Following BriskStream's
relative-rate cost model (arXiv 1904.03604) and TStream's punctuation-bounded
live restructuring (arXiv 1904.03800), this module supplies:

- :func:`proportional_allocation` — divide a core budget across stages in
  proportion to their predicted load so stage throughputs equalize (the
  classic largest-remainder method; stateful stages stay pinned at one
  worker, keyed stages cap at their partition count).
- :class:`CostModel` — per-stage service cost + relative flow (tuples per
  source tuple), seeded from declared :class:`~.operators.OpSpec` priors or
  explicit ``cost_priors``, optionally refined by :meth:`CostModel.calibrate`
  (a short profiled dry run of the actual operator functions on buffered
  source tuples — legal because operator fns are required to be
  deterministic and side-effect-free) and by live observations
  (:meth:`CostModel.observe`).
- :class:`OccupancyMonitor` — samples the per-stage progress/backlog
  counters already flowing through :class:`~.shm.ExchangeRing` (drained
  serials = stage input tuples, ingress-ring queue depths = occupancy),
  re-estimates stage costs from observed service rates, and proposes a new
  width vector when occupancy drifts past a threshold for several
  consecutive samples — the trigger for :class:`~.procrun.ProcessRuntime`'s
  elastic replanning.
- :class:`TrafficMonitor` — the serving-tier counterpart: an offered-load
  rate EWMA fed by :meth:`repro.serve.SessionMux.load_signals` snapshots,
  converted to per-stage utilization against the live cost model, with
  hysteresis (separate grow/shrink thresholds), per-stage patience streaks,
  and post-resize cooldowns — so worker widths react to *traffic* (session
  fan-out, bursty/diurnal ramps), not just skew.

The thread backend's adaptive controller (:meth:`.scheduler.Scheduler.adapt`)
shares the cost surface (:func:`op_cost_us` folds ``cost_priors`` into
declared priors on both paths) but keeps ceil-of-share caps: a thread-side
``dop_cap`` is a cap, not a reservation, so a hot operator must stay able to
absorb idle workers — hard-partitioning applies only where widths reserve
forked processes.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .operators import OpSpec, STATEFUL

#: default worker budget for ``workers="auto"``: one process per core plus
#: one to hide exchange/feeder latency (stages overlap, so mild
#: oversubscription keeps the hot stage fed while feeders run).
def default_budget() -> int:
    return max((os.cpu_count() or 2) + 1, 2)


def resolve_workers(num_workers, budget: Optional[int] = None) -> int:
    """Resolve the ``num_workers`` API value ("auto" | int) to an int.

    The thread backend and :class:`~.pipeline.GraphPipeline` construction
    need a concrete integer; ``"auto"`` means "one worker per core" there
    (the process backend does finer per-stage division via
    :class:`CostModel`)."""
    if num_workers == "auto":
        return budget if budget is not None else max(os.cpu_count() or 2, 2)
    if not isinstance(num_workers, int):
        raise ValueError(
            f"num_workers must be an int or 'auto', got {num_workers!r}"
        )
    return num_workers


def op_cost_us(op: OpSpec, cost_priors: Optional[Dict[str, float]]) -> float:
    """Declared per-tuple cost of ``op`` in µs, with ``cost_priors``
    (``{op name: cost_us}``) taking precedence over the spec's own prior."""
    if cost_priors and op.name in cost_priors:
        return max(float(cost_priors[op.name]), 1e-3)
    return max(float(op.cost_us), 1e-3)


#: per-batch device dispatch overhead prior (µs): jax trace-cache hit +
#: host->device staging setup, amortised over the batch.
DEVICE_DISPATCH_US = 50.0
#: host<->device transfer bandwidth prior, bytes per µs (~8 GB/s).
DEVICE_BYTES_PER_US = 8192.0


def device_cost_us(
    op: OpSpec,
    device_batch: int,
    cost_priors: Optional[Dict[str, float]],
) -> float:
    """Per-tuple cost of a device op: the op's own compute prior plus the
    amortised dispatch overhead and the per-row transfer term (the schema's
    fixed row width is on the wire twice: in and out).  ``cost_priors``
    override the whole estimate, same as :func:`op_cost_us`."""
    if cost_priors and op.name in cost_priors:
        return max(float(cost_priors[op.name]), 1e-3)
    batch = max(int(device_batch), 1)
    cost = max(float(op.cost_us), 1e-3) + DEVICE_DISPATCH_US / batch
    if op.schema is not None:
        cost += 2.0 * op.schema.row_bytes / DEVICE_BYTES_PER_US
    return cost


def proportional_allocation(
    loads: Sequence[float],
    budget: int,
    mins: Sequence[int],
    caps: Sequence[int],
) -> List[int]:
    """Divide ``budget`` workers across stages proportionally to ``loads``.

    Every stage first receives ``mins[i]`` (the allocator never zeroes a
    stage); the remaining budget is split by the largest-remainder method of
    each stage's load share, clipped to ``caps[i]``.  Equalizing
    ``width_i / load_i`` equalizes predicted stage throughput — the pipeline
    moves at the pace of its slowest stage, so the optimum gives each stage
    width proportional to its load.  Leftover budget that no un-capped stage
    can absorb is simply not spent.  ``sum(result) <= max(budget,
    sum(mins))`` always holds.
    """
    n = len(loads)
    if not (n == len(mins) == len(caps)):
        raise ValueError("loads/mins/caps must have equal length")
    widths = [max(int(m), 0) for m in mins]
    caps = [max(int(c), w) for c, w in zip(caps, widths)]
    spare = budget - sum(widths)
    while spare > 0:
        # ideal extra share for each growable stage, by load
        grow = [i for i in range(n) if widths[i] < caps[i]]
        if not grow:
            break
        total = sum(loads[i] for i in grow) or float(len(grow))
        ideal = {
            i: spare * ((loads[i] / total) if total else 1.0 / len(grow))
            for i in grow
        }
        granted = 0
        for i in grow:
            take = min(int(ideal[i]), caps[i] - widths[i])
            widths[i] += take
            granted += take
        if granted == 0:
            # largest remainder: hand single workers to the biggest shares
            order = sorted(grow, key=lambda i: ideal[i] - int(ideal[i]),
                           reverse=True)
            for i in order:
                if spare - granted <= 0:
                    break
                if widths[i] < caps[i]:
                    widths[i] += 1
                    granted += 1
            if granted == 0:
                break
        spare -= granted
    return widths


def graph_flows(
    nodes: Dict[str, object],
    edges: Sequence[Tuple[str, str]],
    cost_priors: Optional[Dict[str, float]] = None,
):
    """Predicted per-operator flow profile of a dataflow graph.

    Propagates relative input flow (tuples per source tuple) through the
    topology — a ``Split`` divides its inbound flow evenly across branches,
    a ``Merge`` sums — chaining each :class:`~.operators.OpSpec`'s declared
    selectivity, with ``cost_priors`` overriding declared per-tuple costs.
    Returns ``(op_rows, routing_names)`` where ``op_rows`` is a list of
    ``(node_name, spec, flow, cost_us)`` tuples in topological order (op
    nodes only) and ``routing_names`` lists the Split/Merge node names.
    Shared by :meth:`.api.Engine.plan` (the plan's per-op load table) and
    kept here so the plan surface and the allocator price operators with
    the same :func:`op_cost_us` rule.
    """
    names = set(nodes)
    indeg = {n: 0 for n in names}
    succ: Dict[str, list] = {n: [] for n in names}
    for u, v in edges:
        if u not in names or v not in names:
            raise ValueError(f"edge ({u!r}, {v!r}) references unknown node")
        succ[u].append(v)
        indeg[v] += 1
    flow = {n: (1.0 if indeg[n] == 0 else 0.0) for n in names}
    ready = sorted(n for n in names if indeg[n] == 0)
    order: list = []
    while ready:
        n = ready.pop(0)
        order.append(n)
        for v in succ[n]:
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    if len(order) != len(names):
        raise ValueError("graph has a cycle")
    op_rows = []
    routing = []
    for n in order:
        spec = nodes[n]
        if isinstance(spec, OpSpec):
            out_flow = flow[n] * max(float(spec.selectivity), 0.0)
            op_rows.append((n, spec, flow[n], op_cost_us(spec, cost_priors)))
        else:  # Split/Merge: flow passes through (a split divides evenly)
            routing.append(n)
            out_flow = flow[n]
        outs = succ[n]
        if outs:
            share = out_flow / len(outs) if len(outs) > 1 else out_flow
            for v in outs:
                flow[v] += share
    return op_rows, routing


# --------------------------------------------------------------- cost model
@dataclass
class StageProfile:
    """Predicted shape of one stage: per-tuple service cost and relative
    input flow (stage input tuples per pipeline source tuple)."""

    index: int
    kind: str  # "stateless" | "keyed" | "stateful" | "device"
    cost_us: float
    flow: float = 1.0
    selectivity: float = 1.0  # stage output tuples per stage input tuple
    measured: bool = False  # True once calibration/observation replaced priors

    @property
    def load(self) -> float:
        """Relative work rate: input flow × per-tuple cost (BriskStream's
        relative-rate model — absolute input rates cancel out)."""
        return self.flow * self.cost_us


class CostModel:
    """Per-stage cost/flow accounting + the allocation rule.

    Built from the planner's :class:`~.procrun.StagePlan` list.  Stage cost
    is the sum of each operator's per-tuple cost weighted by its within-stage
    input flow (the running selectivity product); stage flow chains the same
    product across stages.
    """

    def __init__(
        self,
        plans: Sequence,
        cost_priors: Optional[Dict[str, float]] = None,
        device_batch: int = 256,
    ):
        self.plans = list(plans)
        self.cost_priors = dict(cost_priors) if cost_priors else None
        self.device_batch = max(int(device_batch), 1)
        self.profiles: List[StageProfile] = []
        flow = 1.0
        for plan in self.plans:
            cost = 0.0
            sel = 1.0
            for op in plan.ops:
                if plan.kind == "device":
                    cost += sel * device_cost_us(
                        op, self.device_batch, self.cost_priors
                    )
                else:
                    cost += sel * op_cost_us(op, self.cost_priors)
                sel *= max(float(op.selectivity), 0.0)
            if not plan.ops:  # identity pass-through stage
                cost = 1e-3
            self.profiles.append(
                StageProfile(plan.index, plan.kind, max(cost, 1e-3), flow, sel)
            )
            flow = max(flow * sel, 1e-9)

    # ------------------------------------------------------------ refinement
    def calibrate(self, sample: Sequence, min_tuples: int = 8) -> bool:
        """Profile the real operator functions on ``sample`` source tuples.

        Dry-runs each stage's operator run with throwaway state (operator fns
        are deterministic and side-effect-free by contract, so this is
        invisible to the later real run), measuring per-tuple stage cost and
        selectivity.  Returns True if the sample was large enough to trust.
        """
        if len(sample) < min_tuples:
            return False
        from .procrun import _apply_segment, _init_states  # late: avoid cycle

        values = list(sample)
        for prof, plan in zip(self.profiles, self.plans):
            if not values:
                break
            states = _init_states(plan.ops)
            outs: list = []
            t0 = time.perf_counter()
            for v in values:
                outs.extend(_apply_segment(plan.ops, states, v))
            dt = time.perf_counter() - t0
            prof.cost_us = max(dt * 1e6 / len(values), 1e-3)
            prof.selectivity = len(outs) / len(values)
            prof.measured = True
            values = outs
        self._rechain_flows()
        return True

    def observe(self, index: int, cost_us: float, alpha: float = 0.5) -> None:
        """Fold a live per-worker service-cost observation into stage
        ``index`` (EMA; used by :class:`OccupancyMonitor`)."""
        prof = self.profiles[index]
        if prof.measured:
            prof.cost_us = (1 - alpha) * prof.cost_us + alpha * max(cost_us, 1e-3)
        else:
            prof.cost_us = max(cost_us, 1e-3)
            prof.measured = True

    def observe_flows(self, drained: Sequence[int]) -> None:
        """Update relative flows from the stages' drained-serial counters
        (stage i's serials count its *input* tuples, so the ratios are the
        exact observed flow fractions)."""
        if not drained or drained[0] <= 0:
            return
        base = float(drained[0])
        for prof, d in zip(self.profiles, drained):
            if d > 0:
                prof.flow = max(d / base, 1e-9)

    def _rechain_flows(self) -> None:
        flow = 1.0
        for prof in self.profiles:
            prof.flow = flow
            flow = max(flow * prof.selectivity, 1e-9)

    # ------------------------------------------------------------ allocation
    def loads(self) -> List[float]:
        """Per-stage relative loads (``flow × cost``), allocation's input."""
        return [p.load for p in self.profiles]

    def stage_caps(self) -> List[int]:
        """Per-stage width caps: stateful = 1, keyed = partition count,
        device = its planned width (pinned), stateless = effectively
        unbounded."""
        caps = []
        for plan, prof in zip(self.plans, self.profiles):
            if prof.kind == "stateful":
                caps.append(1)  # intrinsic serial constraint
            elif prof.kind == "keyed":
                caps.append(max(plan.ops[0].num_partitions, 1))
            elif prof.kind == "device":
                # device widths are pinned at plan time (device_workers):
                # batching state lives per worker, so elastic resize would
                # strand half-filled batches.
                caps.append(max(plan.max_workers, 1))
            else:
                caps.append(1 << 30)
        return caps

    def allocate(self, budget: int) -> List[int]:
        """Width vector for ``budget`` total workers (each stage >= 1,
        stateful pinned at 1, keyed capped at its partition count, device
        pinned at its planned width)."""
        mins = [
            max(plan.max_workers, 1) if p.kind == "device" else 1
            for plan, p in zip(self.plans, self.profiles)
        ]
        # stateful stages carry load but cannot widen: exclude their load so
        # the remaining budget divides over the stages that can absorb it.
        # Device stages are likewise pinned (mins == caps), so their load is
        # excluded too.
        loads = [
            0.0 if p.kind in ("stateful", "device") else p.load
            for p in self.profiles
        ]
        return proportional_allocation(loads, budget, mins, self.stage_caps())

    def describe(self) -> str:
        """One-line human rendering of the per-stage profiles."""
        return " ".join(
            f"s{p.index}[{p.kind} cost={p.cost_us:.1f}us flow={p.flow:.2f}"
            f"{' meas' if p.measured else ''}]"
            for p in self.profiles
        )


# --------------------------------------------------------- occupancy monitor
@dataclass
class _Snapshot:
    ts: float
    drained: List[int]  # per-stage drained serials (reorder shared_next - 1)
    backlog: List[int]  # per-stage queued ingress slots


def _refresh_measured_costs(
    model: CostModel,
    prev: _Snapshot,
    snap: _Snapshot,
    widths: Sequence[int],
    min_backlog: int,
) -> None:
    """Fold live drain rates into ``model``: a backlogged stage is
    service-limited, so its drain rate ≈ width / cost; an unsaturated
    stage's drain rate only upper-bounds its cost (it is arrival-limited),
    so it may only lower the estimate."""
    dt = snap.ts - prev.ts
    if dt <= 0:
        return
    for i, width in enumerate(widths):
        dd = snap.drained[i] - prev.drained[i]
        if dd <= 0 or width <= 0:
            continue
        measured = width * dt * 1e6 / dd
        if (
            snap.backlog[i] >= min_backlog
            or measured < model.profiles[i].cost_us
        ):
            model.observe(i, measured)
    model.observe_flows(snap.drained)


class OccupancyMonitor:
    """Watches live stage counters and proposes elastic replans.

    Fed by the process-backend supervisor each ``interval`` seconds with the
    per-stage counters the :class:`~.shm.ExchangeRing` already publishes.
    When one stage holds more than ``occupancy_threshold`` of the queued
    work for ``patience`` consecutive samples, the monitor proposes growing
    it by one worker — funded by spare budget if any, else by shrinking the
    idlest resizable stage (shrink listed first so the supervisor frees the
    budget before spending it).  The one-worker step is deliberate: observed
    occupancy says *which* stage is starved with certainty, but service-cost
    estimates for non-saturated stages are only upper bounds, so stepwise
    rebalancing converges without thrashing on estimation noise.  Live
    service rates still refresh the cost model (for reporting and for the
    next static allocation).
    """

    def __init__(
        self,
        model: CostModel,
        budget: int,
        *,
        interval: float = 0.25,
        occupancy_threshold: float = 0.55,
        min_backlog: int = 8,
        patience: int = 3,
    ):
        self.model = model
        self.budget = budget
        self.interval = interval
        self.occupancy_threshold = occupancy_threshold
        self.min_backlog = min_backlog
        self.patience = patience
        self._prev: Optional[_Snapshot] = None
        self._next_at = 0.0
        # patience accumulates PER STAGE: two stages alternating as the
        # backlog leader each still reach ``patience`` qualifying samples
        # (a single shared streak would reset on every leader change and
        # an oscillating hot spot would never replan).  All streaks clear
        # whenever the pipeline shows no addressable drift at all.
        self._streaks: Dict[int, int] = {}
        self.samples = 0  # instrumentation

    def due(self, now: float) -> bool:
        """Whether the next sampling interval has elapsed."""
        return now >= self._next_at

    def sample(
        self,
        now: float,
        drained: Sequence[int],
        backlog: Sequence[int],
        widths: Sequence[int],
        resizable: Sequence[bool],
    ) -> Optional[List[Tuple[int, int]]]:
        """Feed one counter snapshot; returns ``[(stage, new_width), ...]``
        (shrinks first) when a replan should happen, else None."""
        self._next_at = now + self.interval
        snap = _Snapshot(now, list(drained), list(backlog))
        prev, self._prev = self._prev, snap
        self.samples += 1
        if prev is None:
            return None
        dt = now - prev.ts
        if dt <= 0:
            return None
        _refresh_measured_costs(self.model, prev, snap, widths,
                                self.min_backlog)

        total_backlog = sum(snap.backlog)
        if total_backlog < self.min_backlog:
            self._streaks.clear()
            return None
        hot = max(range(len(widths)), key=lambda i: snap.backlog[i])
        caps = self.model.stage_caps()
        if (
            snap.backlog[hot] / total_backlog < self.occupancy_threshold
            or not resizable[hot]
            or widths[hot] >= caps[hot]
        ):
            # no drift, or drift that is unaddressable (hot stage pinned or
            # already at cap): do not thrash the others
            self._streaks.clear()
            return None
        proposal: List[Tuple[int, int]] = []
        if self.budget - sum(widths) <= 0:
            donors = [
                i for i in range(len(widths))
                if i != hot and resizable[i] and widths[i] > 1
            ]
            if not donors:
                self._streaks.clear()
                return None
            donor = min(donors, key=lambda i: snap.backlog[i])
            proposal.append((donor, widths[donor] - 1))
        proposal.append((hot, widths[hot] + 1))
        self._streaks[hot] = self._streaks.get(hot, 0) + 1
        if self._streaks[hot] < self.patience:
            return None
        self._streaks.clear()
        return proposal


# ----------------------------------------------------------- traffic monitor
@dataclass
class TrafficSnapshot:
    """One serving-tier load observation, as exported by
    :meth:`repro.serve.SessionMux.load_signals`.

    ``admitted_total`` is a monotonic count of tuples the mux admitted into
    the runtime, ``ingress_queued`` the tuples still parked in per-session
    DRR ingress queues (admission pressure the runtime is not absorbing),
    ``backpressured`` the number of sessions paused on a full result
    buffer."""

    ts: float
    sessions: int = 0
    admitted_total: int = 0
    ingress_queued: int = 0
    backpressured: int = 0


class TrafficMonitor:
    """Traffic-aware elasticity policy: grow/shrink proposals keyed on
    *offered load*, not just ring occupancy.

    The :class:`OccupancyMonitor` reacts to stage *skew* — where queued work
    sits.  A multiplexed serving tier (``repro.serve.SessionMux``) also
    needs the plan to react to *traffic*: session fan-out and offered-load
    ramps should widen the sid-partitioned stage, sustained diurnal troughs
    should hand the workers back.  Following BriskStream's rule that scaling
    decisions come from a measured execution model re-evaluated at runtime,
    this policy:

    - ingests serving-tier load snapshots (:meth:`ingest`) and keeps an
      EWMA of the offered source-tuple rate — the admitted-counter delta
      *plus* ingress-queue growth, so load the runtime fails to absorb
      still counts as offered;
    - converts the rate into per-stage utilization against the live
      measured cost model (``util = rate * flow * cost_us / (width * 1e6)``)
      and proposes growing the hottest resizable stage (keyed —
      i.e. sid-partitioned — stages preferred) once utilization exceeds
      ``grow_util`` for ``patience`` consecutive samples, or immediately on
      sustained admission pressure even when the cost model disagrees;
    - proposes shrinking the idlest over-provisioned stage only when its
      utilization sits below ``shrink_util`` *and* would remain below
      ``grow_util`` at the narrower width — the hysteresis band that stops
      grow/shrink oscillation;
    - enforces a ``cooldown`` after every proposal, quadrupled when the
      supervisor reports the resize was aborted or blew its latency budget
      (:meth:`resize_result`), so a resize that stalls the pipeline is not
      immediately retried.

    Streaks accumulate per stage and per direction; all state is touched
    only from the supervisor thread.
    """

    def __init__(
        self,
        model: CostModel,
        budget: int,
        *,
        interval: float = 0.5,
        grow_util: float = 0.85,
        shrink_util: float = 0.30,
        patience: int = 2,
        cooldown: float = 2.0,
        alpha: float = 0.3,
        min_backlog: int = 8,
    ):
        if not (0.0 < shrink_util < grow_util):
            raise ValueError(
                "traffic policy hysteresis requires 0 < shrink_util "
                f"< grow_util, got shrink={shrink_util} grow={grow_util}"
            )
        self.model = model
        self.budget = budget
        self.interval = interval
        self.grow_util = grow_util
        self.shrink_util = shrink_util
        self.patience = max(int(patience), 1)
        self.cooldown = cooldown
        self.alpha = alpha
        self.min_backlog = min_backlog
        self._last: Optional[TrafficSnapshot] = None
        self._rate = 0.0  # EWMA offered source tuples/s
        self._have_rate = False
        self._pressure = 0
        self._sessions = 0
        self._prev: Optional[_Snapshot] = None
        self._next_at = 0.0
        self._cooldown_until = 0.0
        self._grow_streaks: Dict[int, int] = {}
        self._shrink_streaks: Dict[int, int] = {}
        self.ingests = 0  # instrumentation
        self.samples = 0
        self.proposals = 0
        self.backoffs = 0

    @property
    def rate(self) -> float:
        """Current EWMA estimate of the offered source-tuple rate (1/s)."""
        return self._rate

    def ingest(self, signals: Dict[str, float]) -> None:
        """Feed one serving-tier load snapshot (a ``load_signals()`` dict).

        The offered rate between consecutive snapshots is the admitted
        delta plus the ingress-queue growth over the elapsed time; it is
        folded into the EWMA.  Queue depth and session count are kept as
        the admission-pressure signal."""
        snap = TrafficSnapshot(
            ts=float(signals.get("ts", 0.0)),
            sessions=int(signals.get("sessions", 0)),
            admitted_total=int(signals.get("admitted_total", 0)),
            ingress_queued=int(signals.get("ingress_queued", 0)),
            backpressured=int(signals.get("backpressured", 0)),
        )
        prev, self._last = self._last, snap
        self._pressure = snap.ingress_queued
        self._sessions = snap.sessions
        self.ingests += 1
        if prev is None:
            return
        dt = snap.ts - prev.ts
        if dt <= 0:
            return
        offered = max(
            (snap.admitted_total - prev.admitted_total)
            + (snap.ingress_queued - prev.ingress_queued),
            0,
        ) / dt
        if not self._have_rate:
            self._rate, self._have_rate = offered, True
        else:
            self._rate += self.alpha * (offered - self._rate)

    def due(self, now: float) -> bool:
        """Whether the next policy evaluation interval has elapsed."""
        return now >= self._next_at

    def saturated(self) -> bool:
        """Sustained admission pressure: the mux-side ingress queues hold
        more than a couple of tuples per open session, i.e. the runtime is
        not absorbing the offered load regardless of what the cost model
        predicts."""
        return self._pressure >= max(16, 2 * max(self._sessions, 1))

    def utilization(self, widths: Sequence[int]) -> List[float]:
        """Predicted per-stage utilization of the offered rate:
        ``rate * flow_i * cost_us_i / (width_i * 1e6)`` — the fraction of
        stage *i*'s service capacity the measured load consumes."""
        return [
            self._rate * p.flow * p.cost_us / (max(w, 1) * 1e6)
            for p, w in zip(self.model.profiles, widths)
        ]

    def resize_result(
        self,
        now: float,
        *,
        stall_s: Optional[float] = None,
        aborted: bool = False,
        over_budget: bool = False,
    ) -> None:
        """Record the outcome of a resize: a completed one (re)starts the
        normal cooldown; an aborted or over-latency-budget one backs off
        4x, so a resize whose quiesce stall blew the p99 budget is not
        immediately retried.  ``stall_s`` is informational."""
        mult = 4.0 if (aborted or over_budget) else 1.0
        if aborted or over_budget:
            self.backoffs += 1
        self._cooldown_until = max(
            self._cooldown_until, now + mult * self.cooldown
        )

    def sample(
        self,
        now: float,
        drained: Sequence[int],
        backlog: Sequence[int],
        widths: Sequence[int],
        resizable: Sequence[bool],
    ) -> Optional[List[Tuple[int, int]]]:
        """Evaluate the policy against one stage-counter snapshot; returns
        ``[(stage, new_width), ...]`` (shrinks first) or None.  Inert until
        the first two :meth:`ingest` calls establish a rate estimate."""
        self._next_at = now + self.interval
        self.samples += 1
        snap = _Snapshot(now, list(drained), list(backlog))
        prev, self._prev = self._prev, snap
        if prev is not None:
            _refresh_measured_costs(self.model, prev, snap, widths,
                                    self.min_backlog)
        if not self._have_rate:
            return None
        if now < self._cooldown_until:
            return None
        utils = self.utilization(widths)
        caps = self.model.stage_caps()
        saturated = self.saturated()

        # grow path: hottest resizable under-cap stage, keyed preferred —
        # in a mux'd plan the sid-partitioned stage is where fan-out lands.
        grow_cands = [
            i for i in range(len(widths))
            if resizable[i] and widths[i] < caps[i]
        ]
        target = None
        if grow_cands:
            keyed = [
                i for i in grow_cands
                if self.model.profiles[i].kind == "keyed"
            ]
            pool = keyed or grow_cands
            target = max(pool, key=lambda i: (utils[i], snap.backlog[i]))
        if target is not None and (utils[target] > self.grow_util or saturated):
            self._shrink_streaks.clear()
            self._grow_streaks[target] = self._grow_streaks.get(target, 0) + 1
            if self._grow_streaks[target] < self.patience:
                return None
            proposal: List[Tuple[int, int]] = []
            if self.budget - sum(widths) <= 0:
                donors = [
                    i for i in range(len(widths))
                    if i != target and resizable[i] and widths[i] > 1
                ]
                if not donors:
                    self._grow_streaks.pop(target, None)
                    return None
                donor = min(donors, key=lambda i: utils[i])
                proposal.append((donor, widths[donor] - 1))
            proposal.append((target, widths[target] + 1))
            self._grow_streaks.clear()
            self._cooldown_until = now + self.cooldown
            self.proposals += 1
            return proposal
        self._grow_streaks.clear()

        # shrink path: sustained trough only — idle utilization below the
        # shrink threshold AND still below grow_util at the narrower width
        # (hysteresis), with no queued pressure anywhere near the stage.
        if saturated:
            self._shrink_streaks.clear()
            return None
        victim = None
        for i in sorted(range(len(widths)), key=lambda i: utils[i]):
            if not resizable[i] or widths[i] <= 1:
                continue
            if snap.backlog[i] >= self.min_backlog:
                continue
            if (
                utils[i] < self.shrink_util
                and utils[i] * widths[i] / (widths[i] - 1) < self.grow_util
            ):
                victim = i
                break
        if victim is None:
            self._shrink_streaks.clear()
            return None
        self._shrink_streaks[victim] = self._shrink_streaks.get(victim, 0) + 1
        if self._shrink_streaks[victim] < self.patience:
            return None
        self._shrink_streaks.clear()
        self._cooldown_until = now + self.cooldown
        self.proposals += 1
        return [(victim, widths[victim] - 1)]
