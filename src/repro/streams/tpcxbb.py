"""TPCx-BB streaming queries Q1-Q4, Q15 (paper §7 table 1) as pipelines.

Pipeline structures follow table 1 exactly (SL = stateless, PS = partitioned
stateful, SF = stateful):
  Q1 : SS -> SL -> PS -> PS -> SF   items sold together hourly top-100
  Q2 : WC -> SL -> PS -> SL -> PS -> SF  viewed-together (60-min sessions)
  Q3 : WC -> SL -> PS -> PS         last-5 views before purchase (10 days)
  Q4 : WC -> SL -> PS -> SL -> SF   cart abandonment: avg pages per session
  Q15: SS -> SL -> SL -> PS         categories w/ flat or declining sales

Each builder returns (specs, source_iterator). Specs carry per-op cost/
selectivity priors used by the scheduler and the discrete-event simulator.

DAG forms (``q1_dag``/``q4_dag``/``q15_dag``, registry ``DAG_QUERIES``)
restructure a query's partitioned hot spot as a genuine dataflow DAG:
a keyed ``Split`` fans tuples across B parallel copies of the partitioned
operator (same key routes to the same branch, so per-key state is preserved)
and a ``Merge`` re-interleaves the branch outputs in split-ingress order —
egress is identical to the linear form, but the hot operator's exposed
parallelism is B-fold. Builders return (nodes, edges, source_iterator) for
:class:`repro.core.GraphPipeline`.
"""
from __future__ import annotations

import itertools
from typing import Iterable

from repro.core import Merge, OpSpec, Split

from . import sources

SESSION_TIMEOUT = 3600.0  # 60 min
HOUR = 3600.0


# ----------------------------------------------------------------------- Q1
def q1(n: int = 20000, seed: int = 0):
    def project(sale):  # SL
        return [(sale.store, sale.basket, sale.item, sale.ts)]

    def basket_pairs(state, key, t):  # PS by basket
        store, basket, item, ts = t
        items = state or []
        outs = [((min(item, i2), max(item, i2)), ts) for i2 in items if i2 != item]
        return items + [item], outs

    def pair_count(state, key, t):  # PS by pair
        pair, ts = t
        c = (state or 0) + 1
        return c, [(pair, c, ts)]

    def hourly_top100(state, t):  # SF
        pair, c, ts = t
        top, hour = state if state else ({}, 0)
        top[pair] = c
        out = []
        if ts // HOUR > hour:
            hour = ts // HOUR
            ranked = sorted(top.items(), key=lambda kv: -kv[1])[:100]
            out = [("top100", hour, ranked)]
        return (top, hour), out

    specs = [
        OpSpec("project", "stateless", project, cost_us=4, selectivity=1.0),
        OpSpec(
            "basket_pairs", "partitioned", basket_pairs,
            key_fn=lambda t: t[1], num_partitions=64,
            init_state=lambda: None, cost_us=6, selectivity=1.2,
        ),
        OpSpec(
            "pair_count", "partitioned", pair_count,
            key_fn=lambda t: t[0], num_partitions=128,
            init_state=lambda: 0, cost_us=5, selectivity=1.0,
        ),
        OpSpec("hourly_top100", "stateful", hourly_top100, init_state=lambda: None,
               cost_us=8, selectivity=0.01),
    ]
    return specs, sources.store_sales(n, seed=seed, dt_s=6.0)  # ~hours span


# ----------------------------------------------------------------------- Q2
def q2(n: int = 20000, seed: int = 0):
    def views(c):  # SL: keep views only
        return [(c.user, c.item, c.ts)] if c.action == "view" else []

    def sessionize(state, key, t):  # PS by user: emit co-viewed pairs per session
        user, item, ts = t
        sess = state or {"items": [], "last": ts}
        outs = []
        if ts - sess["last"] > SESSION_TIMEOUT and sess["items"]:
            items = sorted(set(sess["items"]))
            outs = [(a, b) for i, a in enumerate(items) for b in items[i + 1 :]]
            sess = {"items": [], "last": ts}
        sess["items"].append(item)
        sess["last"] = ts
        return sess, outs

    def norm_pair(p):  # SL
        return [p]

    def pair_count(state, key, p):  # PS by pair
        c = (state or 0) + 1
        return c, [(p, c)]

    def top30(state, t):  # SF
        pair, c = t
        top, n_in = state if state else ({}, 0)
        top[pair] = c
        n_in += 1
        ranked = sorted(top.items(), key=lambda kv: -kv[1])[:30]
        return (top, n_in), [ranked] if n_in % 50 == 0 else []

    specs = [
        OpSpec("views", "stateless", views, cost_us=3, selectivity=0.86),
        OpSpec(
            "sessionize", "partitioned", sessionize,
            key_fn=lambda t: t[0], num_partitions=128,
            init_state=lambda: None, cost_us=8, selectivity=0.6,
        ),
        OpSpec("norm_pair", "stateless", norm_pair, cost_us=2, selectivity=1.0),
        OpSpec(
            "pair_count", "partitioned", pair_count,
            key_fn=lambda p: p, num_partitions=128,
            init_state=lambda: 0, cost_us=5, selectivity=1.0,
        ),
        OpSpec("top30", "stateful", top30, init_state=lambda: None,
               cost_us=10, selectivity=0.02),
    ]
    return specs, sources.clickstream(n, seed=seed, dt_s=4.0)  # sessions can time out


# ----------------------------------------------------------------------- Q3
def q3(n: int = 20000, seed: int = 0):
    TEN_DAYS = 10 * 24 * 3600.0

    def project(c):  # SL
        return [(c.user, c.item, c.action, c.ts)]

    def last5_before_purchase(state, key, t):  # PS by user
        user, item, action, ts = t
        hist = [(i, s) for (i, s) in (state or []) if ts - s < TEN_DAYS][-5:]
        outs = []
        if action == "purchase":
            outs = [(item, viewed) for (viewed, _) in hist]
        elif action == "view":
            hist = hist + [(item, ts)]
        return hist, outs

    def view_count(state, key, t):  # PS by viewed item
        purchased, viewed = t
        c = (state or 0) + 1
        return c, [(viewed, c)]

    specs = [
        OpSpec("project", "stateless", project, cost_us=3, selectivity=1.0),
        OpSpec(
            "last5", "partitioned", last5_before_purchase,
            key_fn=lambda t: t[0], num_partitions=128,
            init_state=lambda: None, cost_us=7, selectivity=0.3,
        ),
        OpSpec(
            "view_count", "partitioned", view_count,
            key_fn=lambda t: t[1], num_partitions=128,
            init_state=lambda: 0, cost_us=4, selectivity=1.0,
        ),
    ]
    return specs, sources.clickstream(n, seed=seed)


# ----------------------------------------------------------------------- Q4
def q4(n: int = 20000, seed: int = 0):
    def project(c):  # SL
        return [(c.user, c.action, c.ts)]

    def abandoned_sessions(state, key, t):  # PS by user
        user, action, ts = t
        sess = state or {"pages": 0, "cart": False, "bought": False, "last": ts}
        outs = []
        if ts - sess["last"] > SESSION_TIMEOUT and sess["pages"]:
            if sess["cart"] and not sess["bought"]:
                outs = [(user, sess["pages"])]
            sess = {"pages": 0, "cart": False, "bought": False, "last": ts}
        sess["pages"] += 1
        sess["cart"] |= action == "add2cart"
        sess["bought"] |= action == "purchase"
        sess["last"] = ts
        return sess, outs

    def pages(t):  # SL
        return [t[1]]

    def running_avg(state, pages_n):  # SF
        total, count = state if state else (0, 0)
        total, count = total + pages_n, count + 1
        return (total, count), [total / count]

    specs = [
        OpSpec("project", "stateless", project, cost_us=3, selectivity=1.0),
        OpSpec(
            "abandoned", "partitioned", abandoned_sessions,
            key_fn=lambda t: t[0], num_partitions=128,
            init_state=lambda: None, cost_us=7, selectivity=0.05,
        ),
        OpSpec("pages", "stateless", pages, cost_us=2, selectivity=1.0),
        OpSpec("running_avg", "stateful", running_avg, init_state=lambda: None,
               cost_us=3, selectivity=1.0),
    ]
    return specs, sources.clickstream(n, seed=seed, dt_s=4.0)


# ----------------------------------------------------------------------- Q15
def q15(n: int = 20000, seed: int = 0):
    WEEK = 7 * 24 * 3600.0

    def in_store(s):  # SL: filter to interesting stores
        return [s] if s.store < 10 else []

    def project(s):  # SL
        return [(s.category, s.ts // WEEK, s.qty * s.price)]

    def slope(state, key, t):  # PS by category: regression over weekly sums
        cat, week, amount = t
        weeks = state or {}
        weeks[week] = weeks.get(week, 0.0) + amount
        out = []
        if len(weeks) >= 3:
            xs = sorted(weeks)
            ys = [weeks[x] for x in xs]
            n_ = len(xs)
            mx = sum(xs) / n_
            my = sum(ys) / n_
            denom = sum((x - mx) ** 2 for x in xs) or 1.0
            b = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / denom
            if b <= 0:
                out = [(cat, b)]
        return weeks, out

    specs = [
        OpSpec("in_store", "stateless", in_store, cost_us=2, selectivity=0.5),
        OpSpec("project", "stateless", project, cost_us=3, selectivity=1.0),
        OpSpec(
            "slope", "partitioned", slope,
            key_fn=lambda t: t[0], num_partitions=10,  # 10 categories (paper)
            init_state=lambda: None, cost_us=9, selectivity=0.4,
        ),
    ]
    return specs, sources.store_sales(n, seed=seed, dt_s=400.0)  # spans weeks


QUERIES = {"q1": q1, "q2": q2, "q3": q3, "q4": q4, "q15": q15}


def run_query(
    name: str,
    n: int = 20000,
    *,
    seed: int = 0,
    backend: str = "thread",
    num_workers=4,  # int, or "auto" for cost-model worker allocation
    batch_size: int = 1,
    heuristic: str = "ct",
    cost_priors=None,
    **kw,
):
    """One-shot runner with backend plumb-through: compile query ``name`` and
    run it on the chosen execution backend.  ``thread`` honors ``heuristic``
    and ``batch_size``; ``process`` cuts the query into staged process worker
    groups at its partitioned/stateful boundaries (e.g. Q1's SL|PS|PS|SF
    becomes four stages) — pass ``stages=1`` via ``**kw`` for the ingress-only
    plan, ``io_batch``/``max_inflight`` for exchange tuning.

    ``num_workers="auto"`` sizes each stage's worker group from the query's
    declared per-op cost/selectivity priors (table 1 carries them on every
    ``OpSpec``) via :mod:`repro.core.costmodel` — the skew-aware allocation
    a hot ``sessionize``/``basket_pairs`` stage wants; ``cost_priors=``
    ``{op name: cost_us}`` overrides the declared numbers.

    Runs natively on the :class:`repro.core.Engine` surface (``**kw`` is
    parsed strictly by :meth:`repro.core.EngineConfig.from_kwargs`, so typos
    raise :class:`repro.core.ConfigError`); returns ``(handle, RunReport)``
    where ``handle`` exposes the uniform result surface (``outputs``,
    ``egress_count``, ``markers``) plus backend introspection pass-through.
    For plan inspection without running, build the engine yourself::

        engine = Engine(EngineConfig.from_kwargs(backend="process",
                                                 num_workers="auto"))
        print(engine.plan(QUERIES["q2"](n=1)[0]).explain())
    """
    from repro.core import Engine, EngineConfig

    specs, src = QUERIES[name](n=n, seed=seed)
    engine = Engine(EngineConfig.from_kwargs(
        backend=backend,
        num_workers=num_workers,
        batch_size=batch_size,
        heuristic=heuristic,
        cost_priors=cost_priors,
        **kw,
    ))
    result = engine.run(specs, src)
    return result.handle(), result.report


# ------------------------------------------------------------------ DAG forms
def q1_dag(n: int = 20000, seed: int = 0, branches: int = 2):
    """Q1 as a DAG: the basket_pairs hot spot runs on ``branches`` parallel
    keyed branches (split by basket -> per-key state stays consistent)."""
    specs, src = q1(n=n, seed=seed)
    project, basket_pairs, pair_count, hourly_top100 = specs
    nodes = {"project": project, "split": Split("keyed", key_fn=lambda t: t[1])}
    edges = [("project", "split")]
    for b in range(branches):
        nodes[f"pairs{b}"] = basket_pairs
        edges += [("split", f"pairs{b}"), (f"pairs{b}", "merge")]
    nodes["merge"] = Merge()
    nodes["pair_count"] = pair_count
    nodes["top100"] = hourly_top100
    edges += [("merge", "pair_count"), ("pair_count", "top100")]
    return nodes, edges, src


def q4_dag(n: int = 20000, seed: int = 0, branches: int = 2):
    """Q4 as a DAG: whole sessionize->pages sub-chains run per branch (split
    keyed by user), merged back in arrival order before the running average."""
    specs, src = q4(n=n, seed=seed)
    project, abandoned, pages, running_avg = specs
    nodes = {"project": project, "split": Split("keyed", key_fn=lambda t: t[0])}
    edges = [("project", "split")]
    for b in range(branches):
        nodes[f"abandoned{b}"] = abandoned
        nodes[f"pages{b}"] = pages
        edges += [
            ("split", f"abandoned{b}"),
            (f"abandoned{b}", f"pages{b}"),
            (f"pages{b}", "merge"),
        ]
    nodes["merge"] = Merge()
    nodes["avg"] = running_avg
    edges += [("merge", "avg")]
    return nodes, edges, src


def q15_dag(n: int = 20000, seed: int = 0, branches: int = 2):
    """Q15 as a DAG: regression slopes computed on parallel keyed branches;
    the merge is the egress node (ordered fan-in straight to the collector)."""
    specs, src = q15(n=n, seed=seed)
    in_store, project, slope = specs
    nodes = {
        "in_store": in_store,
        "project": project,
        "split": Split("keyed", key_fn=lambda t: t[0]),
        "merge": Merge(),
    }
    edges = [("in_store", "project"), ("project", "split")]
    for b in range(branches):
        nodes[f"slope{b}"] = slope
        edges += [("split", f"slope{b}"), (f"slope{b}", "merge")]
    return nodes, edges, src


DAG_QUERIES = {"q1": q1_dag, "q4": q4_dag, "q15": q15_dag}


def sim_ops(query: str):
    """SimOp list mirroring a query's cost/selectivity profile (fig. 8 sims)."""
    from repro.core.simulate import SimOp

    specs, _src = QUERIES[query](n=1)
    out = []
    for s in specs:
        out.append(
            SimOp(
                name=s.name,
                kind=s.kind,
                cost_us=s.cost_us,
                selectivity=s.selectivity,
                num_partitions=s.num_partitions,
            )
        )
    return out
