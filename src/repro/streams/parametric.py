"""Parametric operators (paper §7): stateless / partitioned-stateful operators
with tunable per-tuple processing cost (matrix work), selectivity, and state
size — used by the thread-runtime micro-benchmarks and tests.
"""
from __future__ import annotations

import numpy as np

from repro.core import OpSpec


def _work(n: int, seed_mat: np.ndarray) -> float:
    # ~n^3 flops of real compute per tuple
    return float((seed_mat @ seed_mat).sum())


def stateless_parametric(
    name: str = "param_sl",
    matrix_n: int = 8,
    selectivity: float = 1.0,
    cost_us: float | None = None,
) -> OpSpec:
    m = np.random.RandomState(0).randn(matrix_n, matrix_n).astype(np.float32)
    acc = [0.0]

    def fn(v):
        _work(matrix_n, m)
        base = int(selectivity)
        acc[0] += selectivity - base
        if acc[0] >= 1.0:
            acc[0] -= 1.0
            base += 1
        return [v] * base

    return OpSpec(
        name, "stateless", fn,
        cost_us=cost_us or (matrix_n ** 3) * 2e-3,
        selectivity=selectivity,
    )


def cpu_bound_stateless(
    name: str = "cpu_sl",
    spin: int = 100,
    selectivity: float = 1.0,
) -> OpSpec:
    """Pure-Python (GIL-bound) compute operator — the fig. 8 CPU-bound
    synthetic profile.  Unlike the numpy variants, none of the per-tuple work
    releases the GIL, so the threaded runtime is pinned to ~1 core and the
    process backend's scaling is measured against an honest baseline.
    ``spin`` iterations ≈ ``spin * 0.08`` µs of interpreter work per tuple.
    """
    period = None
    if selectivity < 1.0:
        period = max(int(round(1.0 / (1.0 - selectivity))), 2)

    def fn(v):
        x = float(v) if not isinstance(v, float) else v
        for _ in range(spin):
            x = (x * 1.0000001 + 1.31) % 97.0
        if period is not None and int(v) % period == 0:
            return []  # deterministic filter: same drop set on every backend
        return [x]

    return OpSpec(
        name, "stateless", fn, cost_us=spin * 0.08, selectivity=selectivity
    )


def cpu_bound_chain(
    stages: int = 3, spin: int = 100, selectivity: float = 1.0
) -> list[OpSpec]:
    """Fig. 8-style CPU-bound synthetic query: a chain of pure-Python compute
    stages (used by ``benchmarks/bench_core.py`` and the fig. 8 backend
    comparison)."""
    return [
        cpu_bound_stateless(f"cpu{i}", spin=spin,
                            selectivity=selectivity if i == 0 else 1.0)
        for i in range(stages)
    ]


def cpu_bound_partitioned(
    name: str = "cpu_ps",
    spin: int = 100,
    num_partitions: int = 64,
    key_mod: int = 64,
) -> OpSpec:
    """Pure-Python (GIL-bound) partitioned-stateful compute operator: per-key
    counter state plus ``spin`` iterations of interpreter work per tuple.
    Deterministic, so it is legal on every backend (incl. crash replay)."""

    def fn(state, key, v):
        x = float(v)
        for _ in range(spin):
            x = (x * 1.0000001 + 1.31) % 97.0
        return (state or 0) + 1, [x]

    return OpSpec(
        name, "partitioned", fn,
        key_fn=lambda v: int(v) % key_mod,
        num_partitions=num_partitions,
        init_state=lambda: 0,
        cost_us=spin * 0.08,
        selectivity=1.0,
    )


def keyed_hotspot_chain(
    spin_edge: int = 30, spin_hot: int = 400, num_partitions: int = 64
) -> list[OpSpec]:
    """SL → PS(hot) → SL: a cheap stateless rim around an interior keyed
    compute hot spot.  The configuration the ingress-only process plan cannot
    parallelize (the hot operator lands in the serial parent tail) but the
    staged plan can (the keyed stage gets its own worker group) — the
    tentpole benchmark workload of ``benchmarks/bench_core.py``."""
    return [
        cpu_bound_stateless("pre", spin=spin_edge),
        cpu_bound_partitioned("hot", spin=spin_hot,
                              num_partitions=num_partitions),
        cpu_bound_stateless("post", spin=spin_edge),
    ]


def skewed_stage_chain(
    spin_hot: int = 10000, spin_cold: int = 30, num_partitions: int = 64
) -> list[OpSpec]:
    """SL(hot) → PS(cold): a deliberately *skewed* staged pipeline — the
    leading stateless stage carries ``spin_hot/spin_cold``× the work of the
    keyed stage behind it.  A flat per-stage worker count starves the hot
    stage (the even split of a small core budget leaves it one worker),
    which is exactly the load-imbalance failure mode the paper's scaling
    argument targets; cost-model allocation (``workers="auto"``)
    concentrates the budget on it instead.  The ``auto_vs_flat_process``
    benchmark workload (``benchmarks/bench_core.py``)."""
    return [
        cpu_bound_stateless("hot", spin=spin_hot),
        cpu_bound_partitioned("cold", spin=spin_cold,
                              num_partitions=num_partitions),
    ]


def partitioned_parametric(
    name: str = "param_ps",
    matrix_n: int = 8,
    state_n: int = 16,
    num_partitions: int = 64,
    cost_us: float | None = None,
) -> OpSpec:
    m = np.random.RandomState(1).randn(matrix_n, matrix_n).astype(np.float32)

    def fn(state, key, v):
        if state is None:
            state = np.zeros((state_n,), np.float32)
        _work(matrix_n, m)
        state = state + 1.0
        return state, [(key, float(state[0]))]

    return OpSpec(
        name, "partitioned", fn,
        key_fn=lambda v: hash(v),
        num_partitions=num_partitions,
        init_state=lambda: None,
        cost_us=cost_us or (matrix_n ** 3) * 2e-3,
        selectivity=1.0,
    )
