"""Parametric operators (paper §7): stateless / partitioned-stateful operators
with tunable per-tuple processing cost (matrix work), selectivity, and state
size — used by the thread-runtime micro-benchmarks and tests.
"""
from __future__ import annotations

import numpy as np

from repro.core import OpSpec


def _work(n: int, seed_mat: np.ndarray) -> float:
    # ~n^3 flops of real compute per tuple
    return float((seed_mat @ seed_mat).sum())


def stateless_parametric(
    name: str = "param_sl",
    matrix_n: int = 8,
    selectivity: float = 1.0,
    cost_us: float | None = None,
) -> OpSpec:
    m = np.random.RandomState(0).randn(matrix_n, matrix_n).astype(np.float32)
    acc = [0.0]

    def fn(v):
        _work(matrix_n, m)
        base = int(selectivity)
        acc[0] += selectivity - base
        if acc[0] >= 1.0:
            acc[0] -= 1.0
            base += 1
        return [v] * base

    return OpSpec(
        name, "stateless", fn,
        cost_us=cost_us or (matrix_n ** 3) * 2e-3,
        selectivity=selectivity,
    )


def cpu_bound_stateless(
    name: str = "cpu_sl",
    spin: int = 100,
    selectivity: float = 1.0,
) -> OpSpec:
    """Pure-Python (GIL-bound) compute operator — the fig. 8 CPU-bound
    synthetic profile.  Unlike the numpy variants, none of the per-tuple work
    releases the GIL, so the threaded runtime is pinned to ~1 core and the
    process backend's scaling is measured against an honest baseline.
    ``spin`` iterations ≈ ``spin * 0.08`` µs of interpreter work per tuple.
    """
    period = None
    if selectivity < 1.0:
        period = max(int(round(1.0 / (1.0 - selectivity))), 2)

    def fn(v):
        x = float(v) if not isinstance(v, float) else v
        for _ in range(spin):
            x = (x * 1.0000001 + 1.31) % 97.0
        if period is not None and int(v) % period == 0:
            return []  # deterministic filter: same drop set on every backend
        return [x]

    return OpSpec(
        name, "stateless", fn, cost_us=spin * 0.08, selectivity=selectivity
    )


def cpu_bound_chain(
    stages: int = 3, spin: int = 100, selectivity: float = 1.0
) -> list[OpSpec]:
    """Fig. 8-style CPU-bound synthetic query: a chain of pure-Python compute
    stages (used by ``benchmarks/bench_core.py`` and the fig. 8 backend
    comparison)."""
    return [
        cpu_bound_stateless(f"cpu{i}", spin=spin,
                            selectivity=selectivity if i == 0 else 1.0)
        for i in range(stages)
    ]


def partitioned_parametric(
    name: str = "param_ps",
    matrix_n: int = 8,
    state_n: int = 16,
    num_partitions: int = 64,
    cost_us: float | None = None,
) -> OpSpec:
    m = np.random.RandomState(1).randn(matrix_n, matrix_n).astype(np.float32)

    def fn(state, key, v):
        if state is None:
            state = np.zeros((state_n,), np.float32)
        _work(matrix_n, m)
        state = state + 1.0
        return state, [(key, float(state[0]))]

    return OpSpec(
        name, "partitioned", fn,
        key_fn=lambda v: hash(v),
        num_partitions=num_partitions,
        init_state=lambda: None,
        cost_us=cost_us or (matrix_n ** 3) * 2e-3,
        selectivity=1.0,
    )
