"""Synthetic stream sources (paper §7 benchmark inputs).

- web clickstreams: (ts, user, item, category, action) with zipf-ish item
  popularity and session structure (action in view/add2cart/purchase)
- store sales: (ts, store, basket, item, category, qty, price)
- call-data records (fig. 1 example): (ts, caller, callee, duration, cell)

All generators are deterministic given a seed.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Iterator


@dataclass(frozen=True)
class Click:
    ts: float
    user: int
    item: int
    category: int
    action: str  # view | add2cart | purchase


@dataclass(frozen=True)
class Sale:
    ts: float
    store: int
    basket: int
    item: int
    category: int
    qty: int
    price: float


@dataclass(frozen=True)
class CDR:
    ts: float
    caller: int
    callee: int
    duration: float
    cell: int  # tower/cell id -> location proxy
    area_code: int


def _zipf_item(rng: random.Random, n_items: int, skew: float = 1.2) -> int:
    # inverse-cdf-ish cheap zipf
    u = rng.random()
    return min(int(n_items * (u ** skew)), n_items - 1)


def clickstream(
    n: int,
    *,
    n_users: int = 500,
    n_items: int = 1000,
    n_categories: int = 24,
    seed: int = 0,
    dt_s: float = 0.05,
) -> Iterator[Click]:
    rng = random.Random(seed)
    ts = 0.0
    carts: dict[int, list[int]] = {}
    for _ in range(n):
        ts += rng.expovariate(1.0 / dt_s)
        user = rng.randrange(n_users)
        item = _zipf_item(rng, n_items)
        r = rng.random()
        if r < 0.86:
            action = "view"
        elif r < 0.95:
            action = "add2cart"
            carts.setdefault(user, []).append(item)
        else:
            action = "purchase"
        yield Click(ts, user, item, item % n_categories, action)


def store_sales(
    n: int,
    *,
    n_stores: int = 20,
    n_items: int = 500,
    n_categories: int = 10,
    basket_size: int = 4,
    seed: int = 0,
    dt_s: float = 0.02,
) -> Iterator[Sale]:
    rng = random.Random(seed)
    ts = 0.0
    basket_id = 0
    emitted = 0
    while emitted < n:
        basket_id += 1
        store = rng.randrange(n_stores)
        k = 1 + rng.randrange(basket_size)
        for _ in range(min(k, n - emitted)):
            ts += rng.expovariate(1.0 / dt_s)
            item = _zipf_item(rng, n_items)
            yield Sale(
                ts, store, basket_id, item, item % n_categories,
                1 + rng.randrange(3), round(rng.uniform(1, 100), 2),
            )
            emitted += 1


def cdr_stream(
    n: int,
    *,
    n_phones: int = 2000,
    n_cells: int = 64,
    seed: int = 0,
    dt_s: float = 0.01,
    fraud_fraction: float = 0.01,
) -> Iterator[CDR]:
    """High-mobility fraud workload: a small fraction of phones 'teleport'
    between distant cells (paper fig. 1)."""
    rng = random.Random(seed)
    ts = 0.0
    location: dict[int, int] = {}
    fraudsters = set(rng.sample(range(n_phones), max(1, int(n_phones * fraud_fraction))))
    for _ in range(n):
        ts += rng.expovariate(1.0 / dt_s)
        caller = rng.randrange(n_phones)
        prev = location.get(caller, rng.randrange(n_cells))
        if caller in fraudsters:
            cell = rng.randrange(n_cells)  # jumps anywhere
        else:
            cell = max(0, min(n_cells - 1, prev + rng.choice([-1, 0, 0, 1])))
        location[caller] = cell
        yield CDR(
            ts,
            caller,
            rng.randrange(n_phones),
            rng.uniform(5, 600),
            cell,
            408 if rng.random() < 0.7 else 650,
        )
