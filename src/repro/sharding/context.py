"""Current-mesh context for in-model sharding constraints.

Model code calls ``shard(x, "dp", None, "tp", None)`` with *logical* dims;
this resolves them against the active mesh ("dp" -> ('pod','data') when the
pod axis exists, "tp" -> 'model') and no-ops when no mesh is set (CPU smoke
tests) or when the dim size does not divide the axis.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_CURRENT: list[Optional[Mesh]] = [None]


def set_mesh(mesh: Optional[Mesh]) -> None:
    _CURRENT[0] = mesh


def get_mesh() -> Optional[Mesh]:
    return _CURRENT[0]


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = _CURRENT[0]
    _CURRENT[0] = mesh
    try:
        yield
    finally:
        _CURRENT[0] = prev


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def shard(x: jax.Array, *dims) -> jax.Array:
    """Constrain ``x`` with logical dims: "dp" | "tp" | None per axis."""
    mesh = _CURRENT[0]
    if mesh is None:
        return x
    spec = []
    for size, d in zip(x.shape, dims):
        if d == "dp":
            axes = ("pod", "data") if "pod" in mesh.axis_names else "data"
        elif d == "tp":
            axes = "model"
        else:
            axes = None
        if axes is not None and size % _axes_size(mesh, axes) != 0:
            axes = None  # non-divisible: leave to the compiler
        spec.append(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
