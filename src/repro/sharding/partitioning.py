"""Sharding rules: batch/cache/activation PartitionSpecs for the production
meshes (see launch/mesh.py). Param specs live with the param definitions in
models/common.py; this module holds everything shape-dependent.

Conventions (DESIGN.md §5):
- batch dims shard over DP axes ('pod','data') when divisible, else replicate
- KV caches shard batch over DP and the *sequence* dim over 'model'
  (flash-decoding style: XLA turns softmax/contraction over the sharded seq
  dim into small cross-shard reductions; exact memory scaling for any #heads)
- mamba caches shard batch over DP and heads over 'model'
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig


def dp_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    size = 1
    for a in dp_axes(mesh):
        size *= mesh.shape[a]
    return size


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """(B, ...) arrays: shard B over DP axes when divisible."""
    if batch % dp_size(mesh) == 0:
        return P(dp_axes(mesh), *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def cache_slice_pspecs(
    cfg: ModelConfig, mesh: Mesh, batch: int, mode: str = "decode"
) -> dict:
    """Per-period cache slice specs (no leading scan dim).

    mode="decode": attn KV shards its *seq* dim over 'model' (flash-decoding
    style; exact memory scaling for any #kv-heads).
    mode="prefill": attn KV shards *heads* over 'model' — matches how prefill
    naturally produces KV (head-sharded from TP attention), avoiding an SPMD
    involuntary full remat; the serving engine re-shards once at the
    prefill->decode hand-off (as disaggregated serving systems do).
    """
    bspec = dp_axes(mesh) if batch % dp_size(mesh) == 0 else None
    slices: dict[str, dict] = {}
    for si, (mixer, _ffn) in enumerate(cfg.pattern):
        if mixer == "attn":
            if mode == "decode":
                spec = P(bspec, None, "model", None)  # (B,Hkv,S,Dh): S over tp
            else:
                # prefill emits head-dim-sharded KV (Dh always divides the TP
                # axis; head counts often don't) — the serving engine
                # re-shards once at the prefill->decode hand-off.
                spec = P(bspec, None, None, "model")
            slices[str(si)] = {"k": spec, "v": spec}
            if cfg.kv_quant:
                sspec = P(bspec, None, "model")  # scales: (B,Hkv,S)
                slices[str(si)].update({"k_scale": sspec, "v_scale": sspec})
        elif mixer == "xattn":
            spec = P(bspec, None, None, "model")  # enc KV: head-dim over tp
            slices[str(si)] = {"ek": spec, "ev": spec}
        elif mixer == "mamba":
            slices[str(si)] = {
                # (B, H, Pdim, N): heads over model
                "ssm": P(bspec, "model", None, None),
                # (B, K-1, conv_dim): channels over model
                "conv": P(bspec, None, "model"),
            }
    return slices


def cache_pspecs(
    cfg: ModelConfig, mesh: Mesh, batch: int, mode: str = "decode"
) -> dict:
    """Full-cache specs: slice specs with the leading num_periods scan dim."""
    return jax.tree.map(
        lambda s: P(None, *s),
        cache_slice_pspecs(cfg, mesh, batch, mode),
        is_leaf=lambda x: isinstance(x, P),
    )


def named(mesh: Mesh, tree: Any) -> Any:
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _axes_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize_spec(mesh: Mesh, spec: P, shape: tuple) -> P:
    """Drop sharding on dims the mesh axis size does not divide (explicit
    in/out shardings must divide exactly; e.g. mamba2's vocab=50280 on a
    16-way axis, or 8 kv-heads on 'model')."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axes in zip(shape, entries):
        if axes is not None and dim % _axes_size(mesh, axes) != 0:
            axes = None
        out.append(axes)
    return P(*out)


def named_sanitized(mesh: Mesh, pspec_tree: Any, abstract_tree: Any) -> Any:
    """Like ``named`` but validates divisibility against the abstract shapes."""
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, sanitize_spec(mesh, s, a.shape)),
        pspec_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def constrain_batch(mesh: Mesh, x: jax.Array) -> jax.Array:
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, batch_spec(mesh, x.shape[0], x.ndim - 1))
    )
