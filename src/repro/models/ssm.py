"""Mamba2 mixer: SSD (state-space duality) chunked scan [arXiv:2405.21060].

Train/prefill use the chunked SSD algorithm (intra-chunk quadratic term +
inter-chunk state recurrence via jax.lax.scan / associative ops); decode is the
O(1)-state recurrent update. ``kernels/ssd`` provides the Pallas version of the
chunk kernel; this module is the XLA-native path and the oracle's substrate.

Shapes (G=1 group): x:(B,L,H,P) dt:(B,L,H) A:(H,) B,C:(B,L,N)
State: (B,H,P,N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.context import shard

from .common import ModelConfig, apply_norm, inner_norm


def segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] for j<i,
    -inf above diagonal (the 1-SS 'attention' log-decay matrix)."""
    L = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P) fp32
    dt: jax.Array,  # (B, L, H) fp32, post-softplus
    A: jax.Array,  # (H,) fp32, negative
    Bm: jax.Array,  # (B, L, N) fp32
    Cm: jax.Array,  # (B, L, N) fp32
    chunk: int = 256,
    h0: jax.Array | None = None,  # (B, H, P, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Returns (y: (B,L,H,P), final_state: (B,H,P,N))."""
    B_, L, H, P = x.shape
    N = Bm.shape[-1]
    nc = L // chunk
    assert nc * chunk == L, "seq len must be a multiple of chunk"

    xc = x.reshape(B_, nc, chunk, H, P)
    dtc = dt.reshape(B_, nc, chunk, H)
    Bc = Bm.reshape(B_, nc, chunk, N)
    Cc = Cm.reshape(B_, nc, chunk, N)

    dA = dtc * A[None, None, None, :]  # (B,nc,cl,H)
    dA_cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay

    # NOTE: every contraction below is a 2-operand einsum with elementwise
    # scalings pre-fused — multi-operand einsums let XLA pick contraction
    # orders with huge intermediates (observed: (b,c,k,n,h)-shaped 25 GB
    # temporaries on the 780m config).
    xdt = xc * dtc[..., None]  # (B,nc,cl,H,P)

    # ---- intra-chunk (quadratic, the "attention-like" term)
    Ldec = jnp.exp(segsum(dA.transpose(0, 1, 3, 2)))  # (B,nc,H,cl,cl)
    Ldec = shard(Ldec, "dp", None, "tp", None, None)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)  # (B,nc,cl,cl)
    M = Ldec * scores[:, :, None]  # (B,nc,H,cl,cl)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M, xdt)

    # ---- chunk summaries: state contributed by each chunk
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (B,nc,cl,H)
    S = jnp.einsum("bckn,bckhp->bchpn", Bc, xdt * decay_to_end[..., None])

    # ---- inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])  # (B,nc,H)

    def step(h, inp):
        S_c, d_c = inp  # (B,H,P,N), (B,H)
        h_new = h * d_c[..., None, None] + S_c
        return h_new, h  # emit state BEFORE this chunk

    if h0 is None:
        h0 = jnp.zeros((B_, H, P, N), x.dtype)
    hT, h_before = jax.lax.scan(
        step,
        h0,
        (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_before = h_before.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # ---- inter-chunk output: y += C_q · h_before * exp(dA_cum_q)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, h_before)
    y_inter = y_inter * jnp.exp(dA_cum)[..., None]
    y = (y_intra + y_inter).reshape(B_, L, H, P)
    return y, hT


def ssd_decode_step(
    x: jax.Array,  # (B, H, P)
    dt: jax.Array,  # (B, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, N)
    Cm: jax.Array,  # (B, N)
    h: jax.Array,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    dA = jnp.exp(dt * A[None, :])  # (B,H)
    h_new = h * dA[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", Bm, dt, x
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm, h_new)
    return y, h_new


# ------------------------------------------------------------------ mixer
def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    Din, N = cfg.d_inner, cfg.ssm_state
    # layout: [z (Din) | x (Din) | B (N) | C (N) | dt (H)]
    z, xBC, dt = jnp.split(zxbcdt, [Din, 2 * Din + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xBC: (B,L,Cd), w: (K,Cd).

    Uses a true grouped convolution (one op) rather than K shifted copies —
    the shifted-slice formulation materializes K full-size temporaries."""
    K, Cd = w.shape
    out = jax.lax.conv_general_dilated(
        xBC,
        w[:, None, :],  # (K, 1, Cd) = (spatial, in/group=1, features)
        window_strides=(1,),
        padding=[(K - 1, 0)],  # causal
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=Cd,
    )
    return jax.nn.silu(out + b[None, None, :])


def _pad_len(L: int, chunk: int) -> int:
    return (chunk - L % chunk) % chunk


def _run_ssd(cfg, xh, dt, A, Bm, Cm, use_kernel: bool, h0=None):
    """Pads L to a chunk multiple with dt=0 (identity steps: no decay, no
    input) so the final state is exact, then truncates the output."""
    B, L = xh.shape[:2]
    pad = _pad_len(L, cfg.ssm_chunk)
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    if use_kernel:
        from repro.kernels.ssd import ops as ssd_ops

        y, hT = ssd_ops.ssd(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk, h0=h0)
    else:
        y, hT = ssd_chunked(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk, h0=h0)
    return y[:, :L], hT


def mamba_train(cfg: ModelConfig, p: dict, xres: jax.Array, use_kernel: bool = False):
    """Full-sequence mamba2 block (train/prefill). Returns residual output."""
    B, L, D = xres.shape
    Din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = apply_norm(cfg, xres, p, "norm")
    zxbcdt = shard(h @ p["in_proj"], "dp", None, "tp")  # (B,L, 2*Din+2N+H)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [Din, Din + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(B, L, H, P).astype(jnp.float32)
    y, _ = _run_ssd(
        cfg, xh, dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), use_kernel
    )
    y = y + xh * p["ssm_D"][None, None, :, None]
    y = y.reshape(B, L, Din).astype(xres.dtype)
    y = inner_norm(y * jax.nn.silu(z), p, "gate_norm")
    return xres + (y @ p["out_proj"]).astype(xres.dtype)


def mamba_prefill(cfg: ModelConfig, p: dict, xres: jax.Array):
    """Like mamba_train but also returns (ssm_state, conv_state) caches."""
    B, L, D = xres.shape
    Din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = apply_norm(cfg, xres, p, "norm")
    zxbcdt = h @ p["in_proj"]
    z, xBC_raw, dt = _split_proj(cfg, zxbcdt)
    K = cfg.ssm_conv_kernel
    conv_state = xBC_raw[:, -(K - 1) :, :]  # last K-1 pre-conv inputs
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [Din, Din + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(B, L, H, P).astype(jnp.float32)
    y, hT = _run_ssd(
        cfg, xh, dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), False
    )
    y = y + xh * p["ssm_D"][None, None, :, None]
    y = y.reshape(B, L, Din).astype(xres.dtype)
    y = inner_norm(y * jax.nn.silu(z), p, "gate_norm")
    return xres + (y @ p["out_proj"]).astype(xres.dtype), (hT, conv_state)


def mamba_decode(
    cfg: ModelConfig,
    p: dict,
    xres: jax.Array,  # (B, 1, D)
    cache: tuple[jax.Array, jax.Array],  # (ssm_state (B,H,P,N), conv_state (B,K-1,Cd))
):
    B = xres.shape[0]
    Din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    ssm_state, conv_state = cache
    h = apply_norm(cfg, xres, p, "norm")
    zxbcdt = (h @ p["in_proj"])[:, 0, :]  # (B, ...)
    z, xBC_new, dt = _split_proj(cfg, zxbcdt[:, None, :])
    xBC_new = xBC_new[:, 0, :]
    # roll conv state, apply conv at last position
    window = jnp.concatenate([conv_state, xBC_new[:, None, :]], axis=1)  # (B,K,Cd)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(xBC, [Din, Din + N], axis=-1)
    dtv = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    y, h_new = ssd_decode_step(
        xh, dtv, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), ssm_state
    )
    y = y + xh * p["ssm_D"][None, :, None]
    y = y.reshape(B, 1, Din).astype(xres.dtype)
    y = inner_norm(y * jax.nn.silu(z), p, "gate_norm")
    return xres + (y @ p["out_proj"]).astype(xres.dtype), (h_new, window[:, 1:, :])
