"""Model configuration and single-source parameter definitions.

Every architecture is described by a :class:`ModelConfig`; the parameter tree
(shapes, dtypes, sharding specs, initializers) is generated once by
``param_defs`` so real init (smoke tests), abstract init (dry-run), and
sharding specs can never diverge.

Layers are organized in *periods*: the smallest repeating pattern of
(mixer, ffn) sublayer kinds; the model is a ``jax.lax.scan`` over stacked
period parameters, keeping HLO size O(1) in depth (100-layer AOT compiles).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Physical mesh axis names (see launch/mesh.py). Params are replicated over
# "pod" (pure DP across pods; FSDP within a pod) — grads all-reduce over both.
FSDP_AXIS = "data"
TP_AXIS = "model"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # layer pattern: tuple of (mixer, ffn) kinds, cycled over num_layers.
    # mixer: "attn" | "xattn" | "mamba"; ffn: "mlp" | "moe" | "none"
    pattern: tuple = (("attn", "mlp"),)
    # norms: "rmsnorm" | "layernorm" | "nonparametric_ln" (olmo)
    norm_type: str = "rmsnorm"
    # rope
    rope_theta: float = 1e4
    rope_fraction: float = 1.0  # chatglm3 2d-RoPE: rotate only half of head_dim
    # ffn
    ffn_act: str = "swiglu"  # "swiglu" | "gelu"
    # moe
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2 / SSD)
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 256
    # vlm/audio frontend stub
    num_encoder_tokens: int = 0  # >0 -> cross-attention encoder states provided
    # dtypes / numerics
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    # training memory knobs (per-arch so the biggest models fit)
    remat: str = "full"  # "full" | "dots" | "none"
    optim_moment_dtype: Any = jnp.float32
    optim_master_fp32: bool = True
    # sharding strategy knobs (hillclimb levers, see EXPERIMENTS.md §Perf)
    fsdp_params: bool = True  # False: TP-only resident weights (serving)
    moe_ep: bool = False  # True: experts sharded over DP axis (EP serving)
    kv_quant: bool = False  # True: int8 KV cache with per-position scales
    attn_bf16_scores: bool = False  # True: bf16 score buffers, fp32 reductions
    seq_parallel: bool = False  # True: residual stream seq-sharded over 'model'
    # (Megatron-SP: norms/MLP run on S/tp shards; only attention gathers S)
    # serving
    max_decode_batch: int = 128
    # metadata
    family: str = "dense"
    active_params_note: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/logits dims
        always shard cleanly (e.g. mamba2's 50280 on a 16-way axis would
        otherwise force replicated (B,S,V) fp32 logits). Pad logits are masked
        to -inf in the unembed."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def num_periods(self) -> int:
        if self.num_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not a multiple of "
                f"pattern period {len(self.pattern)}"
            )
        return self.num_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def has(self, mixer_or_ffn: str) -> bool:
        return any(mixer_or_ffn in slot for slot in self.pattern)


# --------------------------------------------------------------------------
# Parameter definitions
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    spec: P
    init: str = "normal"  # normal | zeros | ones | scaled(fan_in)
    dtype: Any = None  # None -> cfg.param_dtype

    def with_stack(self, n: int) -> "ParamDef":
        return ParamDef(
            (n,) + self.shape, P(None, *self.spec), self.init, self.dtype
        )


def _norm_defs(cfg: ModelConfig, prefix: str) -> dict:
    if cfg.norm_type == "nonparametric_ln":
        return {}
    d = {f"{prefix}_scale": ParamDef((cfg.d_model,), P(None), "ones")}
    if cfg.norm_type == "layernorm":
        d[f"{prefix}_bias"] = ParamDef((cfg.d_model,), P(None), "zeros")
    return d


def _inner_norm_defs(cfg: ModelConfig, prefix: str, dim: int) -> dict:
    if cfg.norm_type == "nonparametric_ln":
        return {}
    return {f"{prefix}_scale": ParamDef((dim,), P(None), "ones")}


def _attn_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    defs = {
        "wq": ParamDef((D, H * Dh), P(FSDP_AXIS, TP_AXIS)),
        "wk": ParamDef((D, Hkv * Dh), P(FSDP_AXIS, TP_AXIS)),
        "wv": ParamDef((D, Hkv * Dh), P(FSDP_AXIS, TP_AXIS)),
        "wo": ParamDef((H * Dh, D), P(TP_AXIS, FSDP_AXIS), "scaled"),
    }
    defs.update(_norm_defs(cfg, "norm"))
    if cross:
        # cross-attn reads encoder states; keys/values from encoder dimension
        defs.update(_inner_norm_defs(cfg, "kv_norm", cfg.d_model))
    return defs


def _mlp_defs(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    defs = {
        "w_up": ParamDef((D, F), P(FSDP_AXIS, TP_AXIS)),
        "w_down": ParamDef((F, D), P(TP_AXIS, FSDP_AXIS), "scaled"),
    }
    if cfg.ffn_act == "swiglu":
        defs["w_gate"] = ParamDef((D, F), P(FSDP_AXIS, TP_AXIS))
    defs.update(_norm_defs(cfg, "ffn_norm"))
    return defs


def _moe_defs(cfg: ModelConfig) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    if cfg.moe_ep:
        # expert-parallel: experts resident, one (or E/dp) per DP rank —
        # no per-step expert-weight gathers (serving-optimal)
        e_up, e_down = P(FSDP_AXIS, None, TP_AXIS), P(FSDP_AXIS, TP_AXIS, None)
    else:
        e_up, e_down = P(None, FSDP_AXIS, TP_AXIS), P(None, TP_AXIS, FSDP_AXIS)
    defs = {
        "w_router": ParamDef((D, E), P(FSDP_AXIS, None), dtype=jnp.float32),
        "we_up": ParamDef((E, D, F), e_up),
        "we_gate": ParamDef((E, D, F), e_up),
        "we_down": ParamDef((E, F, D), e_down, "scaled"),
    }
    if cfg.num_shared_experts:
        Fs = cfg.num_shared_experts * F
        defs["ws_up"] = ParamDef((D, Fs), P(FSDP_AXIS, TP_AXIS))
        defs["ws_gate"] = ParamDef((D, Fs), P(FSDP_AXIS, TP_AXIS))
        defs["ws_down"] = ParamDef((Fs, D), P(TP_AXIS, FSDP_AXIS), "scaled")
    defs.update(_norm_defs(cfg, "ffn_norm"))
    return defs


def _mamba_defs(cfg: ModelConfig) -> dict:
    D, Din, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = Din + 2 * N  # x, B, C go through the causal conv
    defs = {
        # in_proj -> [z (gate), x, B, C, dt]
        "in_proj": ParamDef((D, 2 * Din + 2 * N + H), P(FSDP_AXIS, TP_AXIS)),
        "conv_w": ParamDef((cfg.ssm_conv_kernel, conv_dim), P(None, TP_AXIS)),
        "conv_b": ParamDef((conv_dim,), P(TP_AXIS), "zeros"),
        "A_log": ParamDef((H,), P(None), "ones", dtype=jnp.float32),
        "ssm_D": ParamDef((H,), P(None), "ones", dtype=jnp.float32),
        "dt_bias": ParamDef((H,), P(None), "zeros", dtype=jnp.float32),
        "out_proj": ParamDef((Din, D), P(TP_AXIS, FSDP_AXIS), "scaled"),
    }
    defs.update(_norm_defs(cfg, "norm"))
    defs.update(_inner_norm_defs(cfg, "gate_norm", Din))
    return defs


MIXER_DEFS = {"attn": _attn_defs, "xattn": lambda c: _attn_defs(c, cross=True)}
FFN_DEFS = {"mlp": _mlp_defs, "moe": _moe_defs, "none": lambda c: {}}


def slot_defs(cfg: ModelConfig, mixer: str, ffn: str) -> dict:
    defs = {}
    if mixer == "mamba":
        defs.update({f"mamba.{k}": v for k, v in _mamba_defs(cfg).items()})
    else:
        defs.update({f"{mixer}.{k}": v for k, v in MIXER_DEFS[mixer](cfg).items()})
    defs.update({f"{ffn}.{k}": v for k, v in FFN_DEFS[ffn](cfg).items()})
    return defs


def _strip_fsdp(defs: dict) -> dict:
    """TP-only residency: remove the FSDP ('data') axis from every param spec
    (serving configs — kills per-layer weight all-gathers)."""

    def strip(spec: P) -> P:
        return P(*[None if a == FSDP_AXIS else a for a in spec])

    return {
        k: ParamDef(d.shape, strip(d.spec), d.init, d.dtype)
        for k, d in defs.items()
    }


def param_defs(cfg: ModelConfig) -> dict:
    """Full parameter tree: {name: ParamDef}. Per-layer params carry a leading
    ``num_periods`` stack dim (the scan axis)."""
    n = cfg.num_periods
    defs: dict[str, ParamDef] = {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), P(TP_AXIS, FSDP_AXIS)),
        "lm_head": ParamDef((cfg.d_model, cfg.padded_vocab), P(FSDP_AXIS, TP_AXIS)),
    }
    defs.update(_norm_defs(cfg, "final_norm"))
    for si, (mixer, ffn) in enumerate(cfg.pattern):
        for k, d in slot_defs(cfg, mixer, ffn).items():
            defs[f"layers.{si}.{k}"] = d.with_stack(n)
    if not cfg.fsdp_params:
        defs = _strip_fsdp(defs)
    return defs


def period_param_defs(cfg: ModelConfig) -> dict:
    """One period's params WITHOUT the stack dim (for standalone body
    compiles in the roofline harness)."""
    defs: dict[str, ParamDef] = {}
    for si, (mixer, ffn) in enumerate(cfg.pattern):
        for k, d in slot_defs(cfg, mixer, ffn).items():
            defs[f"{si}.{k}"] = d
    if not cfg.fsdp_params:
        defs = _strip_fsdp(defs)
    return defs


# --------------------------------------------------------------------------
# Materialization: abstract (dry-run) / real (smoke tests) / pspecs
# --------------------------------------------------------------------------
def _unflatten(flat: dict) -> dict:
    tree: dict = {}
    for key, val in flat.items():
        node = tree
        parts = key.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def abstract_params(cfg: ModelConfig) -> dict:
    return _unflatten(
        {
            k: jax.ShapeDtypeStruct(d.shape, d.dtype or cfg.param_dtype)
            for k, d in param_defs(cfg).items()
        }
    )


def param_pspecs(cfg: ModelConfig) -> dict:
    return _unflatten({k: d.spec for k, d in param_defs(cfg).items()})


def abstract_period_params(cfg: ModelConfig) -> dict:
    return _unflatten(
        {
            k: jax.ShapeDtypeStruct(d.shape, d.dtype or cfg.param_dtype)
            for k, d in period_param_defs(cfg).items()
        }
    )


def period_pspecs(cfg: ModelConfig) -> dict:
    return _unflatten({k: d.spec for k, d in period_param_defs(cfg).items()})


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    flat = {}
    defs = param_defs(cfg)
    keys = jax.random.split(key, len(defs))
    for (name, d), k in zip(sorted(defs.items()), keys):
        dtype = d.dtype or cfg.param_dtype
        if d.init == "zeros":
            flat[name] = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            flat[name] = jnp.ones(d.shape, dtype)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = 1.0 / math.sqrt(fan_in)
            if d.init == "scaled":  # extra depth scaling for output projections
                scale /= math.sqrt(2.0 * cfg.num_layers)
            flat[name] = (
                jax.random.normal(k, d.shape, jnp.float32) * scale
            ).astype(dtype)
    return _unflatten(flat)


def count_params(cfg: ModelConfig) -> int:
    return sum(math.prod(d.shape) for d in param_defs(cfg).values())


def count_active_params(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only top_k + shared experts count)."""
    total = 0
    for name, d in param_defs(cfg).items():
        n = math.prod(d.shape)
        if ".we_" in name:  # routed experts: top_k of E active
            n = n * cfg.top_k // max(cfg.num_experts, 1)
        total += n
    return total


# --------------------------------------------------------------------------
# Norm application
# --------------------------------------------------------------------------
def apply_norm(cfg: ModelConfig, x: jax.Array, params: dict, prefix: str) -> jax.Array:
    """Normalization with fp32 *statistics* but the full-size multiply kept in
    the activation dtype — avoids materializing (B,S,D) fp32 staging tensors
    (XLA:TPU would fuse them; XLA:CPU's memory analysis shows they dominate)."""
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        out = x * r.astype(x.dtype)
        return out * params[f"{prefix}_scale"].astype(x.dtype)
    # layernorm / olmo's non-parametric LN
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + 1e-6)
    out = (x - mu.astype(x.dtype)) * r.astype(x.dtype)
    if cfg.norm_type == "nonparametric_ln":
        return out
    out = out * params[f"{prefix}_scale"].astype(x.dtype)
    if f"{prefix}_bias" in params:
        out = out + params[f"{prefix}_bias"].astype(x.dtype)
    return out


def inner_norm(x: jax.Array, params: dict, prefix: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    out = x * r.astype(x.dtype)
    scale = params.get(f"{prefix}_scale")
    if scale is not None:
        out = out * scale.astype(x.dtype)
    return out
