"""GQA self-attention (RoPE / partial RoPE), cross-attention, and KV caches.

Three entry points per mixer:
  - ``attn_train``   : full causal self-attention over the whole sequence
  - ``attn_prefill`` : same, but also returns the populated KV cache
  - ``attn_decode``  : one new token against a cached KV of length S

The einsum formulation below is the XLA-native path used for dry-run/roofline;
``kernels/attention`` provides the Pallas flash kernel for the same math
(selected via ``use_flash``), validated against these functions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.context import shard

from .common import ModelConfig, apply_norm


# ---------------------------------------------------------------- RoPE
def rope_freqs(cfg: ModelConfig, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 -> cos/sin of shape (..., rot_dim/2), fp32."""
    rot = int(cfg.hd * cfg.rope_fraction)
    rot -= rot % 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, Dh); cos/sin: (B, S, r/2) or (S, r/2). Rotates the first
    ``2*(r/2)`` dims (partial rotary for chatglm3), pass-through for the rest."""
    r2 = cos.shape[-1]
    xr, xp = x[..., : 2 * r2], x[..., 2 * r2 :]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    if cos.ndim == 2:  # (S, r/2) -> broadcast over batch and heads
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, S, r/2)
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    o1 = x1 * cos_ - x2 * sin_
    o2 = x2 * cos_ + x1 * sin_
    rotated = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([rotated.astype(x.dtype), xp], axis=-1) if xp.shape[-1] else rotated.astype(x.dtype)


# ---------------------------------------------------------------- QKV helpers
def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, kv_src: jax.Array):
    B = x.shape[0]
    q = (x @ p["wq"]).reshape(B, x.shape[1], cfg.num_heads, cfg.hd)
    k = (kv_src @ p["wk"]).reshape(B, kv_src.shape[1], cfg.num_kv_heads, cfg.hd)
    v = (kv_src @ p["wv"]).reshape(B, kv_src.shape[1], cfg.num_kv_heads, cfg.hd)
    return q, k, v


def _gqa_scores_full(cfg: ModelConfig, q, k, v, causal: bool, q_pos0: int = 0):
    """Full-materialized attention (B,Sq,H,Dh)x(B,Sk,Hkv,Dh) -> (B,Sq,H,Dh).

    KV heads are expanded to the full head count so every intermediate
    (q/k/v/scores) shards cleanly over ('model') on the head dim — H is a
    multiple of the TP axis for all assigned archs, while Hkv often is not
    (e.g. 8 kv-heads on a 16-way axis). The expansion costs O(B*S*H*Dh) HBM,
    negligible next to the O(B*H*S^2) scores it lets us shard.
    """
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    G = H // cfg.num_kv_heads
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    q = shard(q, "dp", None, "tp", None)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = shard(scores, "dp", "tp", None, None)
    scores *= 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_pos0
        ki = jnp.arange(Sk)[None, :]
        mask = qi >= ki
        scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    return shard(out, "dp", None, "tp", None)


def _chunked_causal_attention(cfg: ModelConfig, q, k, v, chunk: int):
    """Causal attention, q chunked into static (unrolled) blocks — the
    XLA-native flash idiom: never materializes S x S scores, each chunk only
    attends to its causal key prefix (true causal FLOPs, ~half of full), and
    the unrolled chunks are counted correctly by cost analysis.

    q/k/v: (B, S, H, Dh), kv already expanded to H heads.
    """
    B, S, H, Dh = q.shape
    # fold the softmax scale into q (one small pass instead of a score pass)
    q = q * (1.0 / jnp.sqrt(Dh)).astype(q.dtype)
    n = max(1, S // chunk)
    c = S // n
    bf16_scores = cfg.attn_bf16_scores
    outs = []
    for i in range(n):
        qs = q[:, i * c : (i + 1) * c]  # (B, c, H, Dh)
        hi = (i + 1) * c
        ks, vs = k[:, :hi], v[:, :hi]
        qi = jnp.arange(c)[:, None] + i * c
        ki = jnp.arange(hi)[None, :]
        if bf16_scores:
            # bf16 score buffers; reductions (max/sum) still accumulate fp32
            s = jnp.einsum("bqhd,bkhd->bhqk", qs, ks)
            bias = jnp.where(qi >= ki, 0.0, -1e30).astype(s.dtype)
            s = s + bias[None, None]
            m = jnp.max(s.astype(jnp.float32), axis=-1, keepdims=True)
            p = jnp.exp(s - m.astype(s.dtype))
            denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
            w = p * (1.0 / denom).astype(s.dtype)
        else:
            # fp32 accumulation straight out of the MXU: no convert pass
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qs, ks, preferred_element_type=jnp.float32
            )
            # additive causal mask (single fused add, no where-select buffer)
            bias = jnp.where(qi >= ki, 0.0, -1e30).astype(jnp.float32)
            w = jax.nn.softmax(s + bias[None, None], axis=-1).astype(q.dtype)
        # scores inherit head sharding from q/k — no explicit constraint
        # (a with_sharding_constraint here materializes a full copy)
        outs.append(jnp.einsum("bhqk,bkhd->bqhd", w, vs))
    out = jnp.concatenate(outs, axis=1) if n > 1 else outs[0]
    return shard(out, "dp", None, "tp", None)


# ---------------------------------------------------------------- entry points
def attn_train(cfg: ModelConfig, p: dict, x: jax.Array, use_flash: bool = False):
    """Causal self-attention over full sequence (training / prefill compute)."""
    h = apply_norm(cfg, x, p, "norm")
    q, k, v = _project_qkv(cfg, p, h, h)
    pos = jnp.arange(x.shape[1])
    cos, sin = rope_freqs(cfg, pos)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    if use_flash:
        from repro.kernels.attention import ops as flash_ops

        out = flash_ops.flash_attention(q, k, v, causal=True)
    else:
        G = cfg.num_heads // cfg.num_kv_heads
        if G > 1:
            k, v = jnp.repeat(k, G, axis=2), jnp.repeat(v, G, axis=2)
        q = shard(q, "dp", None, "tp", None)
        k = shard(k, "dp", None, "tp", None)
        v = shard(v, "dp", None, "tp", None)
        out = _chunked_causal_attention(cfg, q, k, v, chunk=2048)
    B, S = x.shape[:2]
    return x + (out.reshape(B, S, -1) @ p["wo"]).astype(x.dtype)


def attn_prefill(cfg: ModelConfig, p: dict, x: jax.Array, max_len: int = 0):
    """Returns (residual output, (k_cache, v_cache)) for subsequent decode.
    ``max_len`` pads the cache along S so decode can append in place."""
    h = apply_norm(cfg, x, p, "norm")
    q, k, v = _project_qkv(cfg, p, h, h)
    pos = jnp.arange(x.shape[1])
    cos, sin = rope_freqs(cfg, pos)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    G = cfg.num_heads // cfg.num_kv_heads
    ke = jnp.repeat(k, G, axis=2) if G > 1 else k
    ve = jnp.repeat(v, G, axis=2) if G > 1 else v
    q = shard(q, "dp", None, "tp", None)
    ke = shard(ke, "dp", None, "tp", None)
    ve = shard(ve, "dp", None, "tp", None)
    out = _chunked_causal_attention(cfg, q, ke, ve, chunk=2048)
    B, S = x.shape[:2]
    y = x + (out.reshape(B, S, -1) @ p["wo"]).astype(x.dtype)
    # cache layout: (B, Hkv, S, Dh) — batch then heads leading for sharding
    kc, vc = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    if max_len and max_len > S:
        pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0))
        kc, vc = jnp.pad(kc, pad), jnp.pad(vc, pad)
    return y, (kc, vc)


def attn_decode(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, 1, D) current token hidden
    cache: tuple[jax.Array, jax.Array],  # (B, Hkv, S, Dh) x2
    position: jax.Array,  # (B,) current write index per sequence
):
    """One-token decode against cached KV; returns (y, updated cache)."""
    kc, vc = cache
    B, Hkv, S, Dh = kc.shape
    h = apply_norm(cfg, x, p, "norm")
    q, k, v = _project_qkv(cfg, p, h, h)  # q:(B,1,H,Dh) k/v:(B,1,Hkv,Dh)
    cos, sin = rope_freqs(cfg, position[:, None])  # (B,1,r/2)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    # write new kv at per-sequence position via scatter (O(B*Hkv*Dh) bytes,
    # not a full-cache rewrite)
    bidx = jnp.arange(B)
    kc = kc.at[bidx, :, position].set(k[:, 0])  # k[:,0]: (B,Hkv,Dh)
    vc = vc.at[bidx, :, position].set(v[:, 0])
    # attend: (B,1,H,Dh) x (B,Hkv,S,Dh); the cache S dim is sharded over
    # 'model' (flash-decoding style) — softmax over S becomes small
    # cross-shard reductions handled by SPMD.
    G = cfg.num_heads // Hkv
    qg = q.reshape(B, 1, Hkv, G, Dh)
    scores = jnp.einsum("bqhgd,bhkd->bhgqk", qg, kc).astype(jnp.float32)
    scores = shard(scores, "dp", None, None, None, "tp")
    scores *= 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    valid = jnp.arange(S)[None, :] <= position[:, None]  # (B,S)
    scores = jnp.where(valid[:, None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqk,bhkd->bqhgd", w, vc).reshape(B, 1, -1)
    y = x + (out @ p["wo"]).astype(x.dtype)
    return y, (kc, vc)


def attn_decode_quant(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, 1, D)
    cache: dict,  # k/v int8 (B,Hkv,S,Dh) + k_scale/v_scale fp32 (B,Hkv,S)
    position: jax.Array,  # (B,)
):
    """Decode against an int8 KV cache. Per-(seq-position, head) scales are
    applied on the scores / attention weights (128x smaller than the cache),
    so the cache itself is only ever read at 1 byte/element."""
    kc, vc = cache["k"], cache["v"]
    ks, vs = cache["k_scale"], cache["v_scale"]
    B, Hkv, S, Dh = kc.shape
    h = apply_norm(cfg, x, p, "norm")
    q, k, v = _project_qkv(cfg, p, h, h)
    cos, sin = rope_freqs(cfg, position[:, None])
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)

    def quant(new):  # (B,1,Hkv,Dh) -> int8 + per-(b,h) scale
        a = new[:, 0]  # (B,Hkv,Dh)
        scale = jnp.max(jnp.abs(a.astype(jnp.float32)), axis=-1) / 127.0 + 1e-9
        qv = jnp.clip(jnp.round(a.astype(jnp.float32) / scale[..., None]), -127, 127)
        return qv.astype(jnp.int8), scale

    kq, ksc = quant(k)
    vq, vsc = quant(v)
    bidx = jnp.arange(B)
    kc = kc.at[bidx, :, position].set(kq)
    vc = vc.at[bidx, :, position].set(vq)
    ks = ks.at[bidx, :, position].set(ksc)
    vs = vs.at[bidx, :, position].set(vsc)

    G = cfg.num_heads // Hkv
    qg = q.reshape(B, 1, Hkv, G, Dh)
    scores = jnp.einsum(
        "bqhgd,bhkd->bhgqk", qg.astype(jnp.float32), kc.astype(jnp.float32)
    )
    scores = scores * ks[:, :, None, None, :]  # dequant on scores, not cache
    scores = shard(scores, "dp", None, None, None, "tp")
    scores *= 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    valid = jnp.arange(S)[None, :] <= position[:, None]
    scores = jnp.where(valid[:, None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    w = w * vs[:, :, None, None, :]  # fold v-scales into the weights
    out = jnp.einsum(
        "bhgqk,bhkd->bqhgd", w.astype(jnp.float32), vc.astype(jnp.float32)
    ).astype(x.dtype).reshape(B, 1, -1)
    y = x + (out @ p["wo"]).astype(x.dtype)
    return y, {"k": kc, "v": vc, "k_scale": ks, "v_scale": vs}


def cross_attn(cfg: ModelConfig, p: dict, x: jax.Array, enc: jax.Array):
    """Cross-attention to (stub) encoder states ``enc``: (B, Se, D).
    No RoPE on cross keys (positions are modality-internal)."""
    h = apply_norm(cfg, x, p, "norm")
    q, k, v = _project_qkv(cfg, p, h, enc)
    out = _gqa_scores_full(cfg, q, k, v, causal=False)
    B, S = x.shape[:2]
    return x + (out.reshape(B, S, -1) @ p["wo"]).astype(x.dtype)


def cross_attn_prefill(cfg: ModelConfig, p: dict, x: jax.Array, enc: jax.Array):
    """Cross-attention that also returns the encoder KV cache for decode."""
    h = apply_norm(cfg, x, p, "norm")
    q, k, v = _project_qkv(cfg, p, h, enc)
    out = _gqa_scores_full(cfg, q, k, v, causal=False)
    B, S = x.shape[:2]
    y = x + (out.reshape(B, S, -1) @ p["wo"]).astype(x.dtype)
    return y, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))


def cross_attn_decode(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, 1, D)
    cache: tuple[jax.Array, jax.Array],  # encoder KV: (B, Hkv, Se, Dh) x2
):
    ek, ev = cache
    B, Hkv, Se, Dh = ek.shape
    h = apply_norm(cfg, x, p, "norm")
    q = (h @ p["wq"]).reshape(B, 1, cfg.num_heads, Dh)
    G = cfg.num_heads // Hkv
    qg = q.reshape(B, 1, Hkv, G, Dh)
    scores = jnp.einsum("bqhgd,bhkd->bhgqk", qg, ek).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqk,bhkd->bqhgd", w, ev).reshape(B, 1, -1)
    return x + (out @ p["wo"]).astype(x.dtype), cache
