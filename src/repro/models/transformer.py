"""Model assembly: scan-over-periods decoder with heterogeneous layer patterns.

Entry points (all pure functions of (cfg, params, ...)):
  forward_train(cfg, params, tokens, encoder_states) -> (logits, aux_loss)
  loss_fn(cfg, params, batch) -> (loss, metrics)
  prefill(cfg, params, tokens, encoder_states, max_len) -> (last_logits, cache)
  decode_step(cfg, params, token, cache, position) -> (logits, new_cache)

The layer stack is one jax.lax.scan over ``num_periods`` where each step
applies the config's (mixer, ffn) pattern — HLO size is O(period), not
O(depth), which keeps 100-layer AOT compiles tractable and matches how
production frameworks stack layers.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn as ffn_mod
from . import ssm
from .common import ModelConfig, apply_norm

Cache = Any  # nested dict pytree


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full"


def _embed(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    return params["embed"].astype(cfg.dtype)[tokens]


def _unembed(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = apply_norm(cfg, x, params, "final_norm")
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = logits + jnp.where(pad_mask, -1e30, 0.0)
    return logits


# ------------------------------------------------------------- period bodies
def apply_period_train(
    cfg: ModelConfig,
    h: jax.Array,
    layer_params: dict,
    encoder_states: Optional[jax.Array] = None,
    use_flash: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One period of the layer pattern (the scan body; also compiled standalone
    by the roofline harness to correct for XLA's count-loop-body-once costs).

    Each sublayer is individually checkpointed when remat is on, so the
    backward pass of a multi-layer period holds one sublayer's working set at
    a time (not the whole period's)."""
    nested = cfg.remat != "none" and len(cfg.pattern) > 1

    def ck(fn, *args):
        return jax.checkpoint(fn)(*args) if nested else fn(*args)

    def sp(hh):  # Megatron-SP: residual stream S-sharded between blocks
        if cfg.seq_parallel:
            from repro.sharding.context import shard

            return shard(hh, "dp", "tp", None)
        return hh

    aux = jnp.zeros((), jnp.float32)
    for si, (mixer, ffn_kind) in enumerate(cfg.pattern):
        sp_ = layer_params[str(si)]
        if mixer == "attn":
            h = sp(ck(lambda hh, pp=sp_: attn.attn_train(cfg, pp["attn"], hh, use_flash=use_flash), h))
        elif mixer == "xattn":
            h = sp(ck(lambda hh, pp=sp_: attn.cross_attn(cfg, pp["xattn"], hh, encoder_states), h))
        elif mixer == "mamba":
            h = ck(lambda hh, pp=sp_: ssm.mamba_train(cfg, pp["mamba"], hh), h)
        h, a = ck(
            lambda hh, pp=sp_, kind=ffn_kind: ffn_mod.apply_ffn(cfg, kind, pp.get(kind, {}), hh),
            h,
        )
        h = sp(h) if mixer != "mamba" else h
        aux = aux + a
    return h, aux


def apply_period_prefill(
    cfg: ModelConfig,
    h: jax.Array,
    layer_params: dict,
    encoder_states: Optional[jax.Array] = None,
    max_len: int = 0,
) -> tuple[jax.Array, jax.Array, dict]:
    def sp(hh):  # Megatron-SP between blocks (see apply_period_train)
        if cfg.seq_parallel:
            from repro.sharding.context import shard

            return shard(hh, "dp", "tp", None)
        return hh

    aux = jnp.zeros((), jnp.float32)
    cache_slice: dict = {}
    for si, (mixer, ffn_kind) in enumerate(cfg.pattern):
        sp_ = layer_params[str(si)]
        if mixer == "attn":
            h, kv = attn.attn_prefill(cfg, sp_["attn"], h, max_len=max_len)
            h = sp(h)
            cache_slice[str(si)] = {"k": kv[0], "v": kv[1]}
        elif mixer == "xattn":
            h, ekv = attn.cross_attn_prefill(cfg, sp_["xattn"], h, encoder_states)
            h = sp(h)
            cache_slice[str(si)] = {"ek": ekv[0], "ev": ekv[1]}
        elif mixer == "mamba":
            h, (hT, conv) = ssm.mamba_prefill(cfg, sp_["mamba"], h)
            cache_slice[str(si)] = {"ssm": hT, "conv": conv}
        h, a = ffn_mod.apply_ffn(cfg, ffn_kind, sp_.get(ffn_kind, {}), h)
        if mixer != "mamba":
            h = sp(h)
        aux = aux + a
    return h, aux, cache_slice


def apply_period_decode(
    cfg: ModelConfig,
    h: jax.Array,
    layer_params: dict,
    cache_slice: dict,
    position: jax.Array,
) -> tuple[jax.Array, dict]:
    new_slice: dict = {}
    for si, (mixer, ffn_kind) in enumerate(cfg.pattern):
        sp = layer_params[str(si)]
        if mixer == "attn":
            cs = cache_slice[str(si)]
            if cfg.kv_quant:
                h, new_cs = attn.attn_decode_quant(cfg, sp["attn"], h, cs, position)
                new_slice[str(si)] = new_cs
            else:
                h, (kc, vc) = attn.attn_decode(
                    cfg, sp["attn"], h, (cs["k"], cs["v"]), position
                )
                new_slice[str(si)] = {"k": kc, "v": vc}
        elif mixer == "xattn":
            cs = cache_slice[str(si)]
            h, _ = attn.cross_attn_decode(cfg, sp["xattn"], h, (cs["ek"], cs["ev"]))
            new_slice[str(si)] = cs
        elif mixer == "mamba":
            cs = cache_slice[str(si)]
            h, (hn, conv) = ssm.mamba_decode(
                cfg, sp["mamba"], h, (cs["ssm"], cs["conv"])
            )
            new_slice[str(si)] = {"ssm": hn, "conv": conv}
        h, _ = ffn_mod.apply_ffn(cfg, ffn_kind, sp.get(ffn_kind, {}), h)
    return h, new_slice


# --------------------------------------------------------------------- train
def forward_train(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S) int32
    encoder_states: Optional[jax.Array] = None,  # (B, Se, D) for vlm/audio
    use_flash: bool = False,
) -> tuple[jax.Array, jax.Array]:
    x = _embed(cfg, params, tokens)

    def body(carry, layer_params):
        h, aux = carry
        h, a = apply_period_train(cfg, h, layer_params, encoder_states, use_flash)
        return (h, aux + a), None

    (x, aux), _ = jax.lax.scan(
        _remat(cfg, body), (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    return _unembed(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> tuple[jax.Array, dict]:
    logits, aux = forward_train(
        cfg, params, batch["tokens"], batch.get("encoder_states")
    )
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        loss = nll.mean()
    else:
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"nll": loss, "aux": aux}


# -------------------------------------------------------------------- prefill
def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S)
    encoder_states: Optional[jax.Array] = None,
    max_len: int = 0,
) -> tuple[jax.Array, Cache]:
    x = _embed(cfg, params, tokens)

    def body(carry, layer_params):
        h, aux = carry
        h, a, cache_slice = apply_period_prefill(
            cfg, h, layer_params, encoder_states, max_len
        )
        return (h, aux + a), cache_slice

    (x, _), cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    logits = _unembed(cfg, params, x[:, -1:, :])[:, 0, :]
    return logits, cache


# --------------------------------------------------------------------- decode
def decode_step(
    cfg: ModelConfig,
    params: dict,
    token: jax.Array,  # (B,) int32 — current token
    cache: Cache,  # pytree with leading num_periods dim on every leaf
    position: jax.Array,  # (B,) int32 — write index (= #tokens so far)
) -> tuple[jax.Array, Cache]:
    x = _embed(cfg, params, token[:, None])  # (B, 1, D)

    def body(h, xs):
        layer_params, cache_slice = xs
        return apply_period_decode(cfg, h, layer_params, cache_slice, position)

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    logits = _unembed(cfg, params, x)[:, 0, :]  # (B, V)
    return logits, new_cache


# ---------------------------------------------------------------- cache specs
def abstract_cache_slice(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    """ShapeDtypeStruct tree for ONE period's cache slice."""
    Dh, Hkv = cfg.hd, cfg.num_kv_heads
    sds = jax.ShapeDtypeStruct
    slices: dict[str, dict] = {}
    for si, (mixer, _ffn) in enumerate(cfg.pattern):
        if mixer == "attn":
            shape = (batch, Hkv, max_len, Dh)
            if cfg.kv_quant:
                slices[str(si)] = {
                    "k": sds(shape, jnp.int8),
                    "v": sds(shape, jnp.int8),
                    "k_scale": sds(shape[:-1], jnp.float32),
                    "v_scale": sds(shape[:-1], jnp.float32),
                }
                continue
            slices[str(si)] = {"k": sds(shape, cfg.dtype), "v": sds(shape, cfg.dtype)}
        elif mixer == "xattn":
            shape = (batch, Hkv, cfg.num_encoder_tokens, Dh)
            slices[str(si)] = {"ek": sds(shape, cfg.dtype), "ev": sds(shape, cfg.dtype)}
        elif mixer == "mamba":
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            slices[str(si)] = {
                "ssm": sds(
                    (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32,
                ),
                "conv": sds((batch, cfg.ssm_conv_kernel - 1, conv_dim), cfg.dtype),
            }
    return slices


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    """Full-cache ShapeDtypeStructs (leading num_periods scan dim)."""
    nP = cfg.num_periods
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((nP,) + s.shape, s.dtype),
        abstract_cache_slice(cfg, batch, max_len),
    )


# ------------------------------------------------------------------ greedy gen
def generate(
    cfg: ModelConfig,
    params: dict,
    prompt: jax.Array,  # (B, S)
    num_steps: int,
    encoder_states: Optional[jax.Array] = None,
) -> jax.Array:
    """Greedy generation driver (used by examples/tests; the serving engine in
    repro.serve drives decode_step itself for continuous batching)."""
    B, S = prompt.shape
    logits, cache = prefill(
        cfg, params, prompt, encoder_states, max_len=S + num_steps
    )
    token = jnp.argmax(logits, axis=-1).astype(prompt.dtype)

    def step(carry, _):
        token, cache, pos = carry
        logits, cache = decode_step(cfg, params, token, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(token.dtype)
        return (nxt, cache, pos + 1), nxt

    (_, _, _), toks = jax.lax.scan(
        step, (token, cache, jnp.full((B,), S, jnp.int32)), None, length=num_steps
    )
    return jnp.concatenate([token[None], toks], axis=0).T  # (B, num_steps+1)
