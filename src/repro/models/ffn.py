"""Dense MLP and Mixture-of-Experts feed-forward layers.

The MoE dispatch is the *vectorized hybrid-queue* (paper §4 adapted, see
DESIGN.md §2): tokens are routed to partitions (experts) by a stable sort that
preserves arrival order within each partition (= the master-queue order), with
a per-partition capacity (= bounded delegation).

SPMD layout: dispatch happens in a (R, T/R) row layout where R = the DP shard
count, so routing/sort/scatter are *row-local* (never cross shards — the
paper's partitioned-queue locality, with experts replicated across DP and
TP-sharded on d_ff). Expert-parallel all-to-all dispatch is the alternative
(EP; see §Perf hillclimb).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.sharding.context import get_mesh, shard

from .common import ModelConfig, apply_norm

try:  # jax >= 0.6 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _act(cfg: ModelConfig, up: jax.Array, gate: jax.Array | None) -> jax.Array:
    if cfg.ffn_act == "swiglu":
        return jax.nn.silu(gate) * up
    return jax.nn.gelu(up)


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    h = apply_norm(cfg, x, p, "ffn_norm")
    up = h @ p["w_up"]
    gate = h @ p["w_gate"] if "w_gate" in p else None
    return x + (_act(cfg, up, gate) @ p["w_down"]).astype(x.dtype)


# ----------------------------------------------------------------- MoE
def _num_rows(mesh, tokens: int) -> int:
    if mesh is None:
        return 1
    r = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            r *= mesh.shape[a]
    return r if tokens % r == 0 else 1


def moe_dispatch_rowwise(
    expert_ids: jax.Array, num_experts: int, capacity: int
) -> tuple[jax.Array, jax.Array]:
    """Row-local hybrid-queue dispatch.

    expert_ids: (R, A) int32, arrival order = column index within each row.
    Returns (dest, keep): dest[r, a] is the slot in that row's (E*C) buffer
    (E*C when dropped). Stable sort preserves arrival order per partition —
    the master-queue property of the paper's §4.3.
    """
    R, A = expert_ids.shape
    sort_idx = jnp.argsort(expert_ids, axis=-1, stable=True)
    sorted_ids = jnp.take_along_axis(expert_ids, sort_idx, axis=-1)
    group_start = jax.vmap(
        lambda s: jnp.searchsorted(s, jnp.arange(num_experts), side="left")
    )(sorted_ids)  # (R, E)
    gs_of = jnp.take_along_axis(group_start, sorted_ids, axis=-1)  # (R, A)
    pos_in_group = jnp.arange(A)[None, :] - gs_of
    keep_sorted = pos_in_group < capacity
    dest_sorted = jnp.where(
        keep_sorted, sorted_ids * capacity + pos_in_group, num_experts * capacity
    )
    rows = jnp.arange(R)[:, None]
    dest = jnp.zeros((R, A), dest_sorted.dtype).at[rows, sort_idx].set(dest_sorted)
    keep = jnp.zeros((R, A), bool).at[rows, sort_idx].set(keep_sorted)
    return dest, keep


def moe(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (residual output, load-balancing aux loss)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.top_k
    # EP mode (serving): global dispatch, buffers sharded over DP by EXPERT —
    # tokens all-to-all to their resident expert instead of gathering weights
    R = 1 if cfg.moe_ep else _num_rows(get_mesh(), T)
    Tl = T // R
    h_all = apply_norm(cfg, x, p, "ffn_norm").reshape(T, D)
    h = shard(h_all.reshape(R, Tl, D), "dp", None, None)

    logits = jnp.einsum("rtd,de->rte", h.astype(jnp.float32), p["w_router"])
    gates = jax.nn.softmax(logits, axis=-1)  # (R, Tl, E)
    top_v, top_i = jax.lax.top_k(gates, k)  # (R, Tl, k)
    top_v = top_v / jnp.maximum(top_v.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(gates, axis=(0, 1))
    rows = jnp.arange(R)[:, None]
    ce = (
        jnp.zeros((R, E), jnp.float32)
        .at[rows, top_i.reshape(R, Tl * k)]
        .add(1.0)
        .mean(0)
        / (Tl * k)
    )
    aux = E * jnp.sum(me * ce)

    capacity = max(int(math.ceil(Tl * k / E * cfg.capacity_factor)), 4)
    ids = top_i.reshape(R, Tl * k)
    dest, keep = moe_dispatch_rowwise(ids, E, capacity)

    token_of = jnp.arange(Tl * k) // k  # (Tl*k,) same for all rows
    h_assign = h[:, token_of, :]  # (R, Tl*k, D)

    # Row-local scatter/gather. Under a mesh these run inside shard_map so
    # SPMD provably keeps them local to each DP rank (auto propagation was
    # observed to replicate the (R, E*C, D) buffer on every device).
    def _scatter(d_r, v_r):
        return jax.vmap(
            lambda d, v: jnp.zeros((E * capacity, D), x.dtype).at[d].set(
                v, mode="drop"
            )
        )(d_r, v_r)

    def _gather_combine(f_r, i_r, k_r, c_r):
        pa = jax.vmap(lambda f, i: f[i])(f_r, i_r)
        pa = pa * k_r[..., None].astype(x.dtype) * c_r[..., None]
        return jax.vmap(
            lambda v: jnp.zeros((Tl, D), x.dtype).at[token_of].add(v)
        )(pa)

    mesh = get_mesh()
    local = mesh is not None and R > 1
    if local:
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        row2 = P(dp, None)
        row3 = P(dp, None, None)
        scatter = _shard_map(
            _scatter, mesh=mesh, in_specs=(row2, row3), out_specs=row3
        )
        gather_combine = _shard_map(
            _gather_combine,
            mesh=mesh,
            in_specs=(row3, row2, row2, row2),
            out_specs=row3,
        )
    else:
        scatter, gather_combine = _scatter, _gather_combine

    buf = scatter(dest, h_assign)
    if cfg.moe_ep:
        buf = shard(buf.reshape(R, E, capacity, D), None, "dp", None, None)
        up = jnp.einsum("recd,edf->recf", buf, p["we_up"])
        gate = jnp.einsum("recd,edf->recf", buf, p["we_gate"])
        up = shard(up, None, "dp", None, "tp")
        gate = shard(gate, None, "dp", None, "tp")
        down = jnp.einsum("recf,efd->recd", jax.nn.silu(gate) * up, p["we_down"])
        out_flat = shard(down.reshape(R, E * capacity, D), None, None, None)
    else:
        buf = shard(buf.reshape(R, E, capacity, D), "dp", None, None, None)
        up = jnp.einsum("recd,edf->recf", buf, p["we_up"])
        gate = jnp.einsum("recd,edf->recf", buf, p["we_gate"])
        up = shard(up, "dp", None, None, "tp")
        gate = shard(gate, "dp", None, None, "tp")
        down = jnp.einsum("recf,efd->recd", jax.nn.silu(gate) * up, p["we_down"])
        out_flat = shard(down.reshape(R, E * capacity, D), "dp", None, None)

    safe = jnp.where(keep, dest, 0)
    combine = top_v.reshape(R, Tl * k).astype(x.dtype)
    y = gather_combine(out_flat, safe, keep, combine)
    y = shard(y, "dp", None, None).reshape(T, D)

    if cfg.num_shared_experts:
        sup = h_all @ p["ws_up"]
        sgate = h_all @ p["ws_gate"]
        y = y + (jax.nn.silu(sgate) * sup) @ p["ws_down"]

    return x + y.reshape(B, S, D).astype(x.dtype), aux


def apply_ffn(cfg: ModelConfig, kind: str, p: dict, x: jax.Array):
    """Uniform interface: returns (y, aux)."""
    if kind == "mlp":
        return mlp(cfg, p, x), jnp.zeros((), jnp.float32)
    if kind == "moe":
        return moe(cfg, p, x)
    return x, jnp.zeros((), jnp.float32)  # "none"
