"""Sharded checkpointing with elastic restore (fault tolerance substrate).

Design (DESIGN.md §5):
- save: each leaf is gathered per host-shard and written as .npy alongside a
  JSON manifest (tree structure, shapes, dtypes, step, data-pipeline cursor).
  Writes go to a temp dir + atomic rename, so a crash mid-save never corrupts
  the latest checkpoint (restart-safety).
- restore: reshards to ANY mesh — the manifest stores logical arrays, and
  ``jax.device_put`` with the target NamedSharding redistributes. 256 -> 512
  chips (elastic scale-up) or CPU test meshes restore identically.
- the data-pipeline cursor is the ordered stream's serial number (paper §3):
  replaying from serial k gives exactly-once training-sample semantics after
  failover.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# ml_dtypes arrays round-trip .npy as raw views + a logical dtype tag
_VIEW_OF = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _flatten(tree: Any, prefix: str = "") -> dict:
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}."))
        return out
    return {prefix[:-1]: tree}


def _unflatten(flat: dict) -> dict:
    tree: dict = {}
    for key, val in flat.items():
        node = tree
        parts = key.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: dict, extra: Optional[dict] = None) -> str:
        """state: pytree of jax arrays. extra: JSON-serializable metadata
        (e.g. {"data_serial": 12345} — the ordered-stream replay cursor)."""
        flat = _flatten(state)
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_save_")
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for name, arr in flat.items():
            host = np.asarray(jax.device_get(arr))
            fname = name.replace("/", "_") + ".npy"
            logical = str(host.dtype)
            if logical in _VIEW_OF:
                host = host.view(_VIEW_OF[logical])
            np.save(os.path.join(tmp, fname), host)
            manifest["leaves"][name] = {
                "file": fname,
                "shape": list(host.shape),
                "dtype": logical,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(self.directory, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"))

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> tuple[int, dict, dict]:
        """Returns (step, state, extra). ``shardings``: optional pytree of
        NamedSharding matching the state structure — enables elastic restore
        onto any mesh; None keeps arrays on the default device."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        flat = {}
        for name, meta in manifest["leaves"].items():
            host = np.load(os.path.join(path, meta["file"]))
            if meta["dtype"] in _VIEW_OF:
                host = host.view(getattr(ml_dtypes, meta["dtype"]))
            sh = flat_shard.get(name)
            flat[name] = (
                jax.device_put(host, sh) if sh is not None else jax.device_put(host)
            )
        return manifest["step"], _unflatten(flat), manifest["extra"]
