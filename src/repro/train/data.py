"""Ordered training data pipeline (paper §3 serial numbers as replay cursor).

Batches carry a monotone global serial; the checkpoint stores the cursor so a
restart (possibly on a different mesh size — elastic) resumes exactly-once.
The pipeline itself is a linear ordered stream: generate -> pack -> batch,
deterministic given (seed, serial).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class OrderedTokenPipeline:
    """Synthetic LM stream: per-batch deterministic generation keyed by the
    batch serial, so any worker on any topology produces identical batches in
    identical order — ordered processing for the input pipeline."""

    def __init__(self, cfg: DataConfig, start_serial: int = 0):
        self.cfg = cfg
        self.serial = start_serial

    def _batch_for(self, serial: int) -> dict:
        rng = np.random.RandomState((self.cfg.seed * 1_000_003 + serial) % (2**31))
        B, S, V = self.cfg.global_batch, self.cfg.seq_len, self.cfg.vocab_size
        # Markov-ish synthetic text: mixture of a few token bigram chains
        base = rng.randint(0, V, size=(B, 1))
        steps = rng.randint(1, 17, size=(B, S))
        toks = (np.cumsum(steps, axis=1) + base) % V
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = tokens[:, 0]
        return {"tokens": tokens, "labels": labels, "serial": serial}

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = self._batch_for(self.serial)
        self.serial += 1
        return batch

    def cursor(self) -> int:
        return self.serial

    def seek(self, serial: int) -> None:
        self.serial = serial
