"""AdamW with cosine schedule — pure-JAX pytree implementation.

Moment dtype and fp32-master are config-switchable per architecture so the
largest models (jamba-398B) fit the pod: moments in bf16 halve optimizer HBM;
the fp32 master copy is optional. Optimizer state inherits each parameter's
sharding, so state is fully FSDP/TP-sharded like the params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32
    master_fp32: bool = True


def schedule(ocfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(ocfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - ocfg.warmup_steps) / jnp.maximum(ocfg.decay_steps - ocfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = ocfg.min_lr_frac + (1 - ocfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return ocfg.peak_lr * jnp.where(step < ocfg.warmup_steps, warm, cos)


def init_opt_state(ocfg: OptConfig, params: Any) -> dict:
    zeros_like = lambda p: jnp.zeros(p.shape, ocfg.moment_dtype)
    state = {
        "mu": jax.tree.map(zeros_like, params),
        "nu": jax.tree.map(zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if ocfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def abstract_opt_state(ocfg: OptConfig, abstract_params: Any) -> dict:
    sds = lambda p, dt: jax.ShapeDtypeStruct(p.shape, dt)
    state = {
        "mu": jax.tree.map(lambda p: sds(p, ocfg.moment_dtype), abstract_params),
        "nu": jax.tree.map(lambda p: sds(p, ocfg.moment_dtype), abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if ocfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: sds(p, jnp.float32), abstract_params)
    return state


def opt_state_pspecs(ocfg: OptConfig, param_pspecs: Any) -> dict:
    from jax.sharding import PartitionSpec as P

    state = {
        "mu": param_pspecs,
        "nu": param_pspecs,
        "step": P(),
    }
    if ocfg.master_fp32:
        state["master"] = param_pspecs
    return state


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_adamw(
    ocfg: OptConfig, params: Any, grads: Any, state: dict
) -> tuple[Any, dict, dict]:
    step = state["step"] + 1
    lr = schedule(ocfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu, master):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        base = master.astype(jnp.float32) if master is not None else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + ocfg.eps) + ocfg.weight_decay * base)
        return new, mu32.astype(ocfg.moment_dtype), nu32.astype(ocfg.moment_dtype)

    masters = state.get("master")
    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = jax.tree.leaves(grads)
    leaves_mu = jax.tree.leaves(state["mu"])
    leaves_nu = jax.tree.leaves(state["nu"])
    leaves_m = jax.tree.leaves(masters) if masters is not None else [None] * len(leaves_p)

    new_p, new_mu, new_nu, new_master = [], [], [], []
    for p, g, mu, nu, m in zip(leaves_p, leaves_g, leaves_mu, leaves_nu, leaves_m):
        n, mu2, nu2 = upd(p, g, mu, nu, m)
        new_p.append(n.astype(p.dtype))
        new_mu.append(mu2)
        new_nu.append(nu2)
        if m is not None:
            new_master.append(n)

    new_state = {
        "mu": jax.tree.unflatten(treedef, new_mu),
        "nu": jax.tree.unflatten(treedef, new_nu),
        "step": step,
    }
    if masters is not None:
        new_state["master"] = jax.tree.unflatten(treedef, new_master)
    params_out = jax.tree.unflatten(treedef, new_p)
    return params_out, new_state, {"lr": lr, "grad_norm": gnorm}
