"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

Multi-pod training all-reduces gradients over the 'pod' axis (slower
inter-pod links). This module quantizes each pod's gradient shard to int8
with per-chunk scales, all-gathers the int8 payload (2 pods -> 2x int8 bytes
= 0.5x of one fp32/bf16 all-reduce), dequantizes and sums locally, and keeps
the quantization residual as error feedback added to the next step's
gradient (Karimireddy et al., error feedback fixes signSGD-style bias).

Exposed as a drop-in on the train step: compress_grads(grads, err) inside
shard_map over the pod axis.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

try:
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_allreduce_leaf(
    g: jax.Array, err: jax.Array, axis_name: str
) -> tuple[jax.Array, jax.Array]:
    """Inside shard_map: one leaf's compressed all-reduce over ``axis_name``.
    Returns (summed gradient fp32, new error-feedback residual)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = _quantize(g32)
    new_err = g32 - _dequantize(q, scale)
    # all_gather int8 payload + scales; sum dequantized locally
    qs = jax.lax.all_gather(q, axis_name)  # (n_pods, ...)
    scales = jax.lax.all_gather(scale, axis_name)  # (n_pods,)
    total = jnp.tensordot(
        scales, qs.astype(jnp.float32), axes=((0,), (0,))
    )
    return total.astype(g.dtype), new_err


def make_compressed_psum(mesh: Mesh, param_pspecs: Any, abstract_params: Any):
    """Returns fn(grads, err_state) -> (summed_grads, new_err) performing the
    int8 error-feedback sum over the 'pod' axis via shard_map. Leaf specs are
    the (sanitized) param specs with the pod axis absent (grads are computed
    per-pod and replicated across 'pod' only after this sum)."""
    from repro.sharding.partitioning import sanitize_spec

    specs = jax.tree.map(
        lambda s, a: sanitize_spec(mesh, s, a.shape),
        param_pspecs,
        abstract_params,
        is_leaf=lambda x: isinstance(x, P),
    )

    def summed(grads, err):
        def leaf_fn(spec):
            def fn(g, e):
                return compress_allreduce_leaf(g, e, "pod")

            return _shard_map(
                fn,
                mesh=mesh,
                in_specs=(spec, spec),
                out_specs=(spec, spec),
                check_vma=False,
            )

        outs = jax.tree.map(
            lambda g, e, s: leaf_fn(s)(g, e),
            grads,
            err,
            specs,
            is_leaf=lambda x: isinstance(x, jax.Array) or hasattr(x, "shape"),
        )
        new_grads = jax.tree.map(lambda t: t[0], outs, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], outs, is_leaf=lambda x: isinstance(x, tuple))
        return new_grads, new_err

    return summed


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
